"""Batched serving example: prefill a request batch, decode with a donated
KV cache; works for every family (dense GQA, MoE, xLSTM O(1)-state, ...).

    PYTHONPATH=src python examples/serve_batch.py --arch xlstm-1.3b-smoke

Speculative decoding rides the same entry point: ``--spec-k 4`` drafts 4
tokens per slot from each request's own history and verifies them in one
step (``--spec-k auto`` lets the tuner pick from the trace's measured
repetitiveness).  Streams are bit-identical to ``--spec-k 0``; on a
repetitive trace the accepted-tokens/verify-step figure printed below
clears 1 and decode finishes in fewer steps:

    PYTHONPATH=src python examples/serve_batch.py \
        --arch picolm-4-smoke --kv-layout paged \
        --trace repetitive --decode 48 --spec-k 4
"""

import argparse

from repro.launch.serve import TRACES, serve_main


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="deepseek-7b-smoke")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prefill", type=int, default=64)
    p.add_argument("--decode", type=int, default=16)
    p.add_argument("--kv-layout", default="contiguous")
    p.add_argument("--trace", choices=TRACES, default="uniform")
    p.add_argument("--spec-k", default="0",
                   help="draft tokens per verify step (0=off, 'auto'=tuner)")
    a = p.parse_args()
    spec_k = None if a.spec_k == "auto" else int(a.spec_k)
    out = serve_main(arch=a.arch, batch=a.batch, prefill_len=a.prefill,
                     decode_tokens=a.decode, kv_layout=a.kv_layout,
                     trace=a.trace, spec_k=spec_k)
    msg = (f"\n{a.arch}: {out['decode_tok_per_s']:.1f} decode tok/s "
           f"(batch={a.batch}); first tokens of request 0: {out['sample']}")
    if out.get("spec_verify_steps"):
        msg += (f"\nspeculative: k={out['spec_k']}, "
                f"{out['accepted_per_verify']:.2f} tokens/verify-step "
                f"({out['spec_accepted_tokens']}/{out['spec_drafted_tokens']}"
                f" drafts accepted over {out['spec_verify_steps']} verifies)")
    print(msg)


if __name__ == "__main__":
    main()
