"""Batched serving example: prefill a request batch, decode with a donated
KV cache; works for every family (dense GQA, MoE, xLSTM O(1)-state, ...).

    PYTHONPATH=src python examples/serve_batch.py --arch xlstm-1.3b-smoke
"""

import argparse

from repro.launch.serve import serve_main


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="deepseek-7b-smoke")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prefill", type=int, default=64)
    p.add_argument("--decode", type=int, default=16)
    a = p.parse_args()
    out = serve_main(arch=a.arch, batch=a.batch, prefill_len=a.prefill,
                     decode_tokens=a.decode)
    print(f"\n{a.arch}: {out['decode_tok_per_s']:.1f} decode tok/s "
          f"(batch={a.batch}); first tokens of request 0: {out['sample']}")


if __name__ == "__main__":
    main()
