"""The paper's own use case (§4 + Listing 1.5): LULESH deployed via EASEY.

    PYTHONPATH=src python examples/lulesh_easey.py

Reproduces Table 1 in miniature: the Sedov solver run natively
(direct jit) vs through the complete EASEY pipeline, FOM + delta printed
per cube size.  The generated SLURM batch file — what would be submitted
on a real cluster — is printed for one job.
"""

import tempfile
import time
from pathlib import Path

from repro.core.appspec import AppSpec
from repro.core.jobspec import lulesh_example, parse_jobspec
from repro.core.workflow import run_easey
from repro.models import lulesh


def native_fom(grid, iters):
    cfg = lulesh.LuleshConfig(grid=grid, iters=iters)
    state = lulesh.init_state(cfg)
    lulesh.run(state, cfg, 2)["e"].block_until_ready()
    state = lulesh.init_state(cfg)
    t0 = time.perf_counter()
    lulesh.run(state, cfg, iters)["e"].block_until_ready()
    return lulesh.fom(grid ** 3, iters, time.perf_counter() - t0)


def main():
    storage = tempfile.mkdtemp(prefix="easey_lulesh_")
    print(f"{'p':>4} {'zones':>8} {'FOM native':>14} {'FOM easey':>14} {'delta':>8}")
    for grid, iters in [(8, 40), (13, 20), (16, 12)]:
        nat = native_fom(grid, iters)
        spec = parse_jobspec(lulesh_example())
        spec.executions[0].command = (
            f"ch-run -b ./data:/data lulesh.dash -- "
            f"/built/lulesh.dash -i {iters} -s {grid}")
        app = AppSpec(arch="lulesh-dash", shape="train_4k",
                      run=f"lulesh -i {iters} -s {grid}")
        # two runs: first pays jit, second is steady state (as Table 1)
        run_easey(app, "local:cpu", spec, storage=storage)
        mw, jid, _ = run_easey(app, "local:cpu", spec, storage=storage)
        eas = mw.scheduler.result(jid)[0]["fom"]
        print(f"{grid:>4} {grid**3:>8} {nat:>14,.0f} {eas:>14,.0f} "
              f"{(eas - nat) / nat * 100:>+7.2f}%")

    # show the batch file EASEY synthesized (paper Alg. 1 line 'create batch_file')
    batch = sorted(Path(storage, "cluster").glob("*/batch.sh"))[-1]
    print(f"\n--- generated {batch} ---")
    print(batch.read_text())


if __name__ == "__main__":
    main()
