"""Quickstart: deploy a model through EASEY in a dozen lines.

    PYTHONPATH=src python examples/quickstart.py

Writes an Appfile (the paper's Dockerfile analogue), builds it for the
local CPU target, packages it, submits it through the middleware
(Algorithm 1) and polls status + logs — the full Fig. 2 workflow.
"""

from pathlib import Path
import tempfile

from repro.core.appspec import AppSpec, parse_appfile
from repro.core.jobspec import parse_jobspec
from repro.core.workflow import run_easey

APPFILE = """\
FROM arch:deepseek-7b-smoke
SHAPE train_4k
###include_local_kernels###
###include_local_collectives###
SET vocab_size=256
RUN train --steps 10
"""

JOBCONFIG = {
    "job": {"name": "quickstart", "mail": "you@example.org"},
    "deployment": {"nodes": 1, "tasks-per-node": 1, "clocktime": "00:10:00"},
    "execution": [{"serial": {
        "command": "train --steps 10 --seq-len 64 --global-batch 4 "
                   "--arch deepseek-7b-smoke"}}],
}


def main():
    app = parse_appfile(APPFILE)
    app.shape_overrides = {"seq_len": 64, "global_batch": 4}
    spec = parse_jobspec(JOBCONFIG)

    mw, job_id, build = run_easey(app, "local:cpu", spec,
                                  storage=tempfile.mkdtemp(prefix="easey_"))
    print(f"jobID={job_id} state={mw.status(job_id).value}")
    print("--- tuning report -------------------------------------------")
    print(build.plan.report())
    print("--- job stdout ----------------------------------------------")
    out, err = mw.logs(job_id)
    print(out)
    if err:
        print("STDERR:", err)


if __name__ == "__main__":
    main()
