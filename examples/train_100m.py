"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py --steps 300

Uses the full production stack — EASEY build (tuned plan), deterministic
data pipeline, AdamW, atomic async checkpointing, straggler monitor, and
restart-on-failure.  ~100M params (12L, d=768, like GPT-2-small with a
32k vocab) — a few hundred CPU steps take a while; pass --steps 20 for a
quick look.  Add --fail-at 150 to watch the fault-tolerance path resume
from the latest checkpoint.
"""

import argparse
import tempfile

from repro.configs.base import ModelConfig, register
from repro.launch.train import train_main

CFG_100M = ModelConfig(
    name="gpt2s-100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=32768,
    activation="gelu", norm="layernorm", pos="rope",
)
register(CFG_100M, CFG_100M.replace(name="gpt2s-100m-smoke"))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--seq-len", type=int, default=256)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--fail-at", type=int, nargs="*", default=[])
    a = p.parse_args()

    out = train_main(
        arch="gpt2s-100m", steps=a.steps, seq_len=a.seq_len,
        global_batch=a.global_batch,
        ckpt_dir=a.ckpt_dir or tempfile.mkdtemp(prefix="ckpt_100m_"),
        ckpt_every=25, fail_at=tuple(a.fail_at))
    print(f"\ntrained {out['steps']} steps "
          f"({out['restarts']} restarts, {out['stragglers']} stragglers)")
    print(f"loss: {out['first_loss']:.4f} -> {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
