"""AdamW with declarative state tables (dry-run never allocates state).

Two variants, selected by the EASEY AutoTuner from HBM napkin math:

* ``AdamW``      — fp32 moments (paper-faithful default).
* ``AdamW8bit``  — row-wise dynamically quantized int8 moments (m: symmetric
  int8, v: int8 of sqrt(v)).  This is the distributed-optimization trick
  that lets nemotron-4-340b train on 256 x 16 GB chips (fp32 moments alone
  would be 10.6 GB/chip; int8 brings moments to 2.7 GB/chip).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, _map_table


def _tree_map2(f, a, b):
    return jax.tree.map(f, a, b)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# row-wise int8 quantization helpers


def _q8(x):
    """Symmetric row-wise int8. Returns (q int8, scale fp32 over last axis)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    name: str = "adamw"

    # -- declarative state (mirrors the param table) --
    def state_table(self, param_table) -> dict:
        def mom(d: ParamDef) -> dict:
            f32 = dataclasses.replace(d, dtype=jnp.float32, init="zeros")
            return {"m": f32, "v": f32}
        return {"moments": _map_table(param_table, mom),
                "count": ParamDef((), (), jnp.int32, "zeros")}

    def init(self, params) -> dict:
        return {"moments": jax.tree.map(
                    lambda p: {"m": jnp.zeros(p.shape, jnp.float32),
                               "v": jnp.zeros(p.shape, jnp.float32)}, params),
                "count": jnp.zeros((), jnp.int32)}

    def _moment_update(self, g, mom):
        m = self.b1 * mom["m"] + (1 - self.b1) * g
        v = self.b2 * mom["v"] + (1 - self.b2) * jnp.square(g)
        return m, v, {"m": m, "v": v}

    def update(self, grads, state, params, lr):
        count = state["count"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        c1 = 1 - self.b1 ** count.astype(jnp.float32)
        c2 = 1 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, mom):
            g = g.astype(jnp.float32) * scale
            m, v, new_mom = self._moment_update(g, mom)
            mh, vh = m / c1, v / c2
            step = mh / (jnp.sqrt(vh) + self.eps) + \
                self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_mom

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        mom_tree = state["moments"]
        is_mom = lambda x: isinstance(x, dict) and set(x) >= {"m", "v"}
        flat_m = jax.tree.flatten(mom_tree, is_leaf=is_mom)[0]
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_moms = jax.tree.unflatten(
            jax.tree.structure(mom_tree, is_leaf=is_mom), [o[1] for o in out])
        return new_params, {"moments": new_moms, "count": count}, \
            {"grad_norm": gnorm}


@dataclasses.dataclass(frozen=True)
class AdamW8bit(AdamW):
    name: str = "adamw8bit"

    def state_table(self, param_table) -> dict:
        def mom(d: ParamDef) -> dict:
            q = dataclasses.replace(d, dtype=jnp.int8, init="zeros")
            sshape = d.shape[:-1] + (1,) if d.shape else ()
            saxes = d.logical_axes[:-1] + (None,) if d.shape else ()
            s = ParamDef(sshape, saxes, jnp.float32, "zeros")
            return {"m_q": q, "m_s": s, "v_q": q, "v_s": s}
        return {"moments": _map_table(param_table, mom),
                "count": ParamDef((), (), jnp.int32, "zeros")}

    def init(self, params) -> dict:
        def mk(p):
            sshape = p.shape[:-1] + (1,) if p.ndim else ()
            return {"m_q": jnp.zeros(p.shape, jnp.int8),
                    "m_s": jnp.zeros(sshape, jnp.float32),
                    "v_q": jnp.zeros(p.shape, jnp.int8),
                    "v_s": jnp.zeros(sshape, jnp.float32)}
        return {"moments": jax.tree.map(mk, params),
                "count": jnp.zeros((), jnp.int32)}

    def _moment_update(self, g, mom):
        m_prev = _dq8(mom["m_q"], mom["m_s"])
        v_prev = jnp.square(_dq8(mom["v_q"], mom["v_s"]))  # stored sqrt(v)
        m = self.b1 * m_prev + (1 - self.b1) * g
        v = self.b2 * v_prev + (1 - self.b2) * jnp.square(g)
        m_q, m_s = _q8(m)
        r_q, r_s = _q8(jnp.sqrt(v))
        return m, v, {"m_q": m_q, "m_s": m_s, "v_q": r_q, "v_s": r_s}

    def update(self, grads, state, params, lr):
        count = state["count"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        c1 = 1 - self.b1 ** count.astype(jnp.float32)
        c2 = 1 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, mom):
            g = g.astype(jnp.float32) * scale
            m, v, new_mom = self._moment_update(g, mom)
            mh, vh = m / c1, v / c2
            step = mh / (jnp.sqrt(vh) + self.eps) + \
                self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_mom

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        is_mom = lambda x: isinstance(x, dict) and "m_q" in x
        mom_tree = state["moments"]
        flat_m = jax.tree.flatten(mom_tree, is_leaf=is_mom)[0]
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_moms = jax.tree.unflatten(
            jax.tree.structure(mom_tree, is_leaf=is_mom), [o[1] for o in out])
        return new_params, {"moments": new_moms, "count": count}, \
            {"grad_norm": gnorm}


def make_optimizer(name: str, **kw):
    return {"adamw": AdamW, "adamw8bit": AdamW8bit}[name](**kw)
