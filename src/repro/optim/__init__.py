from repro.optim.adamw import AdamW, AdamW8bit, make_optimizer  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
