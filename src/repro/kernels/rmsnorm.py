"""Fused RMSNorm Pallas kernel.

Row-blocked: each grid step loads a (rows x d) tile into VMEM, computes
the fp32 mean-square, rescales and applies the weight — one HBM read and
one write per element instead of the unfused chain (square, mean, rsqrt,
mul, mul) each touching HBM.  d stays tile-resident, so d should be a
multiple of 128 for lane alignment on real TPUs (all assigned archs are).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                   block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """x: (..., d); w: (d,). Fused row-wise RMS normalization."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
