"""Pallas TPU flash-attention kernel (blockwise online softmax, causal, GQA).

TPU adaptation of the standard flash algorithm (DESIGN.md §2): the kv loop
is the innermost GRID dimension (TPU grids execute sequentially per core,
so VMEM scratch carries the running (m, l, acc) statistics across kv
blocks — the TPU analogue of a CUDA thread-block loop), q/k/v blocks are
VMEM tiles shaped to the MXU (block_q x head_dim, head_dim multiples of
128), and the causal mask is applied in-register via broadcasted iotas.

The roofline motivation is measured, not assumed: the dry-run shows the
unfused reference attention moves TB-scale f32 score tensors through HBM
(EXPERIMENTS.md §Roofline); this kernel keeps scores entirely in VMEM.

Validated in interpret mode against kernels/ref.py over a shape/dtype
sweep (tests/test_kernels_flash.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  seq_k: int):
    ib, ih, iq, ik = (pl.program_id(i) for i in range(4))
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_k
    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        mask = mask & (k_pos <= q_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # explicit zero under the mask: a block whose every key is masked
    # before any finite max was seen leaves m_new at NEG_INF, and
    # exp(s - m_new) = exp(NEG_INF - NEG_INF) = 1 for the masked entries
    # — poisoning l/acc.  Unreachable on square causal grids (block 0
    # always holds key 0), live as soon as kv_len < a block's start.
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128, kv_len: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """q: (b, s, H, dh); k/v: (b, t, K, dh), H % K == 0. Returns (b, s, H, dh).

    kv_len: optional valid length of the kv sequence (< t with a padded
    cache); key blocks past it are fully masked.  A row with no valid key
    at all returns zeros.

    interpret=True executes the kernel body on CPU (validation); on a real
    TPU pass interpret=False.
    """
    b, s, H, dh = q.shape
    t, K = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    assert kv_len is None or 0 <= kv_len <= t, (kv_len, t)
    nq, nk = s // block_q, t // block_k
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, seq_k=t if kv_len is None else kv_len)

    return pl.pallas_call(
        kernel,
        grid=(b, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dh),
                         lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda ib, ih, iq, ik: (ib, ik, ih // G, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda ib, ih, iq, ik: (ib, ik, ih // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dh),
                               lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, H, dh), q.dtype),
        scratch_shapes=[
            # VMEM scratch carrying online-softmax state across kv blocks
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
