"""Pallas TPU fused paged-attention decode kernel (page-table walk in-kernel).

The paged serving decode path previously paid a full materialized gather
every tick: K/V were read back *through* the page table into a
(slots, max_pages*page_size, K, dh) tensor before attention — the same
unfused-HBM-traffic failure mode the roofline quantified for prefill
scores, now on the KV stream.  This kernel walks each slot's page-table
row *inside* the kernel instead (the PagedAttention design, vLLM): the
innermost grid dimension streams pages, each page's K/V block DMA'd
straight from the (num_pages, page_size, K, dh) pool via a
scalar-prefetched page-table index map, with the softmax statistics
carried across pages in VMEM scratch — the block/`pl.when` idiom of
kernels/flash_attention.py with the kv grid dimension redirected through
the page table.

Parity contract: the serving engine promises token-identical streams with
the kernel on or off, and the reference path (models/layers.dot_attention
over the gathered KV) rounds its *normalized* probabilities to the
activation dtype (bf16) before the PV contraction.  A single online
pass cannot reproduce that per-element rounding (probabilities are only
normalized at the very end), so the page walk runs in three phases over
the same page stream — max, denominator, then PV with the same
normalize-then-round sequence as the reference:

    phase 0   m   = max_t s_t                    (exact; order-free)
    phase 1   l   = sum_t exp(s_t - m)           (f32, page-sequential)
    phase 2   acc = sum_t round_bf16(exp(s_t - m) / l) * v_t   (f32)

Scratch (m, l, acc) carries across the whole 3 * max_pages walk; pages a
slot does not hold are skipped, so the pool is streamed at ~3x the
slot's *held* bytes — still far below the gather's materialized
worst-case (slots, max_pages*page_size, K, dh) read-plus-write on
heavy-tailed traces (see benchmarks/kernel_bench.py).

Layout/masking contract (mirrors models/layers.py's paged decode arm):

* the grid is (slots, kv_heads, 3 * max_pages); the query block holds
  one slot's G = H // K query heads of one kv head, so GQA rides the
  same ``ih // G``-style index-map trick the flash kernel uses;
* token position ``ip * page_size + j`` is masked at each slot's own
  ``kv_len`` (per-slot lengths — continuous batching);
* page-table entries equal to 0 are the reserved junk page (freed /
  never-grown rows): their blocks are skipped entirely, so a freed
  slot's output is exactly zero rather than an average of dead writes;
* a fully-masked row cannot poison the accumulator: ``p`` is zeroed
  under the mask explicitly (NEG_INF - NEG_INF = 0 would otherwise make
  exp() emit 1 per masked key) and a slot with no live page never
  divides by its zero denominator.

Validated in interpret mode against the gather-then-attend oracle
(kernels/ref.paged_attention_ref) over a page_size x pages-per-slot x
GQA-ratio x per-slot-length sweep (tests/test_kernels_paged.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale: float,
                         page_size: int, max_pages: int):
    """One (slot, kv head, phase*page) grid step of the fused decode attn.

    ``pt_ref``/``len_ref`` are the scalar-prefetched (slots, max_pages)
    page table and (slots,) kv lengths — prefetched so the k/v BlockSpec
    index maps can route each grid step's DMA to ``pt_ref[slot, page]``
    before the body runs.  The innermost grid dimension walks the page
    stream three times (max / denominator / PV — see module docstring);
    VMEM scratch carries (m, l, acc) across the whole walk (innermost is
    sequential on TPU).
    """
    is_, _, it = (pl.program_id(i) for i in range(3))
    ip = it % max_pages
    phase = it // max_pages

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    page = pt_ref[is_, ip]
    kv_len = len_ref[is_]

    # skip junk-page rows (page-table entry 0: freed slots, rows past the
    # slot's held pages) and pages wholly beyond the slot's length — the
    # whole block is masked, so there is nothing to accumulate
    live = (page != 0) & (ip * page_size < kv_len)

    def scores():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, dh)
        k = k_ref[0, :, 0].astype(jnp.float32)       # (page_size, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = ip * page_size + \
            jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        return s, pos < kv_len

    @pl.when(live & (phase == 0))
    def _max_pass():
        s, mask = scores()
        s = jnp.where(mask, s, NEG_INF)
        m_ref[...] = jnp.maximum(m_ref[...], jnp.max(s, axis=-1))

    @pl.when(live & (phase == 1))
    def _sum_pass():
        s, mask = scores()
        # explicit zero under the mask: a row with no live key keeps
        # m = NEG_INF, and exp(s - m) = exp(NEG_INF - NEG_INF) = 1 for
        # the masked entries (the flash-kernel poisoning bug, fixed
        # there too)
        p = jnp.where(mask, jnp.exp(s - m_ref[...][:, None]), 0.0)
        l_ref[...] = l_ref[...] + jnp.sum(p, axis=-1)

    @pl.when(live & (phase == 2))
    def _pv_pass():
        s, mask = scores()
        v = v_ref[0, :, 0]                           # (page_size, dh)
        p = jnp.where(mask, jnp.exp(s - m_ref[...][:, None]), 0.0)
        # normalize THEN round to the value dtype — the reference path's
        # probs.astype(v.dtype) before the PV contraction, reproduced
        # per element so kernel-on streams are token-identical
        p = (p / l_ref[...][:, None]).astype(v.dtype)
        acc_ref[...] = acc_ref[...] + \
            jax.lax.dot_general(p.astype(jnp.float32),
                                v.astype(jnp.float32),
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    @pl.when(it == 3 * max_pages - 1)
    def _finalize():
        # acc is already normalized; a slot with no live page at all
        # (freed / junk-only row) never entered the phases -> exact zero
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


def paged_attention_pallas(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           kv_len: jax.Array, *,
                           interpret: bool = True) -> jax.Array:
    """Fused single-token decode attention over a paged KV pool.

    q: (slots, H, dh) — one new query token per slot;
    k_pages/v_pages: (num_pages, page_size, K, dh) page pool, H % K == 0;
    page_table: (slots, max_pages) int32 — entry 0 is the reserved junk
        page and is masked in-kernel;
    kv_len: (slots,) int32 valid tokens per slot (the new token included).
    Returns (slots, H, dh).

    interpret=True executes the kernel body on CPU (validation); on a
    real TPU pass interpret=False.
    """
    slots, H, dh = q.shape
    _, page_size, K, _ = k_pages.shape
    assert H % K == 0, (H, K)
    G = H // K
    max_pages = page_table.shape[1]
    assert page_table.shape[0] == slots and kv_len.shape == (slots,), \
        (page_table.shape, kv_len.shape, slots)
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(slots, K, G, dh)

    def kv_map(is_, ik, it, pt, kl):
        # the page walk: this slot's (it mod max_pages)-th page, straight
        # from the pool — revisited once per phase
        return (pt[is_, it % max_pages], 0, ik, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # page table + kv lengths
        grid=(slots, K, 3 * max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh),
                         lambda is_, ik, it, pt, kl: (is_, ik, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh), kv_map),
            pl.BlockSpec((1, page_size, 1, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh),
                               lambda is_, ik, it, pt, kl: (is_, ik, 0, 0)),
        scratch_shapes=[
            # VMEM scratch carrying softmax state across the page walk
            pltpu.VMEM((G, dh), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               page_size=page_size, max_pages=max_pages)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, K, G, dh), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(slots, H, dh)
