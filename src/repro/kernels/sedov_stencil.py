"""Pallas TPU kernel for the Sedov hydro step (the LULESH hot loop, §4).

TPU adaptation of LULESH's per-zone update (DESIGN.md §2): the 3-D grid is
blocked along x into VMEM tiles, and the x-halo is assembled from SHIFTED
BLOCK OPERANDS — each field is passed three times with index maps
i-1 / i / i+1 (clamped at the domain edges), so every BlockSpec stays in
standard blocked indexing; no overlapping windows are needed.  y/z
neighbor shifts happen in-register since those axes are tile-resident.

One invocation fuses the whole update chain — EOS, divergence, artificial
viscosity, pressure gradient, momentum, re-divergence, energy, mass —
which the unfused oracle spreads over ~8 HBM round-trips per field.

Exactness: the update at a center row depends on fields up to 3 physical
rows away (q needs div, grad(p+q) needs q, div(v') needs v'), so the
kernel carries a 3-row halo from the neighbor blocks and overrides halo
rows with edge-clamped values at the domain boundary — bitwise-matching
the oracle's reflective boundary (tests/test_kernels_stencil.py).

dt is computed OUTSIDE (global CFL all-reduce on the mesh) and passed as a
scalar operand, matching LULESH's MPI_Allreduce.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.models.lulesh import C_Q, CFL, GAMMA

HALO = 3


def _shift_in(f, axis, d):
    """In-tile neighbor shift with edge clamp (y/z axes are tile-resident)."""
    n = f.shape[axis]
    if d > 0:
        sl = jax.lax.slice_in_dim(f, 1, n, axis=axis)
        edge = jax.lax.slice_in_dim(f, n - 1, n, axis=axis)
        return jnp.concatenate([sl, edge], axis=axis)
    sl = jax.lax.slice_in_dim(f, 0, n - 1, axis=axis)
    edge = jax.lax.slice_in_dim(f, 0, 1, axis=axis)
    return jnp.concatenate([edge, sl], axis=axis)


def _sedov_kernel(dt_ref,
                  rho_l, rho_c, rho_r, e_l, e_c, e_r,
                  vx_l, vx_c, vx_r, vy_l, vy_c, vy_r, vz_l, vz_c, vz_r,
                  rho_o, e_o, vx_o, vy_o, vz_o, *, dx: float, bx: int):
    i = pl.program_id(0)
    nx = pl.num_programs(0)
    dt = dt_ref[0]
    first, last = i == 0, i == nx - 1

    def ext(l_ref, c_ref, r_ref):
        """(bx + 2*HALO, n, n) extended field with boundary clamping."""
        c = c_ref[...]
        lh = jnp.where(first, jnp.broadcast_to(c[:1], (HALO,) + c.shape[1:]),
                       l_ref[...][-HALO:])
        rh = jnp.where(last, jnp.broadcast_to(c[-1:], (HALO,) + c.shape[1:]),
                       r_ref[...][:HALO])
        return jnp.concatenate([lh, c, rh], axis=0)

    rho = ext(rho_l, rho_c, rho_r)
    e = ext(e_l, e_c, e_r)
    vx = ext(vx_l, vx_c, vx_r)
    vy = ext(vy_l, vy_c, vy_r)
    vz = ext(vz_l, vz_c, vz_r)

    def clamp_halo(f):
        """Override halo rows with the edge row at domain boundaries so
        derived quantities (q, v') match the oracle's clamp semantics."""
        lh = jnp.where(first, jnp.broadcast_to(f[HALO:HALO + 1],
                                               (HALO,) + f.shape[1:]),
                       f[:HALO])
        rh = jnp.where(last, jnp.broadcast_to(f[-HALO - 1:-HALO],
                                              (HALO,) + f.shape[1:]),
                       f[-HALO:])
        return jnp.concatenate([lh, f[HALO:-HALO], rh], axis=0)

    def grad_x(f):  # valid on [1 .. L-2]; clamped ends handled by callers
        up = jnp.concatenate([f[1:], f[-1:]], axis=0)
        dn = jnp.concatenate([f[:1], f[:-1]], axis=0)
        return (up - dn) / (2 * dx)

    def grad_y(f):
        return (_shift_in(f, 1, +1) - _shift_in(f, 1, -1)) / (2 * dx)

    def grad_z(f):
        return (_shift_in(f, 2, +1) - _shift_in(f, 2, -1)) / (2 * dx)

    def div(ax, ay, az):
        return grad_x(ax) + grad_y(ay) + grad_z(az)

    rho_inv = 1.0 / jnp.maximum(rho, 1e-12)
    p = (GAMMA - 1.0) * rho * e
    dv = div(vx, vy, vz)
    q = jnp.where(dv < 0, C_Q * rho * dv * dv, 0.0).astype(p.dtype)
    pq = clamp_halo(p + q)

    vx_n = clamp_halo(vx - dt * grad_x(pq) * rho_inv)
    vy_n = clamp_halo(vy - dt * grad_y(pq) * rho_inv)
    vz_n = clamp_halo(vz - dt * grad_z(pq) * rho_inv)
    dv_n = div(vx_n, vy_n, vz_n)

    e_n = jnp.maximum(e - dt * pq * dv_n * rho_inv, 0.0)
    rho_n = jnp.maximum(rho * (1.0 - dt * dv_n), 1e-12)

    c = slice(HALO, HALO + bx)
    rho_o[...] = rho_n[c]
    e_o[...] = e_n[c]
    vx_o[...] = vx_n[c]
    vy_o[...] = vy_n[c]
    vz_o[...] = vz_n[c]


def sedov_step_pallas(state: dict, dt: jax.Array, *, dx: float = 1.0,
                      block_x: int = 16, interpret: bool = True) -> dict:
    """Fused Sedov update given a precomputed dt. Fields are (n, n, n)."""
    rho, e, v = state["rho"], state["e"], state["v"]
    n = rho.shape[0]
    bx = min(block_x, n)
    assert n % bx == 0 and bx >= HALO, (n, bx)
    nblocks = n // bx

    def spec(shift):
        return pl.BlockSpec(
            (bx, n, n),
            lambda i, s=shift: (jnp.clip(i + s, 0, nblocks - 1), 0, 0))

    dt_arr = jnp.reshape(dt.astype(rho.dtype), (1,))
    fields = []
    for f in (rho, e, v[0], v[1], v[2]):
        fields += [f, f, f]  # left / center / right views of the same array

    in_specs = [pl.BlockSpec((1,), lambda i: (0,))]
    for _ in range(5):
        in_specs += [spec(-1), spec(0), spec(+1)]

    out = pl.pallas_call(
        functools.partial(_sedov_kernel, dx=dx, bx=bx),
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((bx, n, n), lambda i: (i, 0, 0))] * 5,
        out_shape=[jax.ShapeDtypeStruct((n, n, n), rho.dtype)] * 5,
        interpret=interpret,
    )(dt_arr, *fields)
    rho_n, e_n, vx_n, vy_n, vz_n = out
    return {"rho": rho_n, "e": e_n,
            "v": jnp.stack([vx_n, vy_n, vz_n]), "t": state["t"] + dt}


def cfl_dt(state: dict, *, dx: float = 1.0):
    """Global CFL reduction (the step's only collective on a real mesh)."""
    rho, e, v = state["rho"], state["e"], state["v"]
    p = (GAMMA - 1.0) * rho * e
    cs = jnp.sqrt(GAMMA * p / jnp.maximum(rho, 1e-12))
    vmag = jnp.sqrt((v * v).sum(0))
    return CFL * dx / jnp.max(cs + vmag + 1e-12)
