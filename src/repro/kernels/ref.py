"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """Plain full-softmax GQA attention in fp32. Shapes as the kernel."""
    b, s, H, dh = q.shape
    t, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(b, s, K, G, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, H, dh).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        page_table: jax.Array, kv_len: jax.Array) -> jax.Array:
    """Gather-then-attend oracle for the fused paged decode kernel.

    Materializes each slot's KV run through the page table — exactly the
    unfused read the kernel eliminates — then runs full-softmax attention
    in fp32 with per-slot length masks.  Freed slots (page-table rows all
    junk page 0 / kv_len 0) return exactly zero, matching the kernel.
    """
    slots, H, dh = q.shape
    _, psize, K, _ = k_pages.shape
    G = H // K
    max_pages = page_table.shape[1]
    t = max_pages * psize
    k_all = jnp.take(k_pages, page_table, axis=0).reshape(slots, t, K, dh)
    v_all = jnp.take(v_pages, page_table, axis=0).reshape(slots, t, K, dh)
    qg = q.reshape(slots, K, G, dh)
    scores = jnp.einsum("skgd,stkd->skgt", qg.astype(jnp.float32),
                        k_all.astype(jnp.float32)) / math.sqrt(dh)
    pos = jnp.arange(t)[None, None, None, :]
    valid = pos < kv_len[:, None, None, None]
    # page-0 entries are the reserved junk page: real tokens never live
    # there, so mask any position routed through it
    live = jnp.repeat(page_table != 0, psize, axis=1)[:, None, None, :]
    mask = valid & live
    scores = jnp.where(mask, scores, -1e30)
    probs = jnp.where(mask, jax.nn.softmax(scores, axis=-1), 0.0)
    out = jnp.einsum("skgt,stkd->skgd", probs, v_all.astype(jnp.float32))
    return out.reshape(slots, H, dh).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def sedov_step_ref(state: dict, mesh=None) -> dict:
    """One oracle hydro step (dt computed inside, as models/lulesh.step)."""
    from repro.models.lulesh import LuleshConfig, step
    cfg = LuleshConfig(grid=state["rho"].shape[0])
    return step(state, cfg, mesh)
