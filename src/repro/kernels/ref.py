"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """Plain full-softmax GQA attention in fp32. Shapes as the kernel."""
    b, s, H, dh = q.shape
    t, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(b, s, K, G, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, H, dh).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def sedov_step_ref(state: dict, mesh=None) -> dict:
    """One oracle hydro step (dt computed inside, as models/lulesh.step)."""
    from repro.models.lulesh import LuleshConfig, step
    cfg = LuleshConfig(grid=state["rho"].shape[0])
    return step(state, cfg, mesh)
