"""Jit'd public wrappers around the Pallas kernels.

`interpret` defaults to True unless running on a real TPU — the EASEY
AutoTuner flips the implementation per target (plan.kernels), which is the
paper's `###includelocalmpi###` mechanism applied to compute libraries.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.sedov_stencil import cfl_dt, sedov_step_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "kv_len",
                                   "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, kv_len: int | None = None,
                    interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, kv_len=kv_len,
                                  interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, page_table, kv_len,
                    interpret: bool | None = None):
    """Fused paged decode attention (see kernels/paged_attention.py).

    q: (slots, H, dh); k_pages/v_pages: (num_pages, page_size, K, dh);
    page_table: (slots, max_pages) int32; kv_len: (slots,) int32.
    """
    interpret = _default_interpret() if interpret is None else interpret
    return paged_attention_pallas(q, k_pages, v_pages, page_table, kv_len,
                                  interpret=interpret)


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return rmsnorm_pallas(x, w, eps=eps, block_rows=block_rows,
                          interpret=interpret)


def sedov_step_kernel(state: dict, cfg, block_x: int = 16,
                      interpret: bool | None = None) -> dict:
    """Fused LULESH step: global CFL reduction + Pallas stencil update."""
    interpret = _default_interpret() if interpret is None else interpret
    dt = cfl_dt(state)
    return sedov_step_pallas(state, dt, block_x=block_x, interpret=interpret)
