"""xlstm-1.3b [ssm] — arXiv:2405.04517.
48 blocks d_model=2048, 4 heads; 7:1 mLSTM:sLSTM mix; sub-quadratic,
so it RUNS the long_500k cell."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="xlstm-1.3b", family="ssm_xlstm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    norm="layernorm", pos="none",
    ssm_heads=4, ssm_expand=2, ssm_head_dim=512,  # qk head dim = d_inner/h/2
    ssm_chunk=256, conv_width=4, slstm_every=8,
    sub_quadratic=True,
)

SMOKE = FULL.replace(
    name="xlstm-1.3b-smoke", num_layers=4, d_model=64, num_heads=2,
    num_kv_heads=2, vocab_size=256, ssm_heads=2, ssm_head_dim=32,
    ssm_chunk=16, slstm_every=2,
)

register(FULL, SMOKE)
