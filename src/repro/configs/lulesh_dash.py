"""lulesh-dash [stencil] — the paper's own evaluated application (§4).

Registered so the EASEY workflow can deploy it exactly like the LM archs;
its shape axis is the grid side + iteration count (paper Listing 1.5:
``/built/lulesh.dash -i 1000 -s 13``), not (seq, batch) — benchmarks/
table1_fom.py sweeps the paper's cube sizes."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="lulesh-dash", family="stencil",
    num_layers=0, d_model=0, num_heads=0, num_kv_heads=0, d_ff=0,
    vocab_size=0, pos="none",
    notes="grid/iters configured per-run (paper: -s 13 -i 1000)",
)

SMOKE = FULL.replace(name="lulesh-dash-smoke")

register(FULL, SMOKE,
         skip_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"))
