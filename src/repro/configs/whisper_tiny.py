"""whisper-tiny [audio enc-dec] — arXiv:2212.04356.
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865; conv frontend stubbed."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="whisper-tiny", family="encdec",
    num_layers=4, num_encoder_layers=4,
    d_model=384, num_heads=6, num_kv_heads=6, d_ff=1536, vocab_size=51865,
    activation="gelu", norm="layernorm", pos="learned", qkv_bias=True,
    tie_embeddings=True, max_position=1 << 20,
    notes="enc-dec; frame embeddings provided by the stub frontend",
)

SMOKE = FULL.replace(
    name="whisper-tiny-smoke", num_layers=2, num_encoder_layers=2,
    d_model=64, num_heads=2, num_kv_heads=2, d_ff=128, vocab_size=256,
    max_position=4096,
)

register(FULL, SMOKE, skip_shapes=("long_500k",))
