"""deepseek-7b [dense] — arXiv:2401.02954 (llama-arch).
30L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=102400."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=102400,
    activation="silu", norm="rmsnorm", pos="rope",
)

SMOKE = FULL.replace(
    name="deepseek-7b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256,
)

register(FULL, SMOKE, skip_shapes=("long_500k",))
