"""llava-next-34b [vlm] — hf:llava-hf (Yi-34B backbone).
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000; anyres patch
frontend stubbed (576 base patches prepended to the token stream)."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=20480, vocab_size=64000,
    activation="silu", norm="rmsnorm", pos="rope", rope_theta=5e6,
    num_patches=576,
)

SMOKE = FULL.replace(
    name="llava-next-34b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, num_patches=16,
)

register(FULL, SMOKE, skip_shapes=("long_500k",))
