"""zamba2-7b [hybrid] — arXiv:2411.15242.
81 Mamba2 layers d_model=3584, ssm_state=64, + ONE shared attention+MLP
block (32H, d_ff=14336) applied every 6 mamba layers.  Sub-quadratic:
runs long_500k with a 4k sliding window on the shared attention."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid_mamba",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    activation="silu", norm="rmsnorm", pos="rope",
    ssm_state=64, ssm_heads=112, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=256, conv_width=4, shared_attn_period=6,
    window=4096, sub_quadratic=True,
)

SMOKE = FULL.replace(
    name="zamba2-7b-smoke", num_layers=5, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256,
    ssm_state=8, ssm_heads=4, ssm_head_dim=32, ssm_chunk=16,
    shared_attn_period=2, window=0,
)

register(FULL, SMOKE)
