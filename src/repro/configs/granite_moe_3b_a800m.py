"""granite-moe-3b-a800m [moe] — hf:ibm-granite (assignment numbers).
32L d_model=1536 24H (GQA kv=8) d_ff=512/expert, MoE 40 experts top-8."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    activation="silu", norm="rmsnorm", pos="rope",
    num_experts=40, experts_per_token=8,
    notes="40 experts do not divide the 16-way model axis: the sharding "
          "fallback keeps experts replicated and TP-shards d_ff (see tuning report)",
)

SMOKE = FULL.replace(
    name="granite-moe-3b-a800m-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=64, vocab_size=256, num_experts=4, experts_per_token=2,
)

register(FULL, SMOKE, skip_shapes=("long_500k",))
