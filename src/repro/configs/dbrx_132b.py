"""dbrx-132b [moe] — hf:databricks/dbrx-base.
40L d_model=6144 48H (GQA kv=8) d_ff=10752/expert, MoE 16 experts top-4."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=10752, vocab_size=100352,
    activation="silu", norm="layernorm", pos="rope", rope_theta=5e5,
    num_experts=16, experts_per_token=4,
)

SMOKE = FULL.replace(
    name="dbrx-132b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=256,
    num_experts=4, experts_per_token=2,
)

register(FULL, SMOKE, skip_shapes=("long_500k",))
