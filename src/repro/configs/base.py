"""Model / shape configuration schema and registry.

A ``ModelConfig`` is the architecture part of an EASEY ``AppSpec``: a
portable, target-agnostic description (the paper's Dockerfile analogue).
Deployment decisions (microbatches, remat, sharding rules, kernel choice)
are *not* stored here — the AutoTuner derives them per target and records
them in a DeploymentPlan, exactly like the paper injects
``###includelocalmpi###`` bricks at build time.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm_xlstm|hybrid_mamba|encdec|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    activation: str = "silu"         # silu|gelu|geglu|sq_relu
    norm: str = "rmsnorm"            # rmsnorm|layernorm
    pos: str = "rope"                # rope|learned|sinusoidal|none
    rope_fraction: float = 1.0
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    causal: bool = True
    max_position: int = 1 << 20
    activation_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- encoder-decoder (whisper) ---
    num_encoder_layers: int = 0
    # --- VLM (llava) ---
    num_patches: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    slstm_every: int = 0             # xlstm: every k-th block is sLSTM
    shared_attn_period: int = 0      # zamba2: shared attn block cadence
    window: int = 0                  # sliding-window attention (0 = full)
    # --- misc ---
    sub_quadratic: bool = False      # eligible for long_500k
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    serve_replicas: int = 1          # serve: engines sharing the HBM budget
    serve_repetitiveness: float = 0.0  # serve: trace n-gram self-overlap in
    #                                    [0, 1] — the tuner's signal for
    #                                    picking plan.serve_spec_k


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCHS: dict[str, dict] = {}


def register(cfg: ModelConfig, smoke: ModelConfig,
             skip_shapes: tuple[str, ...] = ()) -> ModelConfig:
    ARCHS[cfg.name] = {"full": cfg, "smoke": smoke, "skip_shapes": skip_shapes}
    # smoke configs are addressable archs too (runnable examples/drivers)
    ARCHS[smoke.name] = {"full": smoke, "smoke": smoke,
                         "skip_shapes": skip_shapes, "is_smoke": True}
    return cfg


def get_config(arch: str) -> ModelConfig:
    return ARCHS[arch]["full"]


def smoke_config(arch: str) -> ModelConfig:
    return ARCHS[arch]["smoke"]


def list_archs(include_smoke: bool = False) -> list[str]:
    return sorted(a for a, m in ARCHS.items()
                  if include_smoke or not m.get("is_smoke"))


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped cells flagged."""
    out = []
    for arch in list_archs():
        meta = ARCHS[arch]
        if meta["full"].family == "stencil":
            continue  # LULESH has its own shape axis (benchmarks)
        for shape in SHAPES.values():
            skipped = shape.name in meta["skip_shapes"]
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name, skipped))
    return out
