"""nemotron-4-340b [dense] — arXiv:2402.16819.
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000; squared-ReLU."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    head_dim=192, d_ff=73728, vocab_size=256000,
    activation="sq_relu", norm="layernorm", pos="rope",
    rope_fraction=0.5,  # nemotron uses partial rotary
)

SMOKE = FULL.replace(
    name="nemotron-4-340b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=512,
)

register(FULL, SMOKE, skip_shapes=("long_500k",))
