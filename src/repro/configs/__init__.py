"""Architecture registry: one module per assigned architecture."""

from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, ARCHS, register, get_config,
    smoke_config, list_archs, cells,
)

# import for registration side effects
from repro.configs import (  # noqa: F401, E402
    whisper_tiny, mistral_large_123b, nemotron_4_340b, stablelm_1_6b,
    deepseek_7b, xlstm_1_3b, llava_next_34b, granite_moe_3b_a800m,
    dbrx_132b, zamba2_7b, lulesh_dash, picolm,
)
