"""picolm-4 — a 4-token-vocabulary probe model for speculative decoding.

Registered smoke-only (it IS its own smoke config): with a random-init
checkpoint, a full-size vocabulary produces chaotic greedy streams that
no history drafter can predict, but collapsing the vocabulary to 4
tokens makes the greedy continuation settle into short n-gram-
predictable cycles — a deterministic, dependency-free stand-in for
repetitive real text (template fill-in, boilerplate, list continuation).
The serving benchmark's ``paged_spec_{off,on}`` cells decode this arch
over ``repetitive_trace`` to gate accepted-tokens/verify-step > 1 with
bit-identical streams; everything else about the model matches the
``deepseek-7b-smoke`` serving smoke (2 dense layers, d_model 64, GQA
4/4) so the same pools, steps, and kernels exercise unchanged.
"""

from repro.configs.base import ModelConfig, register

SMOKE = ModelConfig(
    name="picolm-4-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=4,
    activation="silu",
    norm="rmsnorm",
    pos="rope",
    notes="4-token-vocab speculative-decoding probe (smoke-only)",
)

# registering the smoke under both roles keeps it out of the full-arch
# dry-run sweeps (is_smoke) while staying addressable as an arch
register(SMOKE, SMOKE, skip_shapes=("train_4k", "prefill_32k",
                                    "decode_32k", "long_500k"))
