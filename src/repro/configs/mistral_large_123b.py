"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407.
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="mistral-large-123b", family="dense",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
    head_dim=128, d_ff=28672, vocab_size=32768,
    activation="silu", norm="rmsnorm", pos="rope", rope_theta=1e6,
)

SMOKE = FULL.replace(
    name="mistral-large-123b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
)

register(FULL, SMOKE, skip_shapes=("long_500k",))
