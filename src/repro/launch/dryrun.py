import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell:
    jax.jit(step).lower(**input_specs).compile()
on the production meshes — 16x16 single pod and 2x16x16 multi-pod — with
512 forced host devices.  Prints memory_analysis / cost_analysis, runs the
while-aware HLO cost model, derives the three roofline terms and dumps one
JSON artifact per cell under artifacts/dryrun/.

Usage:
    python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
    python -m repro.launch.dryrun --all --mesh single
    python -m repro.launch.dryrun --all --mesh multi --skip-existing
"""

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax        # noqa: E402

from repro.analysis import hlo as hlo_mod          # noqa: E402
from repro.analysis.flops import model_flops       # noqa: E402
from repro.analysis.roofline import from_cost      # noqa: E402
from repro.configs import ARCHS, SHAPES, cells, get_config  # noqa: E402
from repro.core.appspec import AppSpec             # noqa: E402
from repro.core.build import BuildService          # noqa: E402
from repro.core.target import get_target           # noqa: E402

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

MESHES = {"single": "lrz:tpu-v5e-pod", "multi": "lrz:tpu-v5e-2pod"}


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell —
    weak-type-correct, shardable, no device allocation."""
    from repro.models.params import shape_structs
    from repro.models.transformer import model_for
    cfg = get_config(arch)
    model = model_for(cfg)
    return shape_structs(model.batch_table(SHAPES[shape_name]))


def run_cell(arch: str, shape_name: str, mesh_key: str,
             overrides: dict | None = None, out_dir: Path = ART,
             tag: str = "") -> dict:
    target = get_target(MESHES[mesh_key])
    app = AppSpec(arch=arch, shape=shape_name)
    svc = BuildService()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_key,
           "target": target.name, "status": "ok", "tag": tag}
    t0 = time.perf_counter()
    try:
        result = svc.build(app, target, overrides=overrides, lower=True)
        rec["lower_s"] = result.timings.get("lower_s")
        t1 = time.perf_counter()
        compiled = result.lowered.compile()
        rec["compile_s"] = time.perf_counter() - t1

        ma = compiled.memory_analysis()
        mem = {k: float(getattr(ma, k, 0) or 0) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")}
        mem["per_chip_total_gb"] = (
            mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]) / 1e9
        rec["memory_analysis"] = mem
        print(f"[{arch} x {shape_name} x {mesh_key}] memory_analysis: "
              f"args={mem['argument_size_in_bytes']/1e9:.2f}GB "
              f"temp={mem['temp_size_in_bytes']/1e9:.2f}GB")

        from repro.analysis.hlo import xla_cost_analysis
        ca = xla_cost_analysis(compiled)
        rec["cost_analysis"] = {"flops": float(ca.get("flops", -1)),
                                "bytes_accessed": float(ca.get("bytes accessed", -1))}
        print(f"  cost_analysis (scan-body-once): flops={rec['cost_analysis']['flops']:.3e}")

        t2 = time.perf_counter()
        text = compiled.as_text()
        cost = hlo_mod.analyze(text, total_devices=target.num_chips)
        rec["hlo_parse_s"] = time.perf_counter() - t2
        mf = model_flops(app.model_config, app.shape_config)
        roof = from_cost(cost, arch=arch, shape=shape_name, mesh=mesh_key,
                         chips=target.num_chips, model_flops=mf["total"],
                         memory_per_chip=mem)
        rec["hlo_cost"] = {
            "flops_per_chip": cost.flops, "hbm_bytes_per_chip": cost.hbm_bytes,
            "wire_bytes_per_chip": cost.wire_bytes,
            "collectives": cost.collective_breakdown,
            "while_trips": cost.while_trips, "dot_count": cost.dot_count}
        rec["model_flops"] = mf
        rec["roofline"] = roof.row()
        rec["plan"] = json.loads(result.plan.to_json())
        rec["fallbacks"] = result.plan.sharding_fallbacks
        print(f"  roofline: compute={roof.t_compute*1e3:.1f}ms "
              f"memory={roof.t_memory*1e3:.1f}ms "
              f"collective={roof.t_collective*1e3:.1f}ms "
              f"-> {roof.bottleneck}-bound, fraction={roof.roofline_fraction:.2f}")
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
        print(f"[{arch} x {shape_name} x {mesh_key}] FAILED: {rec['error']}")
    rec["total_s"] = time.perf_counter() - t0
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out = out_dir / f"{arch}__{shape_name}__{mesh_key}{suffix}.json"
    out.write_text(json.dumps(rec, indent=2, default=str))
    print(f"  wrote {out} ({rec['total_s']:.1f}s total)")
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    p.add_argument("--all", action="store_true")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--tag", default="")
    p.add_argument("--overrides", default="", help="JSON plan overrides")
    a = p.parse_args(argv)

    meshes = ["single", "multi"] if a.mesh == "both" else [a.mesh]
    overrides = json.loads(a.overrides) if a.overrides else None
    todo = []
    if a.all:
        for arch, shape, skipped in cells():
            for mk in meshes:
                todo.append((arch, shape, mk))
    else:
        assert a.arch and a.shape, "--arch/--shape or --all"
        todo = [(a.arch, a.shape, mk) for mk in meshes]

    ok = err = skip = 0
    for arch, shape, mk in todo:
        suffix = f"__{a.tag}" if a.tag else ""
        out = ART / f"{arch}__{shape}__{mk}{suffix}.json"
        if a.skip_existing and out.exists() and \
                json.loads(out.read_text()).get("status") == "ok":
            skip += 1
            continue
        rec = run_cell(arch, shape, mk, overrides=overrides, tag=a.tag)
        ok += rec["status"] == "ok"
        err += rec["status"] != "ok"
    print(f"dry-run summary: {ok} ok, {err} failed, {skip} skipped-existing")
    return 1 if err else 0


if __name__ == "__main__":
    raise SystemExit(main())
