"""Batched serving driver (EASEY RUN command `serve ...`).

Prefill a batch of requests, then decode tokens autoregressively with the
donated KV cache.  Same model code as training; decode O(1)-state paths
for the SSM/hybrid archs.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.appspec import AppSpec
from repro.core.build import BuildService
from repro.core.target import get_target
from repro.models.params import init_params
from repro.models.transformer import model_for
from repro.training.steps import build_decode_step, build_prefill_step


def serve_main(arch: str = "deepseek-7b-smoke", batch: int = 4,
               prefill_len: int = 64, decode_tokens: int = 16,
               target: str = "local:cpu", seed: int = 0, log=print) -> dict:
    app = AppSpec(arch=arch, shape="prefill_32k",
                  shape_overrides={"seq_len": prefill_len,
                                   "global_batch": batch},
                  run=f"serve --decode {decode_tokens}")
    tgt = get_target(target)
    result = BuildService().build(app, tgt, lower=False)
    cfg = app.model_config
    model = model_for(cfg, remat="none")
    mesh = None if tgt.num_chips == 1 else result.mesh

    prefill = jax.jit(build_prefill_step(model, mesh))
    decode = jax.jit(build_decode_step(model, mesh), donate_argnums=(1,))

    rng = jax.random.PRNGKey(seed)
    params = init_params(model.param_table(), rng)
    table = model.batch_table(app.shape_config)
    from repro.data.pipeline import SyntheticSource
    req = SyntheticSource(cfg.vocab_size, seed).batch(table, 0)
    req = jax.tree.map(jnp.asarray, req)

    t0 = time.perf_counter()
    logits, cache = prefill(params, req)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    # grow the self-attention cache to hold decode_tokens more positions
    def grow(path_key, x):
        return x

    if "k" in cache:  # dense-family cache: pad seq axis
        pad = decode_tokens
        for key in ("k", "v"):
            c = cache[key]
            cache[key] = jnp.pad(c, [(0, 0)] * 2 + [(0, pad)] + [(0, 0)] * (c.ndim - 3))
        if "xk" in cache:
            pass  # cross-attention cache length is fixed (encoder side)

    tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    generated = [np.asarray(tokens)]
    t1 = time.perf_counter()
    for _ in range(decode_tokens - 1):
        logits, cache = decode(params, cache, tokens)
        tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        generated.append(np.asarray(tokens))
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t1

    toks = np.concatenate(generated, axis=1)
    out = {
        "arch": arch, "batch": batch, "prefill_len": prefill_len,
        "decode_tokens": decode_tokens,
        "prefill_s": t_prefill, "decode_s": t_decode,
        "decode_tok_per_s": batch * (decode_tokens - 1) / max(t_decode, 1e-9),
        "sample": toks[0][:8].tolist(),
    }
    log(f"[serve] prefill {prefill_len}x{batch} in {t_prefill:.3f}s; "
        f"decode {decode_tokens} tokens: "
        f"{out['decode_tok_per_s']:.1f} tok/s")
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="deepseek-7b-smoke")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prefill", type=int, default=64)
    p.add_argument("--decode", type=int, default=16)
    a = p.parse_args(argv)
    serve_main(arch=a.arch, batch=a.batch, prefill_len=a.prefill,
               decode_tokens=a.decode)


if __name__ == "__main__":
    main()
