"""Serving driver (EASEY RUN command `serve ...`) — thin CLI over the
continuous-batching ServeEngine (repro/serving/).

Dense/MoE families go through the engine: a KV-cache pool sized by the
tuner's serve-mode branch (``--kv-layout contiguous`` reserves
slots x max_len worst cases; ``--kv-layout paged`` buys a page pool with
the same budget and admits by actual tokens), slot-wise decode with
per-request sampling (``--temperature`` / ``--top-k``), and a scheduler
that refills freed slots between steps.  Families without a
slot-indexable attention cache (SSM, hybrid, enc-dec, VLM) keep the
legacy fixed-batch path so `serve --arch xlstm-1.3b-smoke` still works.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.configs.base import get_config

# synthetic request mixes the engine/router paths can serve
TRACES = ("uniform", "zipf", "longprompt", "sharedprefix", "repetitive")


def _make_trace(name: str, n: int, vocab: int, prefill_len: int,
                decode_tokens: int, seed: int, temperature: float,
                top_k: int, top_p: float = 1.0, page_size: int = 0):
    from repro.serving import (longprompt_trace, repetitive_trace,
                               sharedprefix_trace, uniform_trace, zipf_trace)
    kw = dict(max_new=decode_tokens, seed=seed, temperature=temperature,
              top_k=top_k, top_p=top_p)
    if name == "zipf":
        return zipf_trace(n, vocab, max_prompt=prefill_len, **kw)
    if name == "longprompt":
        return longprompt_trace(n, vocab, max_prompt=prefill_len, **kw)
    if name == "repetitive":
        return repetitive_trace(n, vocab, prompt_len=prefill_len, **kw)
    if name == "sharedprefix":
        # head = half the prompt budget, aligned to the pool's REAL page
        # size so the prefix cache has whole pages to reuse (a head
        # aligned to anything else never fully covers a page and the
        # cache silently goes dead); suffixes fill the rest.  A prompt
        # budget too small for an aligned head degrades to an unaligned
        # one — fewer/no hits, but never an over-max_len trace.
        ps = page_size or 16
        head = prefill_len // 2 // ps * ps
        if head < 1:
            head = max(min(ps, prefill_len - 1), 1)
        return sharedprefix_trace(n, vocab, head_len=head,
                                  max_suffix=max(prefill_len - head, 1),
                                  **kw)
    return uniform_trace(n, vocab, prompt_len=prefill_len, **kw)


def _auto_repetitiveness(spec_k, trace, n, vocab, prefill_len,
                         decode_tokens, seed, temperature, top_k, top_p,
                         page_size) -> float:
    """The tuner hint behind ``--spec-k auto`` (``spec_k=None``).

    Measures ``trace_repetitiveness`` on a PREVIEW build of the trace —
    the real trace for the single-engine path (``_make_trace`` is
    deterministic, so the preview and the served trace agree token for
    token).  The one wart: the preview cannot see a tuner-sized pool yet,
    so ``sharedprefix`` head alignment falls back to ``page_size or 16``
    — the tuner's own default page size, so the figures only diverge
    under an explicit nonstandard ``--page-size`` (and repetitiveness is
    a *hint*, not a correctness input: any value yields bit-identical
    streams)."""
    if spec_k is not None:      # explicit k (or 0/off): no hint needed
        return 0.0
    from repro.serving import trace_repetitiveness
    preview = _make_trace(trace, n, vocab, prefill_len, decode_tokens,
                          seed, temperature, top_k, top_p,
                          page_size=page_size or 16)
    return trace_repetitiveness(preview)


def _resolve_slo(slo_ttft: int, slo_e2e: int, plan) -> tuple[int, int]:
    """-1 = adopt the tuner's napkin deadlines (``plan.serve_slo_*``)."""
    if slo_ttft < 0:
        slo_ttft = int(getattr(plan, "serve_slo_ttft_steps", 0))
    if slo_e2e < 0:
        slo_e2e = int(getattr(plan, "serve_slo_e2e_steps", 0))
    return slo_ttft, slo_e2e


def serve_main(arch: str = "deepseek-7b-smoke", batch: int = 4,
               prefill_len: int = 64, decode_tokens: int = 16,
               target: str = "local:cpu", seed: int = 0,
               mode: str = "continuous", requests: int = 0,
               max_len: int = 0, kv_layout: str = "contiguous",
               page_size: int = 0, temperature: float = 0.0,
               top_k: int = 0, top_p: float = 1.0, replicas: int = 1,
               route_policy: str = "least_loaded",
               prefill_chunk: int | None = None,
               prefix_cache: bool = False, kv_kernel: str = "auto",
               spec_k: int | None = 0,
               trace: str = "uniform", arrivals: str = "closed",
               arrival_gap: float = 4.0, slo_ttft: int = 0,
               slo_e2e: int = 0, admission: str = "queue",
               autoscale: int = 0, trace_out: str | None = None,
               metrics_out: str | None = None,
               prom_out: str | None = None, log=print) -> dict:
    """Serve `requests` requests (default: one per slot) of `prefill_len`
    prompts, `decode_tokens` generations each.  Reports per-request latency
    and aggregate tokens/sec.  With ``replicas`` > 1 the requests flow
    through a ``ReplicaRouter`` over N tuner-split engines (``kv_layout``
    may be comma-separated to mix layouts; ``route_policy`` picks the
    balancing rule).  ``prefill_chunk`` sets the prompt-ingestion grain
    (None: the tuner's ``plan.serve_prefill_chunk``; 0: blocking
    full-prompt prefill at admission).  ``prefix_cache`` (paged layout
    only) reuses cached shared-prefix page runs by pointer copy, so
    repeat prefixes skip their re-prefill entirely; pair it with
    ``trace='sharedprefix'`` (Zipf-clustered prompt heads) to see hits —
    the default uniform trace draws unrelated prompts.  ``kv_kernel``
    picks the paged decode attention implementation (auto | gather |
    pallas — see ``--kv-kernel`` help).  ``spec_k`` turns on draft-then-
    verify speculative decoding (k draft tokens per slot per verify step;
    0 = off; None = let the tuner pick from the trace's measured
    repetitiveness — pair with ``trace='repetitive'``); token streams
    are bit-identical with spec on or off.

    Open-loop traffic: ``arrivals`` stamps each request with an
    ``arrival_vstep`` (``poisson``: exponential gaps of mean
    ``arrival_gap`` virtual steps; ``bursty``: sinusoidally rate-
    modulated; ``closed``: everything at t=0, the legacy closed loop).
    ``slo_ttft`` / ``slo_e2e`` are goodput deadlines in VIRTUAL STEPS
    (0 = off; -1 = use the tuner's ``plan.serve_slo_*`` napkin values).
    ``admission='reject'`` (router path) sheds load up front: a request
    whose napkin-predicted TTFT already busts the SLO is rejected with a
    reason instead of queued.  ``autoscale=N`` (router path) lets the
    fleet breathe between N and ``replicas`` serving replicas (grow on
    queue depth / SLO headroom, drain idle replicas to dormant).  Token
    streams stay bit-identical to the closed-loop replay of the same
    trace — arrival timing moves latency, never sampling.

    Telemetry exports (engine and router paths): ``trace_out`` writes a
    Chrome-trace/Perfetto JSON timeline of the whole run (one "process"
    per replica, one "thread" per slot, all timestamps in virtual steps
    — byte-identical across identical runs); ``metrics_out`` writes the
    flat ``to_metrics()`` snapshot as JSON (NaN -> null); ``prom_out``
    writes the same snapshot in Prometheus text exposition format."""
    cfg = get_config(arch)
    if trace not in TRACES:
        raise ValueError(f"trace {trace!r} not in {tuple(TRACES)}")
    from repro.serving import ADMISSION_MODES, ARRIVAL_MODES
    if arrivals not in ARRIVAL_MODES:
        raise ValueError(f"arrivals {arrivals!r} not in {ARRIVAL_MODES}")
    if admission not in ADMISSION_MODES:
        raise ValueError(f"admission {admission!r} not in {ADMISSION_MODES}")
    if replicas == 1 and (admission != "queue" or autoscale):
        raise NotImplementedError(
            "--admission reject and --autoscale need the router path "
            "(--replicas > 1); the single engine always queues")
    if autoscale and not (1 <= autoscale <= replicas):
        raise ValueError(
            f"--autoscale {autoscale} must be in [1, --replicas={replicas}]")
    from repro.serving.engine import SERVABLE_FAMILIES
    if cfg.family not in SERVABLE_FAMILIES:
        if trace_out or metrics_out or prom_out:
            raise NotImplementedError(
                f"--trace-out/--metrics-out/--prom-out need an engine-"
                f"servable family {SERVABLE_FAMILIES}; {arch} "
                f"({cfg.family}) is served by the legacy static path, "
                f"which has no scheduler to trace")
        if replicas > 1:
            raise NotImplementedError(
                f"--replicas needs an engine-servable family "
                f"{SERVABLE_FAMILIES}; {arch} ({cfg.family}) is served by "
                f"the legacy static path")
        return _legacy_serve_main(arch, batch, prefill_len, decode_tokens,
                                  target, seed, log)

    from repro.serving import ServeEngine
    pool_len = max_len or (prefill_len + decode_tokens)
    repetitiveness = _auto_repetitiveness(
        spec_k, trace, requests or batch * replicas, cfg.vocab_size,
        prefill_len, decode_tokens, seed, temperature, top_k, top_p,
        page_size)
    if replicas > 1:
        return _router_serve_main(
            arch=arch, batch=batch, prefill_len=prefill_len,
            decode_tokens=decode_tokens, target=target, seed=seed,
            mode=mode, requests=requests, pool_len=pool_len,
            kv_layout=kv_layout, page_size=page_size,
            temperature=temperature, top_k=top_k, top_p=top_p,
            replicas=replicas,
            route_policy=route_policy, prefill_chunk=prefill_chunk,
            prefix_cache=prefix_cache, kv_kernel=kv_kernel,
            spec_k=spec_k, repetitiveness=repetitiveness, trace=trace,
            arrivals=arrivals, arrival_gap=arrival_gap, slo_ttft=slo_ttft,
            slo_e2e=slo_e2e, admission=admission, autoscale=autoscale,
            trace_out=trace_out, metrics_out=metrics_out,
            prom_out=prom_out, log=log)
    engine = ServeEngine(arch=arch, target=target, num_slots=batch,
                         max_len=pool_len, seed=seed, kv_layout=kv_layout,
                         page_size=page_size, prefill_chunk=prefill_chunk,
                         prefix_cache=prefix_cache, kv_kernel=kv_kernel,
                         spec_k=spec_k, repetitiveness=repetitiveness,
                         log=log)
    n = requests or engine.num_slots
    reqs = _make_trace(trace, n, cfg.vocab_size, prefill_len,
                       decode_tokens, seed, temperature, top_k, top_p,
                       page_size=engine.page_size)
    from repro.serving import with_arrivals
    reqs = with_arrivals(reqs, arrivals, mean_gap=arrival_gap, seed=seed)
    slo_ttft, slo_e2e = _resolve_slo(slo_ttft, slo_e2e, engine.plan)
    tracer = None
    if trace_out:
        from repro.serving import Tracer
        tracer = Tracer()
    stats = engine.run(reqs, policy=mode, slo_ttft_steps=slo_ttft,
                       slo_e2e_steps=slo_e2e, tracer=tracer)
    for r in stats.results:
        log(f"[serve]   req {r.rid}: {r.prompt_len}+{len(r.tokens)} tokens, "
            f"ttft {r.ttft_s*1e3:.1f}ms, latency {r.latency_s*1e3:.1f}ms")
    out = {
        "arch": arch, "batch": engine.num_slots, "prefill_len": prefill_len,
        "decode_tokens": decode_tokens, "mode": mode,
        "kv_layout": kv_layout,
        "kv_kernel": engine.kv_kernel,
        "requests": len(stats.results),
        "decode_steps": stats.decode_steps,
        "occupancy": stats.occupancy,
        "peak_active": stats.peak_active,
        "preemptions": stats.preemptions,
        "prefill_chunks": stats.prefill_chunks,
        "prefill_compiles": stats.prefill_compiles,
        "prefill_queue_peak": stats.prefill_queue_peak,
        "overlap_steps": stats.overlap_steps,
        "mean_ttft_steps": stats.mean_ttft_steps,
        "prefix_hits": stats.prefix_hits,
        "prefix_misses": stats.prefix_misses,
        "prefill_tokens_saved": stats.prefill_tokens_saved,
        "spec_k": engine.spec_k,
        "spec_verify_steps": stats.spec_verify_steps,
        "spec_drafted_tokens": stats.spec_drafted_tokens,
        "spec_accepted_tokens": stats.spec_accepted_tokens,
        "accepted_per_verify": stats.accepted_per_verify,
        "effective_top_k": stats.effective_top_k,
        "arrivals": arrivals,
        "p50_ttft_steps": stats.p50_ttft_steps,
        "p99_ttft_steps": stats.p99_ttft_steps,
        "p50_e2e_steps": stats.p50_e2e_steps,
        "p99_e2e_steps": stats.p99_e2e_steps,
        "goodput_tokens": stats.goodput_tokens,
        "slo_ttft_steps": stats.slo_ttft_steps,
        "slo_e2e_steps": stats.slo_e2e_steps,
        "metrics": stats.to_metrics(),
        "decode_s": stats.wall_s,
        "decode_tok_per_s": stats.tokens_per_s,
        "latency_mean_s": float(np.mean([r.latency_s for r in stats.results])),
        "sample": stats.results[0].tokens[:8],
        "plan": engine.plan,
    }
    log(f"[serve] {kv_layout}:{mode}: {out['decode_tok_per_s']:.1f} tok/s "
        f"aggregate, occupancy {stats.occupancy:.0%}, "
        f"peak {stats.peak_active} in flight")
    _write_telemetry(out["metrics"], tracer, trace_out, metrics_out,
                     prom_out, log)
    return out


def _router_serve_main(arch, batch, prefill_len, decode_tokens, target,
                       seed, mode, requests, pool_len, kv_layout, page_size,
                       temperature, top_k, top_p, replicas, route_policy,
                       prefill_chunk=None, prefix_cache=False,
                       kv_kernel="auto", spec_k=0, repetitiveness=0.0,
                       trace="uniform", arrivals="closed", arrival_gap=4.0,
                       slo_ttft=0, slo_e2e=0, admission="queue",
                       autoscale=0, trace_out=None, metrics_out=None,
                       prom_out=None, log=print) -> dict:
    """Multi-replica path: ReplicaRouter over N tuner-split engines."""
    from repro.serving import AutoscalePolicy, ReplicaRouter, with_arrivals
    cfg = get_config(arch)
    router = ReplicaRouter.build(
        arch=arch, target=target, replicas=replicas, kv_layout=kv_layout,
        num_slots=batch, max_len=pool_len, seed=seed, policy=route_policy,
        page_size=page_size, prefill_chunk=prefill_chunk,
        prefix_cache=prefix_cache, kv_kernel=kv_kernel,
        spec_k=spec_k, repetitiveness=repetitiveness, log=log)
    n = requests or batch * replicas
    reqs = _make_trace(trace, n, cfg.vocab_size, prefill_len,
                       decode_tokens, seed, temperature, top_k, top_p,
                       page_size=max(e.page_size for e in router.engines))
    reqs = with_arrivals(reqs, arrivals, mean_gap=arrival_gap, seed=seed)
    slo_ttft, slo_e2e = _resolve_slo(slo_ttft, slo_e2e,
                                     router.engines[0].plan)
    policy_obj = (AutoscalePolicy(min_replicas=autoscale,
                                  max_replicas=replicas)
                  if autoscale else None)
    tracer = None
    if trace_out:
        from repro.serving import Tracer
        tracer = Tracer()
    stats = router.run(reqs, policy=mode, slo_ttft_steps=slo_ttft,
                       slo_e2e_steps=slo_e2e, admission=admission,
                       autoscale=policy_obj, tracer=tracer)
    for rej in stats.rejected:
        log(f"[serve]   req {rej.rid} REJECTED at v{rej.v_reject}: "
            f"{rej.reason}")
    for r in stats.results:
        log(f"[serve]   req {r.rid} -> replica "
            f"{stats.replica_of[r.rid]}: {r.prompt_len}+{len(r.tokens)} "
            f"tokens, latency {r.latency_s*1e3:.1f}ms")
    out = {
        "arch": arch, "batch": batch, "prefill_len": prefill_len,
        "decode_tokens": decode_tokens, "mode": mode,
        "kv_layout": kv_layout, "replicas": replicas,
        "route_policy": route_policy,
        "requests": len(stats.results),
        "reroutes": stats.reroutes,
        "peak_in_flight": stats.peak_in_flight,
        "imbalance": stats.imbalance,
        "prefill_chunks": stats.prefill_chunks,
        "overlap_steps": stats.overlap_steps,
        "mean_ttft_steps": stats.mean_ttft_steps,
        "prefix_hits": stats.prefix_hits,
        "prefix_misses": stats.prefix_misses,
        "prefill_tokens_saved": stats.prefill_tokens_saved,
        "spec_k": router.engines[0].spec_k,
        "spec_verify_steps": stats.spec_verify_steps,
        "spec_drafted_tokens": stats.spec_drafted_tokens,
        "spec_accepted_tokens": stats.spec_accepted_tokens,
        "accepted_per_verify": stats.accepted_per_verify,
        "effective_top_k": stats.effective_top_k,
        "arrivals": arrivals,
        "admission": admission,
        "autoscale": autoscale,
        "rejected": len(stats.rejected),
        "metrics": stats.to_metrics(),
        "decode_s": stats.wall_s,
        "decode_tok_per_s": stats.tokens_per_s,
        "latency_mean_s": (float(np.mean([r.latency_s
                                          for r in stats.results]))
                           if stats.results else float("nan")),
        "sample": stats.results[0].tokens[:8] if stats.results else [],
        "plan": router.engines[0].plan,
    }
    log(f"[serve] {replicas}x{kv_layout}:{route_policy}:{mode}: "
        f"{out['decode_tok_per_s']:.1f} tok/s fleet, peak "
        f"{stats.peak_in_flight} in flight, imbalance "
        f"{stats.imbalance:.2f}")
    log("[serve] " + stats.summary())
    _write_telemetry(out["metrics"], tracer, trace_out, metrics_out,
                     prom_out, log)
    return out


def _write_telemetry(metrics, tracer, trace_out, metrics_out, prom_out,
                     log=print) -> None:
    """Write the post-run telemetry exports a flag asked for.

    ``metrics`` is a flat ``to_metrics()`` snapshot (its key prefix
    picks the schema); the trace file is pure virtual-step data, so two
    identical runs produce byte-identical files."""
    if not (trace_out or metrics_out or prom_out):
        return
    from repro.serving.telemetry import (ROUTER_SCHEMA, SERVE_SCHEMA,
                                         json_sanitize, prometheus_text,
                                         write_chrome_trace)
    if metrics_out:
        Path(metrics_out).write_text(
            json.dumps(json_sanitize(metrics), indent=2, sort_keys=False)
            + "\n")
        log(f"[serve] wrote metrics snapshot ({len(metrics)} keys) -> "
            f"{metrics_out}")
    if prom_out:
        schema = SERVE_SCHEMA if any(k.startswith("serve_") for k in metrics) \
            else ROUTER_SCHEMA
        Path(prom_out).write_text(prometheus_text(metrics, schema))
        log(f"[serve] wrote Prometheus exposition -> {prom_out}")
    if trace_out and tracer is not None:
        trace = write_chrome_trace(tracer, trace_out)
        log(f"[serve] wrote Chrome trace ({len(trace['traceEvents'])} "
            f"events; load in Perfetto / chrome://tracing) -> {trace_out}")


def _legacy_serve_main(arch: str, batch: int, prefill_len: int,
                       decode_tokens: int, target: str, seed: int,
                       log=print) -> dict:
    """Fixed-batch prefill-all/decode-all (pre-engine behaviour)."""
    import jax
    import jax.numpy as jnp

    from repro.core.appspec import AppSpec
    from repro.core.build import BuildService
    from repro.core.target import get_target
    from repro.models.params import init_params
    from repro.models.transformer import model_for
    from repro.training.steps import build_decode_step, build_prefill_step

    app = AppSpec(arch=arch, shape="prefill_32k",
                  shape_overrides={"seq_len": prefill_len,
                                   "global_batch": batch},
                  run=f"serve --decode {decode_tokens}")
    tgt = get_target(target)
    result = BuildService().build(app, tgt, lower=False)
    cfg = app.model_config
    model = model_for(cfg, remat="none")
    mesh = None if tgt.num_chips == 1 else result.mesh

    prefill = jax.jit(build_prefill_step(model, mesh))
    decode = jax.jit(build_decode_step(model, mesh), donate_argnums=(1,))

    rng = jax.random.PRNGKey(seed)
    params = init_params(model.param_table(), rng)
    table = model.batch_table(app.shape_config)
    from repro.data.pipeline import SyntheticSource
    req = SyntheticSource(cfg.vocab_size, seed).batch(table, 0)
    req = jax.tree.map(jnp.asarray, req)

    t0 = time.perf_counter()
    logits, cache = prefill(params, req)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    if "k" in cache:  # dense-family cache: pad seq axis for decode growth
        pad = decode_tokens
        for key in ("k", "v"):
            c = cache[key]
            cache[key] = jnp.pad(c, [(0, 0)] * 2 + [(0, pad)] +
                                 [(0, 0)] * (c.ndim - 3))

    tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    generated = [np.asarray(tokens)]
    t1 = time.perf_counter()
    for _ in range(decode_tokens - 1):
        logits, cache = decode(params, cache, tokens)
        tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        generated.append(np.asarray(tokens))
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t1

    toks = np.concatenate(generated, axis=1)
    out = {
        "arch": arch, "batch": batch, "prefill_len": prefill_len,
        "decode_tokens": decode_tokens, "mode": "legacy-static",
        "prefill_s": t_prefill, "decode_s": t_decode,
        "decode_tok_per_s": batch * (decode_tokens - 1) / max(t_decode, 1e-9),
        "sample": toks[0][:8].tolist(),
    }
    log(f"[serve] prefill {prefill_len}x{batch} in {t_prefill:.3f}s; "
        f"decode {decode_tokens} tokens: "
        f"{out['decode_tok_per_s']:.1f} tok/s")
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="deepseek-7b-smoke")
    p.add_argument("--batch", type=int, default=4,
                   help="KV pool slots (engine) / batch size (legacy)")
    p.add_argument("--prefill", type=int, default=64)
    p.add_argument("--decode", type=int, default=16)
    p.add_argument("--mode", choices=("continuous", "static"),
                   default="continuous")
    p.add_argument("--requests", type=int, default=0,
                   help="number of requests (default: one per slot)")
    p.add_argument("--max-len", type=int, default=0,
                   help="per-slot KV capacity (default: prefill+decode)")
    p.add_argument("--kv-layout", default="contiguous",
                   help="KV memory layout: contiguous | paged; with "
                        "--replicas a comma-separated mix cycles over "
                        "replicas (e.g. paged,contiguous)")
    p.add_argument("--page-size", type=int, default=0,
                   help="tokens per KV page (paged; default: tuner's)")
    p.add_argument("--kv-kernel", choices=("auto", "gather", "pallas"),
                   default="auto",
                   help="paged decode attention implementation: 'gather' "
                        "reads K/V back through the page table into a "
                        "materialized (slots, max_pages*page_size, heads, "
                        "dim) tensor before attending; 'pallas' runs the "
                        "fused paged-attention kernel that walks the page "
                        "table in-kernel (K/V stream page-by-page, online "
                        "softmax in VMEM scratch) and never materializes "
                        "the gather; 'auto' follows the tuner "
                        "(plan.serve_kv_kernel: pallas targets get the "
                        "kernel).  Token streams are identical either "
                        "way; requires --kv-layout paged")
    p.add_argument("--replicas", type=int, default=1,
                   help="serve through a ReplicaRouter over N tuner-split "
                        "engines (1 = single engine)")
    p.add_argument("--route-policy",
                   choices=("round_robin", "least_loaded", "prefix_affinity"),
                   default="least_loaded",
                   help="replica routing policy (with --replicas > 1)")
    p.add_argument("--prefill-chunk", type=int, default=-1,
                   help="prompt tokens ingested per decode tick (chunked "
                        "prefill); -1 = the tuner's plan.serve_prefill_"
                        "chunk, 0 = blocking full-prompt prefill")
    p.add_argument("--trace", choices=TRACES, default="uniform",
                   help="synthetic request mix: uniform (same-length, "
                        "unrelated prompts), zipf (heavy-tailed), "
                        "longprompt (prefill-stall regime), sharedprefix "
                        "(Zipf-clustered shared prompt heads — the mix "
                        "--prefix-cache hits on), repetitive (short "
                        "cyclic prompts, long greedy generations — the "
                        "mix --spec-k pays off on)")
    p.add_argument("--spec-k", default="0",
                   help="speculative decoding: draft tokens per slot per "
                        "verify step (draft-then-verify; 0 = off, 'auto' "
                        "= let the tuner pick from the trace's measured "
                        "n-gram repetitiveness).  Drafts come from a "
                        "deterministic n-gram scan of each request's own "
                        "history; one jitted verify step scores all k+1 "
                        "positions and the longest accepted prefix lands "
                        "in one burst — token streams are bit-identical "
                        "to --spec-k 0")
    p.add_argument("--prefix-cache", action="store_true",
                   help="reuse shared-prefix KV across requests (paged "
                        "layout only): a per-replica cache maps page-"
                        "aligned prompt prefixes to refcounted page runs, "
                        "so a repeat prefix is admitted by page-table "
                        "pointer copies — no chunk steps, no KV writes — "
                        "and only its cold suffix is prefilled; the LRU "
                        "pin budget comes from the tuner's "
                        "plan.serve_prefix_cache_pages and gives way "
                        "under page pressure before any request is "
                        "preempted; token streams are bit-identical "
                        "with the cache on or off")
    p.add_argument("--arrivals", choices=("closed", "poisson", "bursty"),
                   default="closed",
                   help="open-loop arrival process, stamped in VIRTUAL "
                        "STEPS (the deterministic jitted-invocation "
                        "clock, never wall time): 'closed' submits "
                        "everything at t=0 (legacy closed loop); "
                        "'poisson' draws exponential inter-arrival gaps "
                        "of mean --arrival-gap vsteps; 'bursty' "
                        "sinusoidally rate-modulates the Poisson process "
                        "(diurnal-style peaks and troughs).  The router "
                        "admits a request only once the fleet clock "
                        "reaches its arrival; token streams stay "
                        "bit-identical to the closed-loop replay")
    p.add_argument("--arrival-gap", type=float, default=4.0,
                   help="mean inter-arrival gap in virtual steps "
                        "(--arrivals poisson/bursty)")
    p.add_argument("--slo-ttft", type=int, default=0,
                   help="TTFT goodput deadline in virtual steps: only "
                        "requests whose first token lands within the "
                        "deadline count toward goodput_tokens (0 = off, "
                        "-1 = use the tuner's plan.serve_slo_ttft_steps "
                        "napkin value)")
    p.add_argument("--slo-e2e", type=int, default=0,
                   help="end-to-end goodput deadline in virtual steps "
                        "(0 = off, -1 = use the tuner's "
                        "plan.serve_slo_e2e_steps napkin value)")
    p.add_argument("--admission", choices=("queue", "reject"),
                   default="queue",
                   help="router admission control (--replicas > 1): "
                        "'queue' holds every arrival until a replica "
                        "frees up; 'reject' sheds load up front — a "
                        "request whose napkin-predicted TTFT (waited + "
                        "backlog share + own prefill chunks) already "
                        "busts --slo-ttft is rejected with a reason "
                        "instead of queued (needs an SLO)")
    p.add_argument("--autoscale", type=int, default=0,
                   help="fleet autoscaling (--replicas > 1): N = minimum "
                        "serving replicas; the fleet breathes between N "
                        "and --replicas, growing on queue depth or SLO "
                        "headroom and draining idle replicas (drain = "
                        "stop admitting, finish in-flight, park "
                        "dormant).  0 = off (static fleet)")
    p.add_argument("--trace-out", default=None,
                   help="write a Chrome-trace/Perfetto JSON timeline of "
                        "the run to PATH: one 'process' per replica, one "
                        "'thread' per slot (tid 0 = the queue lane), "
                        "spans for every request lifecycle phase "
                        "(queued, prefill chunks, cache attach, decode, "
                        "spec verify, preempt/resume) and instants for "
                        "fleet events (autoscale, rejections, reclaims). "
                        "All timestamps are virtual steps — identical "
                        "runs produce byte-identical files.  Load via "
                        "https://ui.perfetto.dev or chrome://tracing")
    p.add_argument("--metrics-out", default=None,
                   help="write the flat to_metrics() snapshot as JSON to "
                        "PATH after the run (NaN serialized as null); "
                        "works on the single-engine and router paths")
    p.add_argument("--prom-out", default=None,
                   help="write the metrics snapshot in Prometheus text "
                        "exposition format to PATH after the run")
    p.add_argument("--temperature", type=float, default=0.0,
                   help="sampling temperature (0 = greedy)")
    p.add_argument("--top-k", type=int, default=0,
                   help="top-k sampling filter (0 = off)")
    p.add_argument("--top-p", type=float, default=1.0,
                   help="nucleus sampling: keep the smallest probability "
                        "mass >= p after top-k (1.0 = off)")
    a = p.parse_args(argv)
    spec_k = None if a.spec_k == "auto" else int(a.spec_k)
    serve_main(arch=a.arch, batch=a.batch, prefill_len=a.prefill,
               decode_tokens=a.decode, mode=a.mode, requests=a.requests,
               max_len=a.max_len, kv_layout=a.kv_layout,
               page_size=a.page_size, temperature=a.temperature,
               top_k=a.top_k, top_p=a.top_p, replicas=a.replicas,
               route_policy=a.route_policy,
               prefill_chunk=None if a.prefill_chunk < 0
               else a.prefill_chunk,
               prefix_cache=a.prefix_cache, kv_kernel=a.kv_kernel,
               spec_k=spec_k, trace=a.trace, arrivals=a.arrivals,
               arrival_gap=a.arrival_gap, slo_ttft=a.slo_ttft,
               slo_e2e=a.slo_e2e, admission=a.admission,
               autoscale=a.autoscale, trace_out=a.trace_out,
               metrics_out=a.metrics_out, prom_out=a.prom_out)


if __name__ == "__main__":
    main()
