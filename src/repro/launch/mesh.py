"""Mesh construction for the production targets.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The dry-run driver
(launch/dryrun.py) forces 512 host platform devices *before* importing
anything; everything else (tests, benches) sees the real single CPU
device.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import Mesh

try:  # jax >= 0.6: explicit axis types (Auto matches the old behaviour)
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types kwarg
    AxisType = None


def _mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devs)} are "
            f"available — the dry-run must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"importing jax (see launch/dryrun.py)")
    kw = {} if AxisType is None else \
        {"axis_types": (AxisType.Auto,) * len(axes)}
    return jax.make_mesh(shape, axes, devices=devs[:need], **kw)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def mesh_for_target(target) -> Mesh:
    """Build the mesh a TargetSpec describes (first N devices)."""
    return _mesh(tuple(target.mesh_shape), tuple(target.mesh_axes))


def degraded_mesh(target, *, lost_data_slices: int = 1) -> Mesh:
    """Elastic-scaling mesh: drop `lost_data_slices` rows of the data axis
    (node failure) and rebuild — TP ('model') state needs no resharding."""
    shape = list(target.mesh_shape)
    axes = tuple(target.mesh_axes)
    di = axes.index("data")
    if shape[di] - lost_data_slices < 1:
        raise ValueError("cannot degrade below one data slice")
    shape[di] -= lost_data_slices
    return _mesh(tuple(shape), axes)
