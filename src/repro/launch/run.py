"""Command dispatcher for EASEY execution specs (paper §3: execution
commands are 'bash (serial) or mpi-based'; ours are train/serve/lulesh)."""

from __future__ import annotations

import shlex
from pathlib import Path


def run_command(command: str, job=None, workdir: Path | None = None,
                spec=None, build_result=None):
    log = job.log if job is not None else print
    argv = shlex.split(command)
    # strip ch-run wrappers if a paper-style command was given
    if argv and argv[0] == "ch-run":
        # ch-run -b src:dst image -- cmd args...
        if "--" in argv:
            argv = argv[argv.index("--") + 1:]
    name = Path(argv[0]).name if argv else ""

    if name.startswith("train"):
        from repro.launch.train import train_main
        kw = _parse_kw(argv[1:])
        ckpt = kw.get("ckpt-dir")
        if ckpt is None and workdir is not None:
            ckpt = str(workdir / "ckpt")
        return train_main(
            arch=kw.get("arch", _arch_from(build_result, "deepseek-7b-smoke")),
            steps=int(kw.get("steps", 10)),
            seq_len=int(kw.get("seq-len", 64)),
            global_batch=int(kw.get("global-batch", 4)),
            ckpt_dir=ckpt, ckpt_every=int(kw.get("ckpt-every", 5)),
            log=log)
    if name.startswith("serve"):
        from repro.launch.serve import serve_main
        kw = _parse_kw(argv[1:])
        return serve_main(
            arch=kw.get("arch", _arch_from(build_result, "deepseek-7b-smoke")),
            batch=int(kw.get("batch", 4)),
            prefill_len=int(kw.get("prefill", 64)),
            decode_tokens=int(kw.get("decode", 8)),
            mode=kw.get("mode", "continuous"),
            requests=int(kw.get("requests", 0)),
            max_len=int(kw.get("max-len", 0)),
            kv_layout=kw.get("kv-layout", "contiguous"),
            page_size=int(kw.get("page-size", 0)),
            temperature=float(kw.get("temperature", 0.0)),
            top_k=int(kw.get("top-k", 0)),
            replicas=int(kw.get("replicas", 1)),
            route_policy=kw.get("route-policy", "least_loaded"),
            prefix_cache=str(kw.get("prefix-cache", "")).lower()
            in ("true", "1", "yes"),
            trace=kw.get("trace", "uniform"), log=log)
    if "lulesh" in name:
        import time
        from repro.models import lulesh
        kw = _parse_kw(argv[1:])
        iters = int(kw.get("i", kw.get("iters", 10)))
        size = int(kw.get("s", kw.get("size", 16)))
        cfg = lulesh.LuleshConfig(grid=size, iters=iters)
        state = lulesh.init_state(cfg)
        t0 = time.perf_counter()
        state = lulesh.run(state, cfg, iters)
        state["e"].block_until_ready()
        dt = time.perf_counter() - t0
        f = lulesh.fom(size ** 3, iters, dt)
        log(f"[lulesh] grid={size}^3 iters={iters} time={dt:.3f}s FOM={f:,.0f}")
        return {"fom": f, "seconds": dt, "grid": size, "iters": iters}
    raise ValueError(f"unknown EASEY command: {command!r}")


def _parse_kw(argv: list[str]) -> dict:
    kw, i = {}, 0
    while i < len(argv):
        a = argv[i]
        if a.startswith("--"):
            key = a[2:]
            if i + 1 < len(argv) and not argv[i + 1].startswith("--"):
                kw[key] = argv[i + 1]
                i += 2
            else:
                kw[key] = "true"
                i += 1
        elif a.startswith("-") and len(a) == 2:
            kw[a[1:]] = argv[i + 1] if i + 1 < len(argv) else "true"
            i += 2
        else:
            i += 1
    return kw


def _arch_from(build_result, default):
    if build_result is not None:
        return build_result.appspec.arch
    return default
