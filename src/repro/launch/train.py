"""End-to-end training driver (EASEY RUN command `train ...`).

Wires every substrate together: BuildService (tuned, jitted step) ->
DataPipeline (deterministic, restart-safe) -> Checkpointer (atomic, async)
-> fault tolerance (failure injection + restart policy + straggler
monitor).  Runnable on the CPU debug target with smoke archs; the exact
same code path lowers for the production meshes.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core.appspec import AppSpec
from repro.core.build import BuildService
from repro.core.target import get_target
from repro.data.pipeline import DataPipeline
from repro.models.transformer import model_for
from repro.runtime.fault_tolerance import (FailureInjector, StragglerMonitor,
                                           run_with_restarts)
from repro.training.steps import init_train_state


def train_main(arch: str = "deepseek-7b-smoke", steps: int = 20,
               target: str = "local:cpu", seq_len: int = 64,
               global_batch: int = 4, ckpt_dir: str | None = None,
               ckpt_every: int = 5, async_ckpt: bool = True,
               fail_at: tuple[int, ...] = (), resume: bool = True,
               log=print, seed: int = 0) -> dict:
    app = AppSpec(arch=arch, shape="train_4k",
                  shape_overrides={"seq_len": seq_len,
                                   "global_batch": global_batch},
                  run=f"train --steps {steps}")
    tgt = get_target(target)
    svc = BuildService()
    result = svc.build(app, tgt, lower=False)
    model = model_for(app.model_config, remat=result.plan.remat_policy)
    from repro.optim import make_optimizer
    opt = make_optimizer(result.plan.optimizer)

    jit_step = jax.jit(result.step_fn, donate_argnums=(0,))
    pipeline = DataPipeline(model, app.shape_config, seed=seed,
                            mesh=None if tgt.num_chips == 1 else result.mesh)
    ckpt = Checkpointer(ckpt_dir, keep=3, async_writes=async_ckpt) \
        if ckpt_dir else None
    injector = FailureInjector(fail_at_steps=tuple(fail_at))
    straggler = StragglerMonitor()

    rng = jax.random.PRNGKey(seed)
    losses: dict[int, float] = {}

    def loop(start_step: int) -> int:
        from repro.models.params import init_params
        params = init_params(result.tables["params"], rng)
        state = init_train_state(model, opt, params, result.plan)
        if ckpt and start_step > 0:
            state, at = ckpt.restore(state)
            log(f"[train] restored checkpoint step {at}")
        step = start_step
        while step < steps:
            injector.check(step)
            batch = pipeline.batch_at(step)
            t0 = time.perf_counter()
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if straggler.observe(step, dt):
                log(f"[train] step {step}: straggler ({dt:.3f}s)")
            losses[step] = loss
            if step % max(steps // 10, 1) == 0:
                log(f"[train] step {step} loss={loss:.4f} "
                    f"({dt*1e3:.1f} ms)")
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step, state)
            step += 1
        if ckpt:
            ckpt.save(steps - 1, state)
            ckpt.wait()
        return step

    if resume and ckpt:
        stats = run_with_restarts(loop, checkpointer=ckpt, logger=log)
    else:
        stats = {"final_step": loop(0), "restarts": 0}

    loss_curve = [losses[s] for s in sorted(losses)]
    return {
        "arch": arch, "steps": stats["final_step"],
        "restarts": stats["restarts"],
        "first_loss": loss_curve[0] if loss_curve else float("nan"),
        "final_loss": loss_curve[-1] if loss_curve else float("nan"),
        "stragglers": len(straggler.flagged),
        "plan": result.plan,
    }


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="deepseek-7b-smoke")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--target", default="local:cpu")
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--global-batch", type=int, default=4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=5)
    p.add_argument("--fail-at", type=int, nargs="*", default=[])
    a = p.parse_args(argv)
    out = train_main(arch=a.arch, steps=a.steps, target=a.target,
                     seq_len=a.seq_len, global_batch=a.global_batch,
                     ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every,
                     fail_at=tuple(a.fail_at))
    print(f"final: loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
          f"in {out['steps']} steps ({out['restarts']} restarts)")


if __name__ == "__main__":
    main()
