"""Mamba2 (SSD) blocks + Zamba2-style hybrid backbone — arch `zamba2-7b`.

Zamba2 = a stack of Mamba2 blocks with a **shared** transformer block
(attention + MLP, one set of weights) applied every `shared_attn_period`
Mamba layers.  Training/prefill use the chunkwise SSD algorithm (scan over
chunks, quadratic only within a chunk); decode is the O(1)-state
recurrence.  At 500k context the shared attention block uses its sliding
window (cfg.window) so the whole model stays sub-quadratic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import ParamDef, init_params
from repro.models.ssm import causal_conv
from repro.models.transformer import BaseLM, stack_defs, remat_wrap
from repro.sharding.rules import shard_constraint

# ---------------------------------------------------------------------------
# SSD (state-space duality) core, chunkwise.


def _segsum(x):
    """x: (..., q). Returns (..., q, q) with S[i,j] = sum_{j<t<=i} x_t (i>=j)."""
    q = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    s = c[..., :, None] - c[..., None, :]
    return jnp.where(jnp.tril(jnp.ones((q, q), bool)), s, -jnp.inf)


def ssd_chunkwise(x, dt, A, B, C, D, state, chunk: int):
    """Chunkwise SSD.

    x: (b, l, h, p)   inputs per head
    dt: (b, l, h)     positive step sizes (after softplus+bias)
    A: (h,)           negative decay rates (=-exp(A_log))
    B, C: (b, l, n)   shared across heads (single group)
    D: (h,)           skip connection
    state: (b, h, p, n) or None
    Returns (y (b,l,h,p), final_state).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    if l % chunk:  # ragged tail -> recurrence (exact)
        cut = (l // chunk) * chunk
        if cut == 0:
            return ssd_recurrent_ref(x, dt, A, B, C, D, state)
        y0, state = ssd_chunkwise(x[:, :cut], dt[:, :cut], A, B[:, :cut],
                                  C[:, :cut], D, state, chunk)
        y1, state = ssd_recurrent_ref(x[:, cut:], dt[:, cut:], A, B[:, cut:],
                                      C[:, cut:], D, state)
        return jnp.concatenate([y0, y1], axis=1), state
    nc = l // chunk
    dA = dt * A[None, None, :]                       # (b, l, h) negative

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    dAc = dA.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)   # (b,h,nc,q)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    if state is None:
        state = jnp.zeros((b, h, p, n), jnp.float32)

    def body(S, xs):
        xi, dti, dAi, Bi, Ci = xs       # xi (b,q,h,p), dAi (b,h,q), B/C (b,q,n)
        a = jnp.cumsum(dAi, axis=-1)                              # (b,h,q) inclusive
        Lmat = jnp.exp(_segsum(dAi))                              # (b,h,q,q)
        CB = jnp.einsum("bin,bjn->bij", Ci, Bi)                   # (b,q,q)
        y_diag = jnp.einsum("bij,bhij,bjh,bjhp->bihp", CB, Lmat, dti, xi)
        # inter-chunk: contribution of incoming state
        decay_in = jnp.exp(a)                                     # (b,h,q)
        y_off = jnp.einsum("bin,bhpn,bhi->bihp", Ci, S, decay_in)
        # state update: decay from position j to end of chunk
        decay_out = jnp.exp(a[..., -1:] - a)                      # (b,h,q)
        S_new = jnp.exp(a[..., -1])[..., None, None] * S + \
            jnp.einsum("bjn,bhj,bjh,bjhp->bhpn", Bi, decay_out, dti, xi)
        return S_new, y_diag + y_off

    xs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          dAc.transpose(2, 0, 1, 3), Bc.transpose(1, 0, 2, 3),
          Cc.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(body, state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, h, p)
    return y + x * D[None, None, :, None], state


def ssd_decode(x, dt, A, B, C, D, state):
    """Single-step recurrence. x: (b,1,h,p); B,C: (b,1,n); state (b,h,p,n)."""
    dA = jnp.exp(dt[:, 0, :, None, None] * A[None, :, None, None])  # (b,h,1,1)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], B[:, 0], x[:, 0])
    state = dA * state + dBx
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0], state)[:, None]
    return y + x * D[None, None, :, None], state


def ssd_recurrent_ref(x, dt, A, B, C, D, state):
    """Step-by-step oracle for tests."""
    b, l, h, p = x.shape
    if state is None:
        state = jnp.zeros((b, h, p, B.shape[-1]), jnp.float32)
    ys = []
    for t in range(l):
        y, state = ssd_decode(x[:, t:t + 1], dt[:, t:t + 1], A,
                              B[:, t:t + 1], C[:, t:t + 1], D, state)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), state


# ---------------------------------------------------------------------------
# Mamba2 block


def mamba_block_defs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h, n = cfg.ssm_heads, cfg.ssm_state
    conv_dim = di + 2 * n
    return {
        "ln": L.norm_defs(d, cfg.norm),
        "w_in": ParamDef((d, 2 * di + 2 * n + h), ("embed", "mlp")),
        "conv_w": ParamDef((cfg.conv_width, conv_dim), ("conv", "mlp")),
        "A_log": ParamDef((h,), ("heads",), jnp.float32, "zeros"),
        "D": ParamDef((h,), ("heads",), jnp.float32, "ones"),
        "dt_bias": ParamDef((h,), ("heads",), jnp.float32, "zeros"),
        "gn": ParamDef((di,), ("mlp",), init="ones"),
        "w_out": ParamDef((di, d), ("mlp", "embed")),
    }


def mamba_block_apply(p, x, cfg, mesh, mode, cache, chunk):
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    h, n = cfg.ssm_heads, cfg.ssm_state
    hp = di // h
    res = x
    xin = L.apply_norm(p["ln"], x, cfg.norm)
    zxbcdt = jnp.einsum("bsd,de->bse", xin, p["w_in"])
    z, xbc, dt_pre = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    conv_state = cache.get("conv") if cache else None
    xbc, new_conv = causal_conv(xbc, p["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs, B, C = jnp.split(xbc, [di, di + n], axis=-1)
    xs = xs.reshape(b, s, h, hp)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ssm_state = cache["ssm"] if cache else None
    if mode == "decode" and s == 1:
        y, new_state = ssd_decode(xs.astype(jnp.float32), dt, A,
                                  B.astype(jnp.float32), C.astype(jnp.float32),
                                  p["D"], ssm_state)
    else:
        y, new_state = ssd_chunkwise(xs.astype(jnp.float32), dt, A,
                                     B.astype(jnp.float32), C.astype(jnp.float32),
                                     p["D"], ssm_state, min(chunk, s))
    y = y.reshape(b, s, di)
    # gated RMSNorm (mamba2 style): norm(y * silu(z))
    y = L.rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["gn"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    out = shard_constraint(out, ("act_batch", "act_seq", "act_embed"), mesh)
    new_cache = {"ssm": new_state, "conv": new_conv}
    return res + out, new_cache


# ---------------------------------------------------------------------------
# Zamba2 hybrid


class ZambaHybrid(BaseLM):
    """`num_layers` Mamba2 blocks; one SHARED attention+MLP block applied
    after every `shared_attn_period`-th mamba layer."""

    def _layout(self):
        cfg = self.cfg
        per = cfg.shared_attn_period
        segs = cfg.num_layers // per          # full segments, then remainder
        rem = cfg.num_layers - segs * per
        return per, segs, rem

    def shared_block_defs(self) -> dict:
        cfg = self.cfg
        return {"ln1": L.norm_defs(cfg.d_model, cfg.norm),
                "attn": L.attention_defs(cfg),
                "ln2": L.norm_defs(cfg.d_model, cfg.norm),
                "mlp": L.mlp_defs(cfg)}

    def param_table(self) -> dict:
        cfg = self.cfg
        per, segs, rem = self._layout()
        t = {
            "embed": L.embed_defs(cfg),
            "mamba": stack_defs(stack_defs(mamba_block_defs(cfg), per), segs),
            "shared": self.shared_block_defs(),   # ONE copy, reused `segs` times
            "ln_f": L.norm_defs(cfg.d_model, cfg.norm),
        }
        if rem:
            t["mamba_tail"] = stack_defs(mamba_block_defs(cfg), rem)
        return t

    def cache_table(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        per, segs, rem = self._layout()
        di = cfg.ssm_expand * cfg.d_model
        h, n = cfg.ssm_heads, cfg.ssm_state
        hp = di // h
        conv_dim = di + 2 * n
        kv_len = min(max_len, cfg.window) if cfg.window else max_len

        def m_def(lead, shape, axes, dtype=jnp.float32):
            return ParamDef(lead + shape, ("layers",) * len(lead) + axes,
                            dtype, "zeros")

        t = {
            "mamba": {
                "ssm": m_def((segs, per), (batch, h, hp, n),
                             ("act_batch", "act_heads", None, None)),
                "conv": m_def((segs, per), (batch, cfg.conv_width - 1, conv_dim),
                              ("act_batch", None, "act_mlp"), cfg.activation_dtype),
            },
            # per-invocation KV cache for the shared block (weights shared,
            # cache not!)
            "shared_kv": {
                "k": m_def((segs,), (batch, kv_len, cfg.num_kv_heads, cfg.head_dim),
                           ("act_batch", "act_seq", "act_kv_heads", None),
                           cfg.activation_dtype),
                "v": m_def((segs,), (batch, kv_len, cfg.num_kv_heads, cfg.head_dim),
                           ("act_batch", "act_seq", "act_kv_heads", None),
                           cfg.activation_dtype),
            },
            "index": ParamDef((), (), jnp.int32, "zeros"),
        }
        if rem:
            t["mamba_tail"] = {
                "ssm": m_def((rem,), (batch, h, hp, n),
                             ("act_batch", "act_heads", None, None)),
                "conv": m_def((rem,), (batch, cfg.conv_width - 1, conv_dim),
                              ("act_batch", None, "act_mlp"), cfg.activation_dtype),
            }
        return t

    def shared_block_apply(self, p, x, mesh, positions, mode, kv_cache):
        cfg = self.cfg
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        attn_out, new_kv = L.attention(
            p["attn"], h, cfg, mesh, positions=positions, mode=mode,
            cache=kv_cache, window=cfg.window or None)
        x = x + attn_out
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        return x + L.mlp(p["mlp"], h, cfg, mesh), new_kv

    def backbone(self, params, x, positions, mesh, mode, cache=None):
        cfg = self.cfg
        per, segs, rem = self._layout()
        chunk = cfg.ssm_chunk
        use_cache = cache is not None
        if not use_cache:
            zeros = init_params(self.cache_table(x.shape[0], 0), jax.random.PRNGKey(0))
            mamba_c = zeros["mamba"]
            tail_c = zeros.get("mamba_tail")
        else:
            mamba_c = cache["mamba"]
            tail_c = cache.get("mamba_tail")

        def mamba_scan(y, mp, mc):
            def body(carry, xs):
                bp, c = xs
                out, nc = mamba_block_apply(bp, carry, cfg, mesh, mode, c, chunk)
                return out, nc
            fn = remat_wrap(body, self.remat) if mode == "full" else body
            return jax.lax.scan(fn, y, (mp, mc))

        def seg_body(carry, xs):
            y = carry
            mp, mc, kvk, kvv = xs
            y, new_mc = mamba_scan(y, mp, mc)
            kv = None
            if mode == "decode":
                kv = {"k": kvk, "v": kvv, "index": cache["index"]}
            y, new_kv = self.shared_block_apply(params["shared"], y, mesh,
                                                positions, mode, kv)
            if new_kv is None:
                new_kv = {"k": kvk, "v": kvv}
            return y, (new_mc, new_kv["k"], new_kv["v"])

        per_seg_kv = (cache["shared_kv"]["k"], cache["shared_kv"]["v"]) if use_cache \
            else (jnp.zeros((segs, 0)), jnp.zeros((segs, 0)))
        if not use_cache:
            # prefill/full without prior cache: shared block runs mode='full'
            # or 'prefill'; KV collected via ys when prefill
            def seg_body_nc(carry, xs):
                y = carry
                mp, mc = xs
                y, new_mc = mamba_scan(y, mp, mc)
                y, new_kv = self.shared_block_apply(params["shared"], y, mesh,
                                                    positions, mode, None)
                ys = (new_mc,) + ((new_kv["k"], new_kv["v"]) if new_kv else ())
                return y, ys

            x, ys = jax.lax.scan(seg_body_nc, x, (params["mamba"], mamba_c))
            new_mamba = ys[0]
            new_kv = {"k": ys[1], "v": ys[2]} if mode == "prefill" else None
        else:
            x, (new_mamba, nk, nv) = jax.lax.scan(
                seg_body, x, (params["mamba"], mamba_c) + per_seg_kv)
            new_kv = {"k": nk, "v": nv}

        new_tail = None
        if rem:
            def tail_body(carry, xs):
                bp, c = xs
                out, nc = mamba_block_apply(bp, carry, cfg, mesh, mode, c, chunk)
                return out, nc
            fn = remat_wrap(tail_body, self.remat) if mode == "full" else tail_body
            x, new_tail = jax.lax.scan(fn, x, (params["mamba_tail"], tail_c))

        if mode == "full":
            return x, None
        new_cache = {"mamba": new_mamba, "shared_kv": new_kv,
                     "index": (cache["index"] if use_cache
                               else jnp.asarray(0, jnp.int32)) + x.shape[1]}
        if rem:
            new_cache["mamba_tail"] = new_tail
        return x, new_cache

    # ---- entry points (same pattern as DenseLM) ----
    def loss(self, params, batch, mesh):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = L.embed(params["embed"], batch["tokens"], cfg, mesh, positions=positions)
        x, _ = self.backbone(params, x, positions, mesh, "full")
        x = L.apply_norm(params["ln_f"], x, cfg.norm)
        logits = L.unembed(params["embed"], x, cfg, mesh)
        loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
        return loss, {"loss": loss}

    def prefill(self, params, batch, mesh):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = L.embed(params["embed"], batch["tokens"], cfg, mesh, positions=positions)
        x, cache = self.backbone(params, x, positions, mesh, "prefill")
        x = L.apply_norm(params["ln_f"], x[:, -1:], cfg.norm)
        return L.unembed(params["embed"], x, cfg, mesh), cache

    def decode_step(self, params, cache, tokens, mesh):
        cfg = self.cfg
        b, s = tokens.shape
        positions = cache["index"] + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s))
        x = L.embed(params["embed"], tokens, cfg, mesh, positions=positions)
        x, cache = self.backbone(params, x, positions, mesh, "decode", cache)
        x = L.apply_norm(params["ln_f"], x, cfg.norm)
        return L.unembed(params["embed"], x, cfg, mesh), cache
