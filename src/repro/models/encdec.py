"""Whisper-style encoder-decoder — arch `whisper-tiny`.

The audio conv frontend is a STUB per the assignment: ``batch_table`` takes
precomputed frame embeddings (b, s, d_model).  The encoder is non-causal
self-attention; the decoder is causal with cross-attention onto the encoder
output.  Decode shapes cache both self-attention KV and the cross-attention
KV (computed once at prefill from the encoder output).

Note: the assigned 32k/500k-token decode contexts far exceed Whisper's real
448-token decoder context; they are exercised as synthetic backbone shapes
(see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.models import layers as L
from repro.models.params import ParamDef
from repro.models.transformer import BaseLM, stack_defs, remat_wrap
from repro.sharding.rules import shard_constraint


class EncDecLM(BaseLM):
    # ---- tables ----
    def enc_block_defs(self):
        cfg = self.cfg
        return {"ln1": L.norm_defs(cfg.d_model, cfg.norm),
                "attn": L.attention_defs(cfg),
                "ln2": L.norm_defs(cfg.d_model, cfg.norm),
                "mlp": L.mlp_defs(cfg)}

    def dec_block_defs(self):
        d = self.enc_block_defs()
        cfg = self.cfg
        d["ln_x"] = L.norm_defs(cfg.d_model, cfg.norm)
        d["xattn"] = L.attention_defs(cfg)
        return d

    def param_table(self) -> dict:
        cfg = self.cfg
        return {
            "embed": L.embed_defs(cfg),
            "enc_blocks": stack_defs(self.enc_block_defs(), cfg.num_encoder_layers),
            "enc_ln_f": L.norm_defs(cfg.d_model, cfg.norm),
            "dec_blocks": stack_defs(self.dec_block_defs(), cfg.num_layers),
            "ln_f": L.norm_defs(cfg.d_model, cfg.norm),
        }

    def batch_table(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        frames = ParamDef((b, s, cfg.d_model),
                          ("act_batch", "act_seq", "act_embed"),
                          cfg.activation_dtype, "zeros")
        base = {"frames": frames}
        if shape.kind == "train":
            base["tokens"] = ParamDef((b, s), ("act_batch", "act_seq"), jnp.int32, "zeros")
            base["labels"] = ParamDef((b, s), ("act_batch", "act_seq"), jnp.int32, "zeros")
        elif shape.kind == "prefill":
            base["tokens"] = ParamDef((b, s), ("act_batch", "act_seq"), jnp.int32, "zeros")
        else:  # decode: cross-kv cache already built; no frames input needed
            base = {"tokens": ParamDef((b, 1), ("act_batch", None), jnp.int32, "zeros")}
        return base

    def cache_table(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        kv = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        ax = ("layers", "act_batch", "act_seq", "act_kv_heads", None)
        # cross kv length == encoder length; dry-run uses max_len for both
        return {"k": ParamDef(kv, ax, cfg.activation_dtype, "zeros"),
                "v": ParamDef(kv, ax, cfg.activation_dtype, "zeros"),
                "xk": ParamDef(kv, ax, cfg.activation_dtype, "zeros"),
                "xv": ParamDef(kv, ax, cfg.activation_dtype, "zeros"),
                "index": ParamDef((), (), jnp.int32, "zeros")}

    # ---- encoder ----
    def encode(self, params, frames, mesh):
        cfg = self.cfg
        b, s, _ = frames.shape
        pe = L.sinusoidal_positions(s, cfg.d_model)
        x = frames + pe[None].astype(frames.dtype)
        x = shard_constraint(x, ("act_batch", "act_seq", "act_embed"), mesh)

        def raw(bp, y):
            h = L.apply_norm(bp["ln1"], y, cfg.norm)
            # non-causal self-attention
            saved, cfg_causal = cfg.causal, False
            attn_out, _ = L.attention(
                bp["attn"], h, cfg.replace(causal=False), mesh,
                positions=jnp.zeros((b, s), jnp.int32), mode="full", cache=None)
            y = y + attn_out
            h = L.apply_norm(bp["ln2"], y, cfg.norm)
            return y + L.mlp(bp["mlp"], h, cfg, mesh)

        fn = remat_wrap(raw, self.remat)

        def body(carry, bp):
            return fn(bp, carry), None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.apply_norm(params["enc_ln_f"], x, cfg.norm)

    # ---- decoder block ----
    def dec_block_apply(self, p, x, enc_out, mesh, positions, mode, cache):
        cfg = self.cfg
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        self_cache = None
        if cache is not None:
            self_cache = {"k": cache["k"], "v": cache["v"], "index": cache["index"]}
        attn_out, new_self = L.attention(
            p["attn"], h, cfg, mesh, positions=positions, mode=mode,
            cache=self_cache)
        x = x + attn_out
        h = L.apply_norm(p["ln_x"], x, cfg.norm)
        if mode == "decode":
            # cross-attention against cached encoder KV
            q = jnp.einsum("bse,ehd->bshd", h, p["xattn"]["wq"])
            out = L.dot_attention(q, cache["xk"], cache["xv"], causal=False)
            xo = jnp.einsum("bshd,hde->bse", out, p["xattn"]["wo"])
            new_cross = (cache["xk"], cache["xv"])
        else:
            xo, _ = L.attention(p["xattn"], h, cfg.replace(causal=False), mesh,
                                positions=positions, mode="full",
                                kv_source=enc_out)
            if mode == "prefill":
                xk = jnp.einsum("bte,ekd->btkd", enc_out, p["xattn"]["wk"])
                xv = jnp.einsum("bte,ekd->btkd", enc_out, p["xattn"]["wv"])
                new_cross = (xk, xv)
            else:
                new_cross = None
        x = x + xo
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        x = x + L.mlp(p["mlp"], h, cfg, mesh)
        new_cache = None
        if mode == "prefill":
            new_cache = {"k": new_self["k"], "v": new_self["v"],
                         "xk": new_cross[0], "xv": new_cross[1]}
        elif mode == "decode":
            new_cache = {"k": new_self["k"], "v": new_self["v"],
                         "xk": new_cross[0], "xv": new_cross[1]}
        return x, new_cache

    def decoder(self, params, x, enc_out, positions, mesh, mode, cache=None):
        cfg = self.cfg
        blocks = params["dec_blocks"]
        if mode == "full":
            fn = remat_wrap(
                lambda bp, y: self.dec_block_apply(bp, y, enc_out, mesh,
                                                   positions, "full", None)[0],
                self.remat)

            def body(carry, bp):
                return fn(bp, carry), None
            x, _ = jax.lax.scan(body, x, blocks)
            return x, None

        if mode == "prefill":
            def body_p(carry, bp):
                y, nc = self.dec_block_apply(bp, carry, enc_out, mesh,
                                             positions, "prefill", None)
                return y, nc
            x, caches = jax.lax.scan(body_p, x, blocks)
            caches["index"] = jnp.asarray(x.shape[1], jnp.int32)
            return x, caches

        # decode
        index = cache["index"]

        def body_d(carry, xs):
            bp, ck, cv, cxk, cxv = xs
            y, nc = self.dec_block_apply(
                bp, carry, None, mesh, positions, "decode",
                {"k": ck, "v": cv, "xk": cxk, "xv": cxv, "index": index})
            return y, (nc["k"], nc["v"], nc["xk"], nc["xv"])

        x, (nk, nv, nxk, nxv) = jax.lax.scan(
            body_d, x, (blocks, cache["k"], cache["v"], cache["xk"], cache["xv"]))
        return x, {"k": nk, "v": nv, "xk": nxk, "xv": nxv,
                   "index": index + x.shape[1]}

    # ---- entry points ----
    def _embed_tokens(self, params, tokens, positions, mesh):
        return L.embed(params["embed"], tokens, self.cfg, mesh, positions=positions)

    def loss(self, params, batch, mesh):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        enc_out = self.encode(params, batch["frames"], mesh)
        x = self._embed_tokens(params, batch["tokens"], positions, mesh)
        x, _ = self.decoder(params, x, enc_out, positions, mesh, "full")
        x = L.apply_norm(params["ln_f"], x, cfg.norm)
        logits = L.unembed(params["embed"], x, cfg, mesh)
        loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
        return loss, {"loss": loss}

    def prefill(self, params, batch, mesh):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        enc_out = self.encode(params, batch["frames"], mesh)
        x = self._embed_tokens(params, batch["tokens"], positions, mesh)
        x, cache = self.decoder(params, x, enc_out, positions, mesh, "prefill")
        x = L.apply_norm(params["ln_f"], x[:, -1:], cfg.norm)
        return L.unembed(params["embed"], x, cfg, mesh), cache

    def decode_step(self, params, cache, tokens, mesh):
        cfg = self.cfg
        b, s = tokens.shape
        positions = cache["index"] + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s))
        x = self._embed_tokens(params, tokens, positions, mesh)
        x, cache = self.decoder(params, x, None, positions, mesh, "decode", cache)
        x = L.apply_norm(params["ln_f"], x, cfg.norm)
        return L.unembed(params["embed"], x, cfg, mesh), cache
