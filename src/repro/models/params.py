"""Declarative parameter tables.

Models declare parameters as ``ParamDef`` entries (shape + logical axes +
init law).  From one table the framework derives, without ever allocating
the full tensors:

* ``init_params``      -- materialized weights (smoke tests, examples),
* ``shape_structs``    -- ShapeDtypeStruct tree for the multi-pod dry-run
                          (340B-parameter models never touch device memory),
* ``partition_specs``  -- PartitionSpec tree via the sharding rules engine,
* ``param_count``      -- exact parameter count for roofline MODEL_FLOPS.

This is the mechanism that lets the EASEY BuildService treat a model like
the paper treats a Dockerfile: a portable description that is *compiled
for* a target rather than edited by the user.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import AxisRules, DEFAULT_RULES, logical_to_spec


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float | None = None  # None -> fan-in 1/sqrt(fan_in)

    def __post_init__(self):
        if len(self.shape) != len(self.logical_axes):
            raise ValueError(
                f"shape {self.shape} vs logical axes {self.logical_axes}")

    @property
    def size(self) -> int:
        return math.prod(self.shape)


ParamTable = dict  # nested dict[str, ParamDef | ParamTable]


def _map_table(table: ParamTable, fn: Callable[[ParamDef], Any]):
    out = {}
    for k, v in table.items():
        out[k] = fn(v) if isinstance(v, ParamDef) else _map_table(v, fn)
    return out


def param_count(table: ParamTable) -> int:
    total = 0
    for v in jax.tree.leaves(_map_table(table, lambda d: d.size)):
        total += v
    return total


def init_params(table: ParamTable, rng: jax.Array, dtype=None):
    """Materialize weights. Only used for runnable (small/smoke) configs."""
    leaves, treedef = jax.tree.flatten(
        _map_table(table, lambda d: d), is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, d in zip(keys, leaves):
        dt = dtype or d.dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            if d.scale is not None:
                scale = d.scale
            elif d.init == "embed":
                scale = 1.0
            else:
                fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[-1], 1)
                scale = 1.0 / math.sqrt(fan_in)
            out.append((jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def shape_structs(table: ParamTable, dtype=None):
    return _map_table(
        table, lambda d: jax.ShapeDtypeStruct(d.shape, dtype or d.dtype))


def partition_specs(table: ParamTable, mesh: Mesh,
                    rules: AxisRules | None = None,
                    fallbacks: list[str] | None = None):
    rules = rules or DEFAULT_RULES
    return _map_table(
        table,
        lambda d: NamedSharding(
            mesh, logical_to_spec(d.logical_axes, d.shape, mesh, rules, fallbacks)),
    )


def bytes_of(tree) -> int:
    """Total bytes of a pytree of arrays or ShapeDtypeStructs."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += math.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
    return total


def replicated_specs(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
