"""xLSTM (sLSTM + mLSTM blocks) — arch `xlstm-1.3b`.

mLSTM: matrix-memory cell with exponential input gating.  Training and
prefill use an **exact stabilized chunkwise-parallel form** (derived from
the recurrence; property-tested to match the step-by-step reference in
tests/test_ssm_equivalence.py).  Decode uses the O(1)-state recurrence —
this is why xlstm runs the `long_500k` cell that quadratic-attention archs
must skip.

sLSTM: scalar-memory cell with recurrent (block-diagonal per-head) gate
connections — inherently sequential, implemented with lax.scan over time.
Layout: every `slstm_every`-th block is sLSTM (paper's 7:1 mix).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import ParamDef
from repro.models.transformer import BaseLM, stack_defs, remat_wrap
from repro.sharding.rules import shard_constraint

# ---------------------------------------------------------------------------
# mLSTM cell


def mlstm_recurrent(q, k, v, li, lf, state):
    """Step-by-step reference (also the decode path).

    q,k: (b,h,s,dk); v: (b,h,s,dv); li,lf: (b,h,s) log input/forget gates.
    state: (C (b,h,dv,dk), n (b,h,dk), m (b,h)).  Returns (h (b,h,s,dv), state).
    """
    dk = q.shape[-1]
    qs = q / math.sqrt(dk)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, lit, lft = xs
        m_new = jnp.maximum(lft + m, lit)
        i_p = jnp.exp(lit - m_new)[..., None]
        f_p = jnp.exp(lft + m - m_new)[..., None]
        C = f_p[..., None] * C + i_p[..., None] * (vt[..., :, None] * kt[..., None, :])
        n = f_p * n + i_p * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (qs, k, v.astype(jnp.float32)))
    xs = xs + tuple(jnp.moveaxis(t, 2, 0) for t in (li, lf))
    state, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 2), state


def mlstm_chunkwise(q, k, v, li, lf, state, chunk: int):
    """Exact chunkwise-parallel mLSTM (stabilized). Shapes as above.
    Ragged tails (s % chunk != 0) run through the recurrence."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    if s % chunk:
        cut = (s // chunk) * chunk
        if cut == 0:
            return mlstm_recurrent(q, k, v, li, lf, state)
        y0, state = mlstm_chunkwise(q[:, :, :cut], k[:, :, :cut], v[:, :, :cut],
                                    li[:, :, :cut], lf[:, :, :cut], state, chunk)
        y1, state = mlstm_recurrent(q[:, :, cut:], k[:, :, cut:], v[:, :, cut:],
                                    li[:, :, cut:], lf[:, :, cut:], state)
        return jnp.concatenate([y0, y1], axis=2), state
    n_chunks = s // chunk
    qs = (q / math.sqrt(dk)).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def resh(t, d=None):
        shape = (b, h, n_chunks, chunk) + ((d,) if d else ())
        return t.reshape(shape).transpose(2, 0, 1, 3, *((4,) if d else ()))

    qc, kc, vc = resh(qs, dk), resh(kf, dk), resh(vf, dv)
    lic, lfc = resh(li), resh(lf)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, xs):
        C, n, m_prev = carry                       # (b,h,dv,dk),(b,h,dk),(b,h)
        qi, ki, vi, lii, lfi = xs
        a = jnp.cumsum(lfi, axis=-1)               # (b,h,Q)
        D = a[..., :, None] - a[..., None, :] + lii[..., None, :]
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)
        m_inter = m_prev[..., None] + a
        m_t = jnp.maximum(m_intra, m_inter)        # (b,h,Q)
        W = jnp.exp(D - m_t[..., None])            # masked weights
        qk = jnp.einsum("bhid,bhjd->bhij", qi, ki)
        num = jnp.einsum("bhij,bhjv->bhiv", W * qk, vi)
        inter = jnp.exp(m_inter - m_t)             # (b,h,Q)
        num = num + inter[..., None] * jnp.einsum("bhqk,bhvk->bhqv", qi, C)
        den_i = (W * qk).sum(-1) + inter * jnp.einsum("bhqk,bhk->bhq", qi, n)
        den = jnp.maximum(jnp.abs(den_i), jnp.exp(-m_t))
        hidden = num / den[..., None]
        # state update to end of chunk
        m_new = m_t[..., -1]
        decay = jnp.exp(a[..., -1:] - a + lii - m_new[..., None])  # (b,h,Q)
        C_new = jnp.einsum("bhj,bhjv,bhjk->bhvk", decay, vi, ki) + \
            jnp.exp(m_prev + a[..., -1] - m_new)[..., None, None] * C
        n_new = jnp.einsum("bhj,bhjk->bhk", decay, ki) + \
            jnp.exp(m_prev + a[..., -1] - m_new)[..., None] * n
        return (C_new, n_new, m_new), hidden

    state, hs = jax.lax.scan(body, state, (qc, kc, vc, lic, lfc))
    return hs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dv), state


def mlstm_zero_state(b, h, dk, dv):
    return (jnp.zeros((b, h, dv, dk), jnp.float32),
            jnp.zeros((b, h, dk), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM cell (scalar memory, recurrent gates)


def slstm_scan(gates_x, R, state):
    """gates_x: (b, s, 4, h, dh) pre-activations from the input path.
    R: (4, h, dh, dh) recurrent per-head gate weights.
    state: (c, n, hid, m) each (b, h, dh) except m (b, h).
    """

    def step(carry, gx):
        c, n, hid, m = carry
        rec = jnp.einsum("ghde,bhd->gbhe", R.astype(jnp.float32),
                         hid)                        # (4, b, h, dh)
        gi, gf, gz, go = (gx[:, i].astype(jnp.float32) + rec[i] for i in range(4))
        m_dim = m[..., None]
        lf = -jax.nn.softplus(-gf)                   # log sigmoid
        m_new = jnp.maximum(lf + m_dim, gi)
        i_p = jnp.exp(gi - m_new)
        f_p = jnp.exp(lf + m_dim - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c = f_p * c + i_p * z
        n = f_p * n + i_p
        hid = o * c / jnp.maximum(n, 1.0)
        return (c, n, hid, jnp.max(m_new, axis=-1)), hid

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(gates_x, 1, 0))
    return jnp.moveaxis(hs, 0, 1), state             # (b, s, h, dh)


def slstm_zero_state(b, h, dh):
    return (jnp.zeros((b, h, dh), jnp.float32), jnp.zeros((b, h, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32), jnp.full((b, h), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# causal depthwise conv (width w) with streaming state


def causal_conv(x, w, state=None):
    """x: (b, s, d); w: (width, d). state: (b, width-1, d) trailing inputs.
    Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[width - 1 - i] for i in range(width))
    return y, xp[:, -(width - 1):, :]


# ---------------------------------------------------------------------------
# Blocks


def mlstm_block_defs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.ssm_heads
    dqk, dv = cfg.ssm_head_dim, di // h
    return {
        "ln": L.norm_defs(d, cfg.norm),
        "w_up": ParamDef((d, 2 * di), ("embed", "mlp")),
        "conv_w": ParamDef((cfg.conv_width, di), ("conv", "mlp")),
        # block-diagonal per-head projections (xLSTM paper): each head
        # projects its own di/h slice
        "wq": ParamDef((h, dv, dqk), ("heads", "head_dim", None)),
        "wk": ParamDef((h, dv, dqk), ("heads", "head_dim", None)),
        "wv": ParamDef((h, dv, dv), ("heads", "head_dim", None)),
        "w_if": ParamDef((di, 2, h), ("mlp", None, "heads"), init="zeros"),
        "b_if": ParamDef((2, h), (None, "heads"), init="zeros"),
        "gn": ParamDef((h, dv), ("heads", "head_dim"), init="ones"),
        "w_down": ParamDef((di, d), ("mlp", "embed")),
    }


def slstm_block_defs(cfg) -> dict:
    d = cfg.d_model
    h = cfg.ssm_heads
    dh = d // h
    ff = int(d * 4 / 3 / 64 + 1) * 64
    return {
        "ln": L.norm_defs(d, cfg.norm),
        "wx": ParamDef((d, 4, h, dh), ("embed", None, "heads", "head_dim")),
        "r": ParamDef((4, h, dh, dh), (None, "heads", "head_dim", None),
                      init="normal", scale=0.05),
        "gn": ParamDef((h, dh), ("heads", "head_dim"), init="ones"),
        "ln_ffn": L.norm_defs(d, cfg.norm),
        "ffn_wi": ParamDef((d, ff), ("embed", "mlp")),
        "ffn_wg": ParamDef((d, ff), ("embed", "mlp")),
        "ffn_wo": ParamDef((ff, d), ("mlp", "embed")),
    }


def _groupnorm(x, scale):
    """x: (b, s, h, dv) normalized per head."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return (xf - mu) * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)


def mlstm_block_apply(p, x, cfg, mesh, mode, cache, chunk):
    b, s, d = x.shape
    h = cfg.ssm_heads
    di = cfg.ssm_expand * d
    dv = di // h
    res = x
    xin = L.apply_norm(p["ln"], x, cfg.norm)
    up = jnp.einsum("bsd,de->bse", xin, p["w_up"])
    xb, z = jnp.split(up, 2, axis=-1)
    conv_state = cache.get("conv") if cache else None
    xc, new_conv = causal_conv(xb, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    xch = xc.reshape(b, s, h, dv)   # per-head slices (block-diagonal proj)
    xbh = xb.reshape(b, s, h, dv)
    q = jnp.einsum("bshc,hck->bhsk", xch, p["wq"])
    k = jnp.einsum("bshc,hck->bhsk", xch, p["wk"])
    v = jnp.einsum("bshc,hck->bhsk", xbh, p["wv"])
    gates = jnp.einsum("bsd,dgh->bsgh", xc, p["w_if"]) + p["b_if"].astype(jnp.float32)
    li = gates[:, :, 0].transpose(0, 2, 1).astype(jnp.float32)      # (b,h,s)
    lf = -jax.nn.softplus(-gates[:, :, 1]).transpose(0, 2, 1).astype(jnp.float32)

    if cache is not None:
        state = (cache["C"], cache["n"], cache["m"])
    else:
        state = mlstm_zero_state(b, h, cfg.ssm_head_dim, dv)
    if mode == "decode":
        hidden, state = mlstm_recurrent(q, k, v, li, lf, state)
    else:
        hidden, state = mlstm_chunkwise(q, k, v, li, lf, state,
                                        min(chunk, s))
    hidden = hidden.transpose(0, 2, 1, 3)                            # (b,s,h,dv)
    hidden = _groupnorm(hidden, p["gn"]).reshape(b, s, di)
    out = (hidden * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", out, p["w_down"])
    y = shard_constraint(y, ("act_batch", "act_seq", "act_embed"), mesh)
    new_cache = {"C": state[0], "n": state[1], "m": state[2], "conv": new_conv}
    return res + y, new_cache


def slstm_block_apply(p, x, cfg, mesh, mode, cache):
    b, s, d = x.shape
    h = cfg.ssm_heads
    dh = d // h
    res = x
    xin = L.apply_norm(p["ln"], x, cfg.norm)
    gx = jnp.einsum("bsd,dghe->bsghe", xin, p["wx"])                 # (b,s,4,h,dh)
    # perf iteration I7: replicate the (tiny) recurrence across the data
    # axis.  With batch-sharded states, AD all-reduces dR (the recurrent
    # weight cotangent, ~17 MB) EVERY timestep x every microbatch — 12.6 TB
    # of wire for xlstm train_4k.  Replicated compute costs ~+1% FLOPs and
    # keeps dR local until the single post-loop reduction.
    state = (cache["c"], cache["n"], cache["h"], cache["m"]) if cache else \
        slstm_zero_state(b, h, dh)
    if mesh is not None and s > 1:
        gx = shard_constraint(gx, (None, None, None, None, None), mesh)
        # states must be replicated too, or the bwd carry re-shards and the
        # dR all-reduce reappears (measured: it2)
        state = tuple(
            shard_constraint(t, (None,) * t.ndim, mesh) for t in state)
    hs, state = slstm_scan(gx, p["r"], state)
    if mesh is not None and s > 1:
        # pin hs (and thus its cotangent) REPLICATED: a batch-sharded
        # cotangent entering the backward time loop re-introduces the
        # per-timestep dR all-reduce (measured in it3); the price is one
        # all-gather per group scan instead of 4096 ARs.
        hs = shard_constraint(hs, (None,) * hs.ndim, mesh)
    hs = _groupnorm(hs, p["gn"]).reshape(b, s, d).astype(x.dtype)
    x = res + hs
    # gated FFN
    hin = L.apply_norm(p["ln_ffn"], x, cfg.norm)
    f = jax.nn.silu(jnp.einsum("bsd,df->bsf", hin, p["ffn_wg"])) * \
        jnp.einsum("bsd,df->bsf", hin, p["ffn_wi"])
    y = jnp.einsum("bsf,fd->bsd", f, p["ffn_wo"])
    new_cache = {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}
    return x + y, new_cache


# ---------------------------------------------------------------------------


class XLSTM(BaseLM):
    """48 blocks in groups of `slstm_every`: (k-1) mLSTM + 1 sLSTM."""

    def _layout(self):
        cfg = self.cfg
        k = cfg.slstm_every
        assert cfg.num_layers % k == 0
        groups = cfg.num_layers // k
        return groups, k - 1  # groups, mlstm per group

    def param_table(self) -> dict:
        cfg = self.cfg
        groups, m_per = self._layout()
        return {
            "embed": L.embed_defs(cfg),
            "mlstm": stack_defs(stack_defs(mlstm_block_defs(cfg), m_per), groups),
            "slstm": stack_defs(slstm_block_defs(cfg), groups),
            "ln_f": L.norm_defs(cfg.d_model, cfg.norm),
        }

    def cache_table(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        groups, m_per = self._layout()
        h = cfg.ssm_heads
        di = cfg.ssm_expand * cfg.d_model
        dv, dk, dh = di // h, cfg.ssm_head_dim, cfg.d_model // h
        f32 = jnp.float32

        def m_def(shape, axes):
            return ParamDef((groups, m_per) + shape, ("layers", "layers") + axes,
                            f32, "zeros")

        def s_def(shape, axes, dtype=f32):
            return ParamDef((groups,) + shape, ("layers",) + axes, dtype, "zeros")

        return {
            "mlstm": {
                "C": m_def((batch, h, dv, dk), ("act_batch", "act_heads", None, None)),
                "n": m_def((batch, h, dk), ("act_batch", "act_heads", None)),
                "m": m_def((batch, h), ("act_batch", "act_heads")),
                "conv": ParamDef((groups, m_per, batch, cfg.conv_width - 1, di),
                                 ("layers", "layers", "act_batch", None, "act_mlp"),
                                 cfg.activation_dtype, "zeros"),
            },
            "slstm": {
                "c": s_def((batch, h, dh), ("act_batch", "act_heads", None)),
                "n": s_def((batch, h, dh), ("act_batch", "act_heads", None)),
                "h": s_def((batch, h, dh), ("act_batch", "act_heads", None)),
                "m": s_def((batch, h), ("act_batch", "act_heads")),
            },
            "index": ParamDef((), (), jnp.int32, "zeros"),
        }

    def backbone(self, params, x, mesh, mode, cache=None):
        cfg = self.cfg
        groups, m_per = self._layout()
        chunk = cfg.ssm_chunk
        use_cache = cache is not None

        def group_body(carry, xs):
            y = carry
            mp, sp, mc, sc = xs

            def m_body(yy, xs2):
                bp, c = xs2
                out, nc = mlstm_block_apply(bp, yy, cfg, mesh, mode, c, chunk)
                return out, nc

            m_fn = remat_wrap(m_body, self.remat) if mode == "full" else m_body
            y, new_mc = jax.lax.scan(m_fn, y, (mp, mc))
            # sLSTM must be rematted too (it6): unchecked, its 4096-step
            # scan saves stacked f32 residuals (~2 GB x several per group)
            s_fn = slstm_block_apply
            if mode == "full":
                s_fn = remat_wrap(
                    lambda p_, y_: slstm_block_apply(p_, y_, cfg, mesh,
                                                     "full", None)[0],
                    self.remat)
                y = s_fn(sp, y)
                new_sc = sc
            else:
                y, new_sc = slstm_block_apply(sp, y, cfg, mesh, mode, sc)
            return y, (new_mc, new_sc)

        if use_cache:
            mcache = {k: v for k, v in cache["mlstm"].items()}
            scache = {k: v for k, v in cache["slstm"].items()}
        else:
            mcache = jax.tree.map(
                lambda d: jnp.zeros((groups, m_per) + (0,), jnp.float32), {})
            # build fresh zero caches so scan carries a uniform structure
            b = x.shape[0]
            tbl = self.cache_table(b, 0)
            from repro.models.params import init_params
            zeros = init_params(tbl, jax.random.PRNGKey(0))
            mcache, scache = zeros["mlstm"], zeros["slstm"]

        x, (new_m, new_s) = jax.lax.scan(
            group_body, x, (params["mlstm"], params["slstm"], mcache, scache))
        new_cache = None
        if use_cache:
            new_cache = {"mlstm": new_m, "slstm": new_s,
                         "index": cache["index"] + x.shape[1]}
        return x, new_cache

    def loss(self, params, batch, mesh):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = L.embed(params["embed"], batch["tokens"], cfg, mesh, positions=positions)
        x, _ = self.backbone(params, x, mesh, "full")
        x = L.apply_norm(params["ln_f"], x, cfg.norm)
        logits = L.unembed(params["embed"], x, cfg, mesh)
        loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
        return loss, {"loss": loss}

    def prefill(self, params, batch, mesh):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = L.embed(params["embed"], batch["tokens"], cfg, mesh, positions=positions)
        from repro.models.params import init_params
        cache = init_params(self.cache_table(b, 0), jax.random.PRNGKey(0))
        x, cache = self.backbone(params, x, mesh, "prefill", cache)
        x = L.apply_norm(params["ln_f"], x[:, -1:], cfg.norm)
        return L.unembed(params["embed"], x, cfg, mesh), cache

    def decode_step(self, params, cache, tokens, mesh):
        cfg = self.cfg
        b, s = tokens.shape
        positions = cache["index"] + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s))
        x = L.embed(params["embed"], tokens, cfg, mesh, positions=positions)
        x, cache = self.backbone(params, x, mesh, "decode", cache)
        x = L.apply_norm(params["ln_f"], x, cfg.norm)
        return L.unembed(params["embed"], x, cfg, mesh), cache
