"""Token-choice top-k Mixture-of-Experts (granite-moe, dbrx).

Dispatch is gather/scatter based (GShard capacity semantics, per-batch-row
groups) rather than one-hot-einsum based, so the dispatch tensors stay
O(tokens·k) instead of O(tokens·experts·capacity).  The MoE layer chunks
internally over the sequence axis so prefill at 32k tokens uses the same
bounded working set as a training microbatch.

Sharding: expert weights are (experts, embed, ff).  On a 16-way model axis
dbrx (16 experts) gets true expert parallelism; granite (40 experts) hits
the divisibility fallback and the rules engine automatically degrades to
TP-within-expert (ff=512 shards 16-way) — the fallback is recorded in the
EASEY tuning report.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import ParamDef
from repro.models.transformer import DenseLM
from repro.sharding.rules import shard_constraint

_MOE_SEQ_CHUNK = 2048


def moe_defs(cfg) -> dict:
    E, m, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    d = {
        "router": ParamDef((m, E), ("embed", "experts")),
        "wi": ParamDef((E, m, f), ("experts", "embed", "mlp")),
        "wo": ParamDef((E, f, m), ("experts", "mlp", "embed")),
    }
    if cfg.activation in ("silu", "geglu"):
        d["wg"] = ParamDef((E, m, f), ("experts", "embed", "mlp"))
    return d


def route_tokens(router_logits: jax.Array, k: int, capacity: int):
    """router_logits: (b, s, E) fp32.  Returns (slot, gates, keep, aux_loss).

    slot: (b, s*k) int32 in [0, E*C]; E*C is the drop sentinel.
    Position-in-expert is assigned in token order per batch row (GShard).
    """
    b, s, E = router_logits.shape
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (b, s, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize
    flat_e = expert_idx.reshape(b, s * k)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (b, s*k, E)
    pos = jnp.cumsum(oh, axis=1) - oh                        # rank within expert
    pos_in_e = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]
    keep = pos_in_e < capacity
    slot = jnp.where(keep, flat_e * capacity + pos_in_e, E * capacity)

    # load-balance auxiliary loss (Switch style): E * sum_e f_e * P_e
    frac = oh.reshape(b, s, k, E).sum(2).mean(axis=(0, 1)).astype(jnp.float32) / k
    mean_p = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac * mean_p)
    return slot, gate_vals.astype(jnp.float32), keep, aux


def moe_mlp_chunk(p, x, cfg, mesh):
    """x: (b, S, m) one seq chunk. Returns (y, aux)."""
    b, S, m = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = max(int(cfg.capacity_factor * k * S / E), 1)
    C = -(-C // 8) * 8  # round up to 8 for tiling friendliness

    logits = jnp.einsum("bsm,me->bse", x, p["router"],
                        preferred_element_type=jnp.float32)
    slot, gates, keep, aux = route_tokens(logits, k, C)

    # slot -> token scatter (int indices only), then row gather.
    tok_ids = jnp.broadcast_to(
        (jnp.arange(S * k, dtype=jnp.int32) // k)[None], (b, S * k))
    batch_ix = jnp.broadcast_to(jnp.arange(b)[:, None], (b, S * k))
    slot_tok = jnp.full((b, E * C + 1), S, jnp.int32)        # default: pad row
    slot_tok = slot_tok.at[batch_ix, slot].set(tok_ids, mode="drop")
    slot_tok = slot_tok[:, : E * C]

    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, m), x.dtype)], axis=1)
    ex = jnp.take_along_axis(x_pad, slot_tok[..., None], axis=1)
    ex = ex.reshape(b, E, C, m)
    ex = shard_constraint(ex, ("act_batch", "act_experts", None, None), mesh)

    h = jnp.einsum("becm,emf->becf", ex, p["wi"])
    if "wg" in p:
        h = jax.nn.silu(jnp.einsum("becm,emf->becf", ex, p["wg"])) * h
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.activation == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    h = shard_constraint(h, ("act_batch", "act_experts", None, "act_mlp"), mesh)
    ye = jnp.einsum("becf,efm->becm", h, p["wo"])
    ye = shard_constraint(ye, ("act_batch", "act_experts", None, None), mesh)

    ye_flat = ye.reshape(b, E * C, m)
    ye_pad = jnp.concatenate([ye_flat, jnp.zeros((b, 1, m), ye.dtype)], axis=1)
    y_assign = jnp.take_along_axis(ye_pad, slot[..., None], axis=1)  # (b, s*k, m)
    w = gates * keep.astype(jnp.float32).reshape(b, S, k)
    y = jnp.einsum("bskm,bsk->bsm", y_assign.reshape(b, S, k, m),
                   w.astype(y_assign.dtype))
    y = shard_constraint(y, ("act_batch", "act_seq", "act_embed"), mesh)
    return y, aux


def moe_mlp(p, x, cfg, mesh):
    """Chunked over sequence; returns (y, mean aux loss)."""
    b, s, m = x.shape
    chunk = min(_MOE_SEQ_CHUNK, s)
    if s <= chunk:
        return moe_mlp_chunk(p, x, cfg, mesh)
    assert s % chunk == 0
    n = s // chunk
    xc = x.reshape(b, n, chunk, m).transpose(1, 0, 2, 3)

    def body(_, xi):
        y, aux = moe_mlp_chunk(p, xi, cfg, mesh)
        return None, (y, aux)

    _, (yc, auxc) = jax.lax.scan(body, None, xc)
    y = yc.transpose(1, 0, 2, 3).reshape(b, s, m)
    return y, auxc.mean()


class MoELM(DenseLM):
    """Dense attention + MoE FFN. Aux loss threaded through the layer scan."""

    def mlp_defs(self) -> dict:
        return moe_defs(self.cfg)

    def block_apply(self, p, x, mesh, positions, mode, cache):
        cfg = self.cfg
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        attn_out, new_cache = L.attention(
            p["attn"], h, cfg, mesh, positions=positions, mode=mode,
            cache=cache, window=cfg.window or None)
        x = x + attn_out
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        y, aux = moe_mlp(p["mlp"], h, cfg, mesh)
        return x + y, (new_cache, aux)

    # backbone: thread aux through the scan carry
    def backbone(self, params, x, positions, mesh, mode, cache=None):
        blocks = params["blocks"]
        if mode == "full":
            def raw(bp, y):
                out, (_, aux) = self.block_apply(bp, y, mesh, positions, "full", None)
                return out, aux
            fn = jax.checkpoint(
                raw, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable) \
                if self.remat == "dots" else (jax.checkpoint(raw) if self.remat == "full" else raw)

            def body(carry, bp):
                y, aux_sum = carry
                y, aux = fn(bp, y)
                return (y, aux_sum + aux), None

            (x, aux_sum), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), blocks)
            self._last_aux = aux_sum / self.cfg.num_layers
            return x, None

        if mode == "decode":
            pages = cache.get("pages")

            def body_d(carry, xs):
                bp, ck, cv, ci = xs[:4]
                layer_cache = {"k": ck, "v": cv, "index": ci}
                if pages is not None:
                    layer_cache["pages"] = xs[4]
                y, (nc, _) = self.block_apply(bp, carry, mesh, positions,
                                              "decode", layer_cache)
                return y, (nc["k"], nc["v"])

            index = cache["index"]   # scalar, or per-slot vector (serving)
            L = self.cfg.num_layers
            xs = (blocks, cache["k"], cache["v"],
                  jnp.broadcast_to(index, (L,) + jnp.shape(index)))
            if pages is not None:
                xs = xs + (jnp.broadcast_to(pages, (L,) + pages.shape),)
            x, (nk, nv) = jax.lax.scan(body_d, x, xs)
            new_cache = {"k": nk, "v": nv, "index": index + x.shape[1]}
            if pages is not None:
                new_cache["pages"] = pages
            return x, new_cache

        if mode == "chunk":
            slot, offset = cache["slot"], cache["offset"]
            bound = cache["kv_bound"]              # static python int
            pages_row = cache.get("pages_row")

            def body_c(carry, xs):
                bp, ck, cv = xs
                layer_cache = {"k": ck, "v": cv, "slot": slot,
                               "offset": offset, "kv_bound": bound}
                if pages_row is not None:
                    layer_cache["pages_row"] = pages_row
                y, (nc, _) = self.block_apply(bp, carry, mesh, positions,
                                              "chunk", layer_cache)
                return y, (nc["k"], nc["v"])

            x, (nk, nv) = jax.lax.scan(body_c, x,
                                       (blocks, cache["k"], cache["v"]))
            return x, {"k": nk, "v": nv}

        def body_p(carry, bp):
            y, (nc, _) = self.block_apply(bp, carry, mesh, positions, "prefill", None)
            return y, (nc["k"], nc["v"])

        x, kvs = jax.lax.scan(body_p, x, blocks)
        return x, {"k": kvs[0], "v": kvs[1],
                   "index": jnp.asarray(x.shape[1], jnp.int32)}

    def loss(self, params, batch, mesh):
        loss, metrics = super().loss(params, batch, mesh)
        aux = getattr(self, "_last_aux", 0.0)
        total = loss + self.cfg.router_aux_coef * aux
        metrics = dict(metrics, aux_loss=aux, loss=total)
        return total, metrics
