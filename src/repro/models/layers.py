"""Shared neural-net layers (pure functions over param dicts).

Everything here is target-agnostic: activations carry logical-axis
sharding constraints (`shard_constraint`) that the EASEY deployment layer
resolves against the concrete mesh.  Attention has two interchangeable
implementations — the pure-jnp chunked online-softmax path (used on CPU
and as the Pallas oracle) and the Pallas flash kernel the AutoTuner swaps
in for TPU targets (kernels/flash_attention.py).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.sharding.rules import shard_constraint

# ---------------------------------------------------------------------------
# Norms


def _match_dgrad_dtype(fn):
    """Perf iteration I8: norms compute in fp32, so their input cotangent
    comes back fp32 and rides the TP backward all-reduces at 2x the wire
    bytes of the bf16 primal.  Cast the outgoing dx to the primal dtype —
    standard mixed-precision practice (grads accumulate fp32 AFTER the
    reduction)."""
    import functools

    @functools.wraps(fn)
    @jax.custom_vjp
    def wrapped(*args):
        return fn(*args)

    def fwd(*args):
        out, vjp = jax.vjp(fn, *args)
        return out, vjp

    def bwd(vjp, g):
        grads = vjp(g)
        # dx (the residual-stream cotangent) matches the primal dtype = the
        # cotangent's own dtype; small param grads stay fp32.
        return (grads[0].astype(g.dtype),) + tuple(grads[1:])

    wrapped.defvjp(fwd, bwd)
    return wrapped


@_match_dgrad_dtype
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


@_match_dgrad_dtype
def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_defs(d_model: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamDef((d_model,), ("embed",), init="ones")}
    return {"scale": ParamDef((d_model,), ("embed",), init="ones"),
            "bias": ParamDef((d_model,), ("embed",), init="zeros")}


def apply_norm(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Rotary position embeddings


def rope_frequencies(head_dim: int, fraction: float, theta: float) -> int:
    """Number of rotated dims (even)."""
    rot = int(head_dim * fraction)
    return rot - rot % 2


def apply_rope(x: jax.Array, positions: jax.Array, *, fraction: float = 1.0,
               theta: float = 10000.0) -> jax.Array:
    """x: (b, s, heads, head_dim); positions: (b, s) int32."""
    head_dim = x.shape[-1]
    rot = rope_frequencies(head_dim, fraction, theta)
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = jnp.exp(-jnp.arange(0, rot, 2, dtype=jnp.float32)
                    * (math.log(theta) / rot))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, rot/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1) if rot < head_dim \
        else out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ---------------------------------------------------------------------------
# Attention (GQA).  Reference chunked online-softmax implementation.

_Q_CHUNK = 1024


def _attn_one_chunk(q, k, v, mask, scale):
    """q: (b,K,G,qc,dh)  k: (b,t,K,dh)  v: (b,t,K,dh)
    mask: (qc,t) bool, or (b,qc,t) for per-row masks (slot-wise decode)."""
    scores = jnp.einsum("bkgqd,btkd->bkgqt", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bkgqd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def dot_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, q_offset: jax.Array | int = 0,
                  kv_len: jax.Array | None = None,
                  q_chunk: int = _Q_CHUNK) -> jax.Array:
    """Grouped-query attention.

    q: (b, s, H, dh); k/v: (b, t, K, dh) with H % K == 0.
    causal: query i attends keys j <= i + q_offset.
    kv_len: optional valid-length of the kv sequence (decode with a
        pre-allocated cache).
    q_offset / kv_len may also be (b,) vectors — per-row lengths for the
    continuous-batching slot decode, producing a (b, qc, t) mask.
    Long sequences are processed in q-chunks via lax.map so the live score
    buffer is (b, H, q_chunk, t) instead of (b, H, s, t).
    """
    b, s, H, dh = q.shape
    t, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, s, K, G, dh).transpose(0, 2, 3, 1, 4)  # b,K,G,s,dh

    kv_pos = jnp.arange(t)
    per_row = jnp.ndim(q_offset) == 1 or \
        (kv_len is not None and jnp.ndim(kv_len) == 1)
    if not per_row:
        valid = kv_pos < (kv_len if kv_len is not None else t)

    def mask_for(q_pos):
        if per_row:
            m = jnp.ones((b, q_pos.shape[0], t), bool)
            if kv_len is not None:
                m = m & (kv_pos[None, None, :]
                         < jnp.reshape(jnp.asarray(kv_len), (-1, 1, 1)))
            if causal:
                off = jnp.reshape(jnp.asarray(q_offset), (-1, 1, 1))
                m = m & (kv_pos[None, None, :] <= (q_pos[None, :, None] + off))
            return m
        m = valid[None, :]
        if causal:
            m = m & (kv_pos[None, :] <= (q_pos[:, None] + q_offset))
        return jnp.broadcast_to(m, (q_pos.shape[0], t))

    if s <= q_chunk:
        out = _attn_one_chunk(qg, k, v, mask_for(jnp.arange(s)), scale)
    else:
        assert s % q_chunk == 0, (s, q_chunk)
        n = s // q_chunk
        qc = qg.reshape(b, K, G, n, q_chunk, dh).transpose(3, 0, 1, 2, 4, 5)

        # perf iteration I4: checkpoint the chunk body so AD re-derives the
        # (q_chunk x t) scores/probs in the backward instead of stacking
        # them for all chunks (full s x t score matrix in HBM).
        @jax.checkpoint
        def one(args):
            i, qi = args
            q_pos = i * q_chunk + jnp.arange(q_chunk)
            return _attn_one_chunk(qi, k, v, mask_for(q_pos), scale)

        out = jax.lax.map(one, (jnp.arange(n), qc))          # n,b,K,G,qc,dh
        out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, K, G, s, dh)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, H, dh)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache handling)


def attention_defs(cfg) -> dict:
    dh = cfg.head_dim
    d = {
        "wq": ParamDef((cfg.d_model, cfg.num_heads, dh), ("embed", "heads", "head_dim")),
        "wk": ParamDef((cfg.d_model, cfg.num_kv_heads, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((cfg.d_model, cfg.num_kv_heads, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((cfg.num_heads, dh, cfg.d_model), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDef((cfg.num_heads, dh), ("heads", "head_dim"), init="zeros")
        d["bk"] = ParamDef((cfg.num_kv_heads, dh), ("kv_heads", "head_dim"), init="zeros")
        d["bv"] = ParamDef((cfg.num_kv_heads, dh), ("kv_heads", "head_dim"), init="zeros")
    return d


def attention(p: dict, x: jax.Array, cfg, mesh, *, positions: jax.Array,
              mode: str, cache: dict | None = None,
              kv_source: jax.Array | None = None,
              window: int | None = None):
    """mode: 'full' (train / prefill-like, causal unless cross),
    'prefill' (causal + returns fresh cache), 'decode' (uses cache),
    'chunk' (chunked prefill written straight into a serving KV pool).

    kv_source: if given, cross-attention (keys/values from encoder output,
    non-causal, no rope on kv positions beyond source positions).
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    cross = kv_source is not None
    src = kv_source if cross else x
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"])
    k = jnp.einsum("bte,ekd->btkd", src, p["wk"])
    v = jnp.einsum("bte,ekd->btkd", src, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    q = shard_constraint(q, ("act_batch", "act_seq", "act_heads", None), mesh)
    k = shard_constraint(k, ("act_batch", "act_seq", "act_kv_heads", None), mesh)
    v = shard_constraint(v, ("act_batch", "act_seq", "act_kv_heads", None), mesh)

    if cfg.pos == "rope" and not cross:
        src_pos = positions
        q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        k = apply_rope(k, src_pos, fraction=cfg.rope_fraction, theta=cfg.rope_theta)

    new_cache = None
    if mode == "decode":
        assert cache is not None and not cross
        idx = cache["index"]  # int32 tokens seen so far: scalar, or (b,)
        t = cache["k"].shape[1]
        if "pages" in cache:
            # PAGED slot-wise decode (continuous batching over a paged KV
            # pool): this layer's cache is a page pool (num_pages,
            # page_size, K, dh) and `pages` is the (slots, max_pages)
            # int32 page table.  The new kv is scattered to each row's own
            # page/offset; K/V are then read back *through the page table*
            # (one gather per row) so attention sees the same
            # (slots, max_pages*page_size, K, dh) layout the contiguous
            # path uses — identical masks, identical softmax, identical
            # tokens.  Rows with a zeroed page-table entry (freed /
            # never-allocated slots) write into the reserved junk page 0,
            # which no live table references.
            pages = cache["pages"]
            n_pages, psize = cache["k"].shape[0], cache["k"].shape[1]
            max_pages = pages.shape[1]
            Kh, dh = k.shape[2], k.shape[3]
            if s == 1:
                logical_page = idx // psize
                ok = logical_page < max_pages
                dest = jnp.take_along_axis(
                    pages, jnp.minimum(logical_page, max_pages - 1)[:, None],
                    axis=1)[:, 0]                               # (slots,)
                # out-of-range writes (a slot already at its page-run
                # capacity) route to the reserved junk page 0 — NOT wrapped
                # into the slot's last page, which under the prefix cache
                # may be shared with a live request (same ok-guard as the
                # chunk path below)
                fpos = jnp.where(ok, dest * psize + idx % psize, idx % psize)
                k_all = cache["k"].reshape(n_pages * psize, Kh, dh).at[fpos] \
                    .set(k[:, 0]).reshape(n_pages, psize, Kh, dh)
                v_all = cache["v"].reshape(n_pages * psize, Kh, dh).at[fpos] \
                    .set(v[:, 0]).reshape(n_pages, psize, Kh, dh)
            else:
                # VERIFY burst: each row writes s speculative positions
                # idx..idx+s-1.  Per-position page lookup keeps the same
                # junk-page-0 ok-guard, so a burst past a slot's page-run
                # capacity can never scribble into a (possibly
                # prefix-shared) live page.
                pos = idx[:, None] + jnp.arange(s)[None, :]     # (slots, s)
                logical_page = pos // psize
                ok = logical_page < max_pages
                dest = jnp.take_along_axis(
                    pages, jnp.minimum(logical_page, max_pages - 1), axis=1)
                fpos = jnp.where(ok, dest * psize + pos % psize, pos % psize)
                k_all = cache["k"].reshape(n_pages * psize, Kh, dh).at[fpos] \
                    .set(k).reshape(n_pages, psize, Kh, dh)
                v_all = cache["v"].reshape(n_pages * psize, Kh, dh).at[fpos] \
                    .set(v).reshape(n_pages, psize, Kh, dh)
            if cache.get("use_kernel") and s == 1:
                # fused Pallas path (single-token decode only; verify
                # bursts take the gather path): the page table is walked
                # inside the kernel, so the materialized
                # (slots, max_pages*psize, K, dh) gather never hits HBM
                from repro.kernels.ops import paged_attention
                out = paged_attention(q[:, 0], k_all, v_all, pages,
                                      (idx + s).astype(jnp.int32))[:, None]
            else:
                kg = jnp.take(k_all, pages, axis=0).reshape(
                    q.shape[0], max_pages * psize, Kh, dh)
                vg = jnp.take(v_all, pages, axis=0).reshape(
                    q.shape[0], max_pages * psize, Kh, dh)
                out = dot_attention(q, kg, vg, causal=True, q_offset=idx,
                                    kv_len=idx + s)
        elif jnp.ndim(idx) == 1:
            # SLOT-WISE decode (continuous batching): every row is a pool
            # slot at its own length.  The new kv lands at each row's own
            # position (one-hot select — a per-row scatter that XLA fuses),
            # and the mask is per-row causal-with-length.  Window is not
            # applied: pool slots are already bounded by max_len.
            if s == 1:
                hit = (jnp.arange(t)[None, :] == idx[:, None])[..., None, None]
                k_all = jnp.where(hit, k, cache["k"])
                v_all = jnp.where(hit, v, cache["v"])
            else:
                # VERIFY burst: scatter s speculative positions per row;
                # positions past max_len drop (the host caps acceptance at
                # the slot's backed capacity, so dropped writes are never
                # attended)
                rows = jnp.arange(q.shape[0])[:, None]          # (slots, 1)
                pos = idx[:, None] + jnp.arange(s)[None, :]     # (slots, s)
                k_all = cache["k"].at[rows, pos].set(k, mode="drop")
                v_all = cache["v"].at[rows, pos].set(v, mode="drop")
            out = dot_attention(q, k_all, v_all, causal=True, q_offset=idx,
                                kv_len=idx + s)
        elif window is not None and t <= window:
            # RING BUFFER: cache holds only the last `t` positions.  Keys
            # carry absolute RoPE phases from write time, so order in the
            # buffer is irrelevant; everything valid is attendable.
            write = jnp.mod(idx, t)
            k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, write, axis=1)
            v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, write, axis=1)
            out = dot_attention(q, k_all, v_all, causal=False,
                                kv_len=jnp.minimum(idx + s, t))
        else:
            k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
            v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
            out = dot_attention(q, k_all, v_all, causal=True, q_offset=idx,
                                kv_len=idx + s)
        new_cache = {"k": k_all, "v": v_all, "index": idx + s}
    elif mode == "chunk":
        # CHUNKED PREFILL written straight into the serving pool: x is one
        # bucketed chunk (batch 1, s tokens at global positions
        # [offset, offset+s)) of a single request's prompt, and this
        # layer's cache is the pool's own storage — contiguous
        # (num_slots, max_len, K, dh) or paged (num_pages, page_size, K,
        # dh) plus the slot's (max_pages,) page-table row.  The chunk's
        # K/V scatter to their final resting positions (no intermediate
        # contiguous (1, s) cache to re-scatter later), then the slot's
        # whole KV is read back so the chunk attends causally over every
        # prior chunk through the same indirection decode uses.  Bucket
        # padding rows (query j >= the true chunk length) write junk only
        # at positions later chunks / decode overwrite before any mask
        # admits them; out-of-range rows drop (contiguous) or land in the
        # reserved junk page 0 (paged).
        assert cache is not None and not cross
        slot, off = cache["slot"], cache["offset"]
        # kv_bound (a STATIC python int >= offset + s) caps the read-back:
        # a 4-token prompt in a max_len=128 pool attends 4-16 positions,
        # not 128.  Bounds are bucketed to powers of two host-side so the
        # jit cache stays (chunk buckets) x (bound buckets).
        bound = cache["kv_bound"]
        pos = off + jnp.arange(s)                   # (s,) global positions
        Kh, dh = k.shape[2], k.shape[3]
        if "pages_row" in cache:
            pages_row = cache["pages_row"]          # (max_pages,) int32
            n_pages, psize = cache["k"].shape[0], cache["k"].shape[1]
            max_pages = pages_row.shape[0]
            logical = pos // psize
            ok = logical < max_pages
            dest = jnp.take(pages_row, jnp.minimum(logical, max_pages - 1))
            fpos = jnp.where(ok, dest * psize + pos % psize, pos % psize)
            k_all = cache["k"].reshape(n_pages * psize, Kh, dh) \
                .at[fpos].set(k[0]).reshape(n_pages, psize, Kh, dh)
            v_all = cache["v"].reshape(n_pages * psize, Kh, dh) \
                .at[fpos].set(v[0]).reshape(n_pages, psize, Kh, dh)
            B = min(-(-bound // psize), max_pages)
            kg = jnp.take(k_all, pages_row[:B], axis=0).reshape(
                1, B * psize, Kh, dh)
            vg = jnp.take(v_all, pages_row[:B], axis=0).reshape(
                1, B * psize, Kh, dh)
        else:
            k_all = cache["k"].at[slot, pos].set(k[0], mode="drop")
            v_all = cache["v"].at[slot, pos].set(v[0], mode="drop")
            L = min(bound, k_all.shape[1])
            kg = jax.lax.dynamic_slice(
                k_all, (slot, 0, 0, 0), (1, L, Kh, dh))
            vg = jax.lax.dynamic_slice(
                v_all, (slot, 0, 0, 0), (1, L, Kh, dh))
        out = dot_attention(q, kg, vg, causal=True, q_offset=off,
                            kv_len=off + s)
        new_cache = {"k": k_all, "v": v_all}
    else:
        causal = (not cross) and cfg.causal
        if window is not None and s > window and causal:
            out = _windowed_attention(q, k, v, window)
        else:
            out = dot_attention(q, k, v, causal=causal)
        if mode == "prefill" and not cross:
            new_cache = {"k": k, "v": v, "index": jnp.asarray(s, jnp.int32)}

    out = shard_constraint(out, ("act_batch", "act_seq", "act_heads", None), mesh)
    y = jnp.einsum("bshd,hde->bse", out, p["wo"])
    return shard_constraint(y, ("act_batch", "act_seq", "act_embed"), mesh), new_cache


def _windowed_attention(q, k, v, window: int) -> jax.Array:
    """Sliding-window causal attention via q-chunking: each q-chunk only
    sees the kv slice [chunk_start - window, chunk_end)."""
    b, s, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(dh)
    qc = min(_Q_CHUNK, s)
    assert s % qc == 0
    n = s // qc
    span = qc + window  # kv window per chunk
    qg = q.reshape(b, n, qc, K, G, dh).transpose(1, 0, 3, 4, 2, 5)

    k_pad = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    v_pad = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

    def one(args):
        i, qi = args
        start = i * qc  # in padded coords the window begins at start
        ks = jax.lax.dynamic_slice_in_dim(k_pad, start, span, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v_pad, start, span, axis=1)
        q_pos = start + jnp.arange(qc)          # unpadded positions
        kv_pos = start - window + jnp.arange(span)
        m = (kv_pos[None, :] <= q_pos[:, None]) & \
            (kv_pos[None, :] > q_pos[:, None] - window) & (kv_pos[None, :] >= 0)
        return _attn_one_chunk(qi, ks, vs, m, scale)

    out = jax.lax.map(one, (jnp.arange(n), qg))   # n,b,K,G,qc,dh
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, K, G, s, dh)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, H, dh)


# ---------------------------------------------------------------------------
# MLP


def mlp_defs(cfg) -> dict:
    gated = cfg.activation in ("silu", "geglu")
    d = {"wi": ParamDef((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
         "wo": ParamDef((cfg.d_ff, cfg.d_model), ("mlp", "embed"))}
    if gated:
        d["wg"] = ParamDef((cfg.d_model, cfg.d_ff), ("embed", "mlp"))
    return d


def mlp(p: dict, x: jax.Array, cfg, mesh) -> jax.Array:
    h = jnp.einsum("bse,ef->bsf", x, p["wi"])
    if cfg.activation == "silu":
        h = jax.nn.silu(h) if "wg" not in p else \
            jax.nn.silu(jnp.einsum("bse,ef->bsf", x, p["wg"])) * h
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(jnp.einsum("bse,ef->bsf", x, p["wg"])) * h
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.activation == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(cfg.activation)
    h = shard_constraint(h, ("act_batch", "act_seq", "act_experts"), mesh)
    y = jnp.einsum("bsf,fe->bse", h, p["wo"])
    return shard_constraint(y, ("act_batch", "act_seq", "act_embed"), mesh)


# ---------------------------------------------------------------------------
# Embedding / unembedding


def embed_defs(cfg) -> dict:
    # NOTE (perf iteration I3, REFUTED): feature-sharding the input table
    # (vocab replicated, features over 'model') makes the token gather
    # local and kills the SPMD "involuntary full rematerialization"
    # warning — but its backward scatter trips an XLA SPMD verifier bug
    # ("slice dim size d_model > d_model/16") on every non-SP train cell.
    # Reverted to vocab-sharded; the inefficiency is priced into the
    # roofline and logged in EXPERIMENTS.md §Perf.
    d = {"embedding": ParamDef((cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"),
                               init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.pos == "learned":
        d["pos_embedding"] = ParamDef((cfg.max_position, cfg.d_model),
                                      (None, "embed"), init="embed", scale=0.02)
    return d


def embed(p: dict, tokens: jax.Array, cfg, mesh, positions=None) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0).astype(cfg.activation_dtype)
    if cfg.pos == "learned":
        assert positions is not None
        x = x + jnp.take(p["pos_embedding"], positions, axis=0).astype(x.dtype)
    elif cfg.pos == "sinusoidal":
        pe = sinusoidal_positions(cfg.max_position, cfg.d_model)
        x = x + jnp.take(pe, positions, axis=0).astype(x.dtype)
    return shard_constraint(x, ("act_batch", "act_seq", "act_embed"), mesh)


def unembed(p: dict, x: jax.Array, cfg, mesh) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bse,ve->bsv", x, p["embedding"].astype(x.dtype))
    else:
        logits = jnp.einsum("bse,ev->bsv", x, p["unembed"])
    return shard_constraint(logits, ("act_batch", "act_seq", "act_vocab"), mesh)


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array | None = None):
    """Mean per-token cross entropy in fp32. labels: int32 (b, s)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
