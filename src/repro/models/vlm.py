"""LLaVA-NeXT-style VLM — arch `llava-next-34b`.

Assignment specifies the transformer BACKBONE only; the vision tower and
anyres tiling are a STUB: ``batch_table`` takes precomputed patch
embeddings (b, num_patches, d_model) which are prepended to the token
embeddings.  The total backbone sequence equals the assigned seq_len
(first `num_patches` positions are image, the rest text); the loss is
masked to text positions.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.models import layers as L
from repro.models.params import ParamDef
from repro.models.transformer import DenseLM
from repro.sharding.rules import shard_constraint


class VLM(DenseLM):
    def batch_table(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        P = cfg.num_patches
        if shape.kind == "decode":
            return {"tokens": ParamDef((b, 1), ("act_batch", None), jnp.int32, "zeros")}
        text = s - P
        assert text > 0, (s, P)
        base = {
            "patch_embeds": ParamDef((b, P, cfg.d_model),
                                     ("act_batch", None, "act_embed"),
                                     cfg.activation_dtype, "zeros"),
            "tokens": ParamDef((b, text), ("act_batch", "act_seq"), jnp.int32, "zeros"),
        }
        if shape.kind == "train":
            base["labels"] = ParamDef((b, text), ("act_batch", "act_seq"),
                                      jnp.int32, "zeros")
        return base

    def embed_inputs(self, params, batch, mesh, positions):
        cfg = self.cfg
        tok = L.embed(params["embed"], batch["tokens"], cfg, mesh,
                      positions=positions[:, batch["patch_embeds"].shape[1]:])
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
        return shard_constraint(x, ("act_batch", "act_seq", "act_embed"), mesh)

    def loss(self, params, batch, mesh):
        cfg = self.cfg
        b = batch["tokens"].shape[0]
        P = cfg.num_patches
        s = P + batch["tokens"].shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = self.embed_inputs(params, batch, mesh, positions)
        x, _ = self.backbone(params, x, positions, mesh, "full")
        # only text positions contribute to the loss
        logits = self.logits_from(params, x[:, P:], mesh)
        loss = L.softmax_xent(logits, batch["labels"])
        return loss, {"loss": loss}

    def prefill(self, params, batch, mesh):
        cfg = self.cfg
        b = batch["tokens"].shape[0]
        s = cfg.num_patches + batch["tokens"].shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = self.embed_inputs(params, batch, mesh, positions)
        x, cache = self.backbone(params, x, positions, mesh, "prefill")
        logits = self.logits_from(params, x[:, -1:], mesh)
        return logits, cache
