"""LULESH-analogue: Sedov-blast hydrodynamics proxy (the paper's §4 app).

The paper evaluates EASEY by deploying the DASH/PGAS port of LULESH
(Livermore Unstructured Lagrangian Explicit Shock Hydrodynamics) on
SuperMUC-NG.  TPU adaptation (DESIGN.md §2): the unstructured PGAS mesh
becomes a structured 3-D grid sharded over the ("data","model") mesh axes;
DASH's hierarchical-locality halo reads become XLA-inserted collective
permutes; the per-zone hot loop becomes a fused Pallas stencil kernel
(kernels/sedov_stencil.py — this module is its pure-jnp oracle).

Physics (simplified staggered-free Sedov proxy, 6-point stencil):
  p   = (gamma-1)·rho·e                       ideal-gas EOS
  a   = -grad(p+q)/rho ; v += dt·a            momentum
  dv  = div(v)                                volume strain rate
  q   = c_q·rho·dv²  where dv<0 else 0        artificial viscosity
  e  += -dt·(p+q)·dv/rho ; rho -= dt·rho·dv   energy / mass
  dt  = CFL·min(dx/(c_s+|v|))                 global reduction (all-reduce)

FOM is LULESH's: zones × iterations / seconds (higher is better).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_constraint

GAMMA = 1.4
C_Q = 2.0
CFL = 0.3


@dataclasses.dataclass(frozen=True)
class LuleshConfig:
    name: str = "lulesh-dash"
    family: str = "stencil"
    grid: int = 48                 # cube side (zones per side)
    iters: int = 10
    dtype: object = jnp.float32


FIELD_AXES = ("act_grid_x", "act_grid_y", "act_grid_z")


def init_state(cfg: LuleshConfig):
    """Sedov problem: cold uniform gas, energy spike at the corner zone."""
    n = cfg.grid
    rho = jnp.ones((n, n, n), cfg.dtype)
    e = jnp.full((n, n, n), 1e-6, cfg.dtype)
    e = e.at[0, 0, 0].set(3.948746e7)  # LULESH's initial energy deposition
    v = jnp.zeros((3, n, n, n), cfg.dtype)
    return {"rho": rho, "e": e, "v": v, "t": jnp.zeros((), cfg.dtype)}


def _shift(f, axis, d):
    """Neighbor value along axis with reflective (edge-clamped) boundary."""
    n = f.shape[axis]
    if d > 0:
        sl = jax.lax.slice_in_dim(f, 1, n, axis=axis)
        edge = jax.lax.slice_in_dim(f, n - 1, n, axis=axis)
        return jnp.concatenate([sl, edge], axis=axis)
    sl = jax.lax.slice_in_dim(f, 0, n - 1, axis=axis)
    edge = jax.lax.slice_in_dim(f, 0, 1, axis=axis)
    return jnp.concatenate([edge, sl], axis=axis)


def _grad(f, dx):
    return jnp.stack([( _shift(f, a, +1) - _shift(f, a, -1)) / (2 * dx)
                      for a in range(3)])


def _div(v, dx):
    return sum((_shift(v[a], a, +1) - _shift(v[a], a, -1)) / (2 * dx)
               for a in range(3))


def step(state, cfg: LuleshConfig, mesh=None, dx: float = 1.0):
    """One explicit hydro step. Pure-jnp oracle for the Pallas kernel."""
    rho, e, v = state["rho"], state["e"], state["v"]
    rho = shard_constraint(rho, FIELD_AXES, mesh)
    e = shard_constraint(e, FIELD_AXES, mesh)

    p = (GAMMA - 1.0) * rho * e
    dv = _div(v, dx)
    q = jnp.where(dv < 0, C_Q * rho * dv * dv, 0.0).astype(p.dtype)

    # global CFL reduction -> all-reduce on the device mesh
    cs = jnp.sqrt(GAMMA * p / jnp.maximum(rho, 1e-12))
    vmag = jnp.sqrt((v * v).sum(0))
    dt = CFL * dx / jnp.max(cs + vmag + 1e-12)

    g = _grad(p + q, dx)
    v = v - dt * g / jnp.maximum(rho, 1e-12)[None]
    v = shard_constraint(v, (None,) + FIELD_AXES, mesh)
    dv = _div(v, dx)
    e = e - dt * (p + q) * dv / jnp.maximum(rho, 1e-12)
    e = jnp.maximum(e, 0.0)
    rho = jnp.maximum(rho * (1.0 - dt * dv), 1e-12)
    return {"rho": rho, "e": e, "v": v, "t": state["t"] + dt}


@partial(jax.jit, static_argnames=("cfg", "iters", "use_kernel"))
def run(state, cfg: LuleshConfig, iters: int, mesh=None, use_kernel: bool = False):
    """`iters` steps via lax.scan (the '-i' flag of the paper's Listing 1.5)."""
    if use_kernel:
        from repro.kernels.ops import sedov_step_kernel
        step_fn = lambda s: sedov_step_kernel(s, cfg)
    else:
        step_fn = lambda s: step(s, cfg, mesh)

    def body(s, _):
        return step_fn(s), None

    state, _ = jax.lax.scan(body, state, None, length=iters)
    return state


def fom(zones: int, iters: int, seconds: float) -> float:
    """LULESH figure-of-merit: zone-iterations per second."""
    return zones * iters / max(seconds, 1e-12)
