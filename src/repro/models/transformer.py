"""Decoder-only transformer LM (dense family) + the generic LM interface.

All families implement ``BaseLM``:

    param_table()                  declarative weights (ParamDef tree)
    batch_table(shape)             declarative inputs for a ShapeConfig
    cache_table(batch, max_len)    declarative decode state
    loss(params, batch, mesh)      training loss (mode='full' forward)
    prefill(params, batch, mesh)   build cache + last-position logits
    decode_step(params, cache, tokens, mesh)

Layers are stacked with ``lax.scan`` (compile time on deep models) and
wrapped in ``jax.checkpoint`` per the deployment plan's remat policy.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models.params import ParamDef, _map_table
from repro.sharding.rules import shard_constraint


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if policy == "full":
        return jax.checkpoint(fn)
    raise ValueError(policy)


def stack_defs(defs: dict, n: int) -> dict:
    """Prepend a scanned 'layers' dimension to every ParamDef in a tree."""
    return _map_table(
        defs,
        lambda d: dataclasses.replace(
            d, shape=(n,) + d.shape, logical_axes=("layers",) + d.logical_axes),
    )


class BaseLM:
    def __init__(self, cfg: ModelConfig, remat: str = "dots"):
        self.cfg = cfg
        self.remat = remat

    # -- declarative tables ------------------------------------------------
    def param_table(self) -> dict:
        raise NotImplementedError

    def batch_table(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {
                "tokens": ParamDef((b, s), ("act_batch", "act_seq"), jnp.int32, "zeros"),
                "labels": ParamDef((b, s), ("act_batch", "act_seq"), jnp.int32, "zeros"),
            }
        if shape.kind == "prefill":
            return {"tokens": ParamDef((b, s), ("act_batch", "act_seq"), jnp.int32, "zeros")}
        # decode: one new token against a cache of length seq_len
        return {"tokens": ParamDef((b, 1), ("act_batch", None), jnp.int32, "zeros")}

    def cache_table(self, batch: int, max_len: int) -> dict:
        raise NotImplementedError

    # -- compute -----------------------------------------------------------
    def loss(self, params, batch, mesh):
        raise NotImplementedError

    def prefill(self, params, batch, mesh):
        raise NotImplementedError

    def decode_step(self, params, cache, tokens, mesh):
        raise NotImplementedError


# ---------------------------------------------------------------------------


class DenseLM(BaseLM):
    """Llama/Mistral/Nemotron/StableLM-style decoder; also the VLM backbone."""

    # ---- tables ----
    def block_defs(self) -> dict:
        cfg = self.cfg
        d = {"ln1": L.norm_defs(cfg.d_model, cfg.norm),
             "attn": L.attention_defs(cfg),
             "ln2": L.norm_defs(cfg.d_model, cfg.norm),
             "mlp": self.mlp_defs()}
        return d

    def mlp_defs(self) -> dict:
        return L.mlp_defs(self.cfg)

    def param_table(self) -> dict:
        cfg = self.cfg
        return {
            "embed": L.embed_defs(cfg),
            "blocks": stack_defs(self.block_defs(), cfg.num_layers),
            "ln_f": L.norm_defs(cfg.d_model, cfg.norm),
        }

    def cache_table(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        kv = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        ax = ("layers", "act_batch", "act_seq", "act_kv_heads", None)
        return {"k": ParamDef(kv, ax, cfg.activation_dtype, "zeros"),
                "v": ParamDef(kv, ax, cfg.activation_dtype, "zeros"),
                "index": ParamDef((), (), jnp.int32, "zeros")}

    # ---- block ----
    def block_apply(self, p, x, mesh, positions, mode, cache):
        cfg = self.cfg
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        window = cfg.window or None
        attn_out, new_cache = L.attention(
            p["attn"], h, cfg, mesh, positions=positions, mode=mode,
            cache=cache, window=window)
        x = x + attn_out
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        x = x + self.mlp_apply(p["mlp"], h, mesh)
        return x, new_cache

    def mlp_apply(self, p, h, mesh):
        return L.mlp(p, h, self.cfg, mesh)

    # ---- backbone over scanned layers ----
    def backbone(self, params, x, positions, mesh, mode, cache=None):
        blocks = params["blocks"]
        if mode == "full":
            fn = remat_wrap(
                lambda bp, y: self.block_apply(bp, y, mesh, positions, "full", None)[0],
                self.remat)

            def body(carry, bp):
                return fn(bp, carry), None

            x, _ = jax.lax.scan(body, x, blocks)
            return x, None

        # prefill / decode / chunk: per-layer cache travels as scan xs -> ys
        index = cache.get("index") if cache is not None else None

        if mode == "decode":
            pages = cache.get("pages")
            # STATIC python flag (never part of the jit pytree): selects the
            # fused Pallas paged-decode kernel inside the traced body
            use_kernel = bool(cache.get("use_kernel", False))

            def body_d(carry, xs):
                bp, ck, cv, ci = xs[:4]
                layer_cache = {"k": ck, "v": cv, "index": ci}
                if pages is not None:
                    layer_cache["pages"] = xs[4]
                    if use_kernel:
                        layer_cache["use_kernel"] = True
                y, nc = self.block_apply(bp, carry, mesh, positions, "decode",
                                         layer_cache)
                return y, (nc["k"], nc["v"])

            # index is a scalar (static decode) or a per-slot vector
            # (continuous batching); the paged layout adds the shared
            # (slots, max_pages) page table.  Either way each scanned
            # layer sees its own copy.
            L = self.cfg.num_layers
            xs = (blocks, cache["k"], cache["v"],
                  jnp.broadcast_to(index, (L,) + jnp.shape(index)))
            if pages is not None:
                xs = xs + (jnp.broadcast_to(pages, (L,) + pages.shape),)
            x, (nk, nv) = jax.lax.scan(body_d, x, xs)
            new_cache = {"k": nk, "v": nv, "index": index + x.shape[1]}
            if pages is not None:
                new_cache["pages"] = pages
            return x, new_cache

        if mode == "chunk":
            # chunked prefill into a serving pool: each scanned layer sees
            # its own (pool-shaped) K/V slice; slot / offset / the page
            # table row are layer-invariant and close over the body
            slot, offset = cache["slot"], cache["offset"]
            bound = cache["kv_bound"]              # static python int
            pages_row = cache.get("pages_row")

            def body_c(carry, xs):
                bp, ck, cv = xs
                layer_cache = {"k": ck, "v": cv, "slot": slot,
                               "offset": offset, "kv_bound": bound}
                if pages_row is not None:
                    layer_cache["pages_row"] = pages_row
                y, nc = self.block_apply(bp, carry, mesh, positions,
                                         "chunk", layer_cache)
                return y, (nc["k"], nc["v"])

            x, (nk, nv) = jax.lax.scan(body_c, x,
                                       (blocks, cache["k"], cache["v"]))
            return x, {"k": nk, "v": nv}

        # prefill
        def body_p(carry, bp):
            y, nc = self.block_apply(bp, carry, mesh, positions, "prefill", None)
            return y, (nc["k"], nc["v"]) if nc is not None else None

        x, kvs = jax.lax.scan(body_p, x, blocks)
        new_cache = {"k": kvs[0], "v": kvs[1],
                     "index": jnp.asarray(x.shape[1], jnp.int32)}
        return x, new_cache

    # ---- entry points ----
    def embed_inputs(self, params, batch, mesh, positions):
        return L.embed(params["embed"], batch["tokens"], self.cfg, mesh,
                       positions=positions)

    def logits_from(self, params, x, mesh):
        x = L.apply_norm(params["ln_f"], x, self.cfg.norm)
        return L.unembed(params["embed"], x, self.cfg, mesh)

    def loss(self, params, batch, mesh):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = self.embed_inputs(params, batch, mesh, positions)
        x, _ = self.backbone(params, x, positions, mesh, "full")
        logits = self.logits_from(params, x, mesh)
        loss = L.softmax_xent(logits, batch["labels"],
                              batch.get("loss_mask"))
        return loss, {"loss": loss}

    def prefill(self, params, batch, mesh):
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = self.embed_inputs(params, batch, mesh, positions)
        x, cache = self.backbone(params, x, positions, mesh, "prefill")
        # optional batch["last"]: the true final-token position when the
        # prompt is right-padded to a bucketed length (serving re-uses one
        # compiled prefill per bucket; causality keeps rows <= last exact)
        last = batch.get("last")
        x_last = x[:, -1:] if last is None else \
            jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)
        logits = self.logits_from(params, x_last, mesh)
        return logits, cache

    def chunk_prefill(self, params, cache, tokens, slot, offset, n_valid,
                      mesh, kv_bound, pages_row=None):
        """One prompt chunk of one request, written straight into a KV pool.

        tokens: (1, c) — a bucketed chunk padded past ``n_valid``; global
        positions are ``[offset, offset + c)``.  ``cache`` is the pool's
        cache tree (contiguous slot layout or page pool; ``pages_row`` is
        the slot's page-table row for the latter).  Returns the logits at
        the chunk's last *valid* position — the next-token logits once the
        final chunk lands — and the updated pool cache with the slot's
        index advanced to ``offset + n_valid``.  ``kv_bound`` is a STATIC
        upper bound (>= offset + c, power-of-two bucketed) on the KV
        prefix the chunk reads back, so short prompts do not pay
        max_len-sized attention.  Causality makes the result independent
        of the bucket padding, and the per-chunk computation is
        row-identical to one whole-prompt prefill, so a chunked ingest is
        token-identical to a blocking one.
        """
        b, c = tokens.shape
        positions = offset + jnp.broadcast_to(
            jnp.arange(c, dtype=jnp.int32), (b, c))
        x = self.embed_inputs(params, {"tokens": tokens}, mesh, positions)
        chunk_cache = {"k": cache["k"], "v": cache["v"],
                       "slot": slot, "offset": offset,
                       "kv_bound": int(kv_bound)}
        if pages_row is not None:
            chunk_cache["pages_row"] = pages_row
        x, new_kv = self.backbone(params, x, positions, mesh, "chunk",
                                  cache=chunk_cache)
        x_last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
        logits = self.logits_from(params, x_last, mesh)
        index = cache["index"].at[slot].set(offset + n_valid)
        return logits, {"k": new_kv["k"], "v": new_kv["v"], "index": index}

    def decode_step(self, params, cache, tokens, mesh):
        b, s = tokens.shape
        idx = cache["index"]
        if jnp.ndim(idx) == 1:      # slot-wise: per-row lengths
            positions = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        else:
            positions = idx + jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (b, s))
        x = L.embed(params["embed"], tokens, self.cfg, mesh, positions=positions)
        x, new_cache = self.backbone(params, x, positions, mesh, "decode",
                                     cache=cache)
        logits = self.logits_from(params, x, mesh)
        return logits, new_cache


def model_for(cfg: ModelConfig, remat: str = "dots") -> BaseLM:
    from repro.models.moe import MoELM
    from repro.models.ssm import XLSTM
    from repro.models.mamba import ZambaHybrid
    from repro.models.encdec import EncDecLM
    from repro.models.vlm import VLM

    cls = {"dense": DenseLM, "moe": MoELM, "ssm_xlstm": XLSTM,
           "hybrid_mamba": ZambaHybrid, "encdec": EncDecLM, "vlm": VLM}[cfg.family]
    return cls(cfg, remat=remat)
