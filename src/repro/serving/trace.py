"""Synthetic request traces for the serving engine and its benchmark.

Real serving traffic is heavy-tailed: many short exchanges, a few long
generations.  ``zipf_trace`` models both the prompt and the generation
lengths with a clipped Zipf draw, which is exactly the regime where
continuous batching beats gang scheduling (a static batch waits for its
longest member).  Prompt lengths are bucketed to powers of two so the
prefill jit cache stays small.
"""

from __future__ import annotations

import numpy as np

from repro.serving.scheduler import Request

PROMPT_BUCKETS = (4, 8, 16, 32, 64, 128)


def _bucket(n: int, max_prompt: int) -> int:
    for b in PROMPT_BUCKETS:
        if n <= b:
            return min(b, max_prompt)
    return max_prompt


def zipf_trace(n: int, vocab_size: int, *, max_prompt: int = 32,
               max_new: int = 32, alpha: float = 1.3, seed: int = 0,
               temperature: float = 0.0, top_k: int = 0) -> list[Request]:
    """n requests with Zipf-distributed prompt/generation lengths."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        plen = _bucket(int(np.clip(rng.zipf(alpha), 1, max_prompt)),
                       max_prompt)
        nnew = int(np.clip(rng.zipf(alpha), 1, max_new))
        prompt = rng.randint(1, max(vocab_size - 1, 2),
                             size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=nnew,
                            temperature=temperature, top_k=top_k))
    return reqs


def longprompt_trace(n: int, vocab_size: int, *, max_prompt: int = 128,
                     max_new: int = 16, alpha: float = 1.5, seed: int = 0,
                     temperature: float = 0.0,
                     top_k: int = 0) -> list[Request]:
    """n requests whose prompt lengths cluster *near* ``max_prompt``.

    The shortfall below max_prompt is the Zipf draw (so most prompts sit
    at the top bucket, a tail reaches down to ~max_prompt/4) and the
    generations are short — the prefill-stall regime: admission cost
    dominates decode cost, which is exactly where blocking prompt
    ingestion serializes the fleet and chunked prefill pays off.
    Deterministic for a fixed seed, like every trace here.
    """
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        short = int(np.clip(rng.zipf(alpha) - 1, 0, max_prompt * 3 // 4))
        plen = _bucket(max_prompt - short, max_prompt)
        nnew = int(np.clip(rng.zipf(alpha), 1, max_new))
        prompt = rng.randint(1, max(vocab_size - 1, 2),
                             size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=nnew,
                            temperature=temperature, top_k=top_k))
    return reqs


def sharedprefix_trace(n: int, vocab_size: int, *, n_heads: int = 4,
                       head_len: int = 32, max_suffix: int = 24,
                       max_new: int = 8, alpha: float = 1.2, seed: int = 0,
                       temperature: float = 0.0,
                       top_k: int = 0) -> list[Request]:
    """n requests whose prompts open with one of ``n_heads`` shared heads.

    Head popularity is Zipf-clustered (head 0 dominates, like a fleet
    where most traffic shares one system preamble and a tail of few-shot
    templates splits the rest), and each request appends a private
    Zipf-length suffix of at least one token.  ``head_len`` defaults to
    two 16-token KV pages, so a page-aligned prefix cache has whole
    pages to reuse — the regime the shared-prefix cache is judged in.
    Deterministic for a fixed seed, like every trace here.
    """
    rng = np.random.RandomState(seed)
    heads = rng.randint(1, max(vocab_size - 1, 2),
                        size=(n_heads, head_len)).astype(np.int32)
    reqs = []
    for i in range(n):
        h = min(int(rng.zipf(alpha)) - 1, n_heads - 1)
        slen = int(np.clip(rng.zipf(alpha), 1, max_suffix))
        suffix = rng.randint(1, max(vocab_size - 1, 2),
                             size=(slen,)).astype(np.int32)
        nnew = int(np.clip(rng.zipf(alpha), 1, max_new))
        reqs.append(Request(rid=i,
                            prompt=np.concatenate([heads[h], suffix]),
                            max_new_tokens=nnew,
                            temperature=temperature, top_k=top_k))
    return reqs


def uniform_trace(n: int, vocab_size: int, *, prompt_len: int = 16,
                  max_new: int = 8, seed: int = 0,
                  temperature: float = 0.0, top_k: int = 0) -> list[Request]:
    """n same-length requests — the static/continuous equivalence case."""
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(1, max(vocab_size - 1, 2),
                                       size=(prompt_len,)).astype(np.int32),
                    max_new_tokens=max_new,
                    temperature=temperature, top_k=top_k)
            for i in range(n)]
