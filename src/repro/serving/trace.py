"""Synthetic request traces for the serving engine and its benchmark.

Real serving traffic is heavy-tailed: many short exchanges, a few long
generations.  ``zipf_trace`` models both the prompt and the generation
lengths with a clipped Zipf draw, which is exactly the regime where
continuous batching beats gang scheduling (a static batch waits for its
longest member).  Prompt lengths are bucketed to powers of two so the
prefill jit cache stays small.

``repetitive_trace`` is the speculative-decoding regime: long greedy
generations whose token streams settle into short cycles (template
expansion, code boilerplate, list continuation), where an n-gram drafter
accepts whole bursts.  ``trace_repetitiveness`` measures a trace's
n-gram self-overlap in [0, 1] — the hint ``launch/serve.py --spec-k
auto`` feeds the tuner's ``plan.serve_spec_k`` pick.

All traces take per-request sampling knobs (``temperature`` / ``top_k``
/ ``top_p``) and are deterministic for a fixed seed.

Traces are *closed-loop* by default (every request available at t=0,
``arrival_vstep == 0``).  ``poisson_arrivals`` / ``bursty_arrivals`` /
``with_arrivals`` stamp open-loop arrival times **on the virtual step
clock** — arrivals, like every latency metric in this stack, are
measured in deterministic virtual steps, never wall-clock — so the same
trace + seed always yields the same arrival schedule.
"""

from __future__ import annotations

import numpy as np

from repro.serving.scheduler import Request

PROMPT_BUCKETS = (4, 8, 16, 32, 64, 128)


def _bucket(n: int, max_prompt: int) -> int:
    for b in PROMPT_BUCKETS:
        if n <= b:
            return min(b, max_prompt)
    return max_prompt


def zipf_trace(n: int, vocab_size: int, *, max_prompt: int = 32,
               max_new: int = 32, alpha: float = 1.3, seed: int = 0,
               temperature: float = 0.0, top_k: int = 0,
               top_p: float = 1.0) -> list[Request]:
    """n requests with Zipf-distributed prompt/generation lengths."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        plen = _bucket(int(np.clip(rng.zipf(alpha), 1, max_prompt)),
                       max_prompt)
        nnew = int(np.clip(rng.zipf(alpha), 1, max_new))
        prompt = rng.randint(1, max(vocab_size - 1, 2),
                             size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=nnew,
                            temperature=temperature, top_k=top_k,
                            top_p=top_p))
    return reqs


def longprompt_trace(n: int, vocab_size: int, *, max_prompt: int = 128,
                     max_new: int = 16, alpha: float = 1.5, seed: int = 0,
                     temperature: float = 0.0, top_k: int = 0,
                     top_p: float = 1.0) -> list[Request]:
    """n requests whose prompt lengths cluster *near* ``max_prompt``.

    The shortfall below max_prompt is the Zipf draw (so most prompts sit
    at the top bucket, a tail reaches down to ~max_prompt/4) and the
    generations are short — the prefill-stall regime: admission cost
    dominates decode cost, which is exactly where blocking prompt
    ingestion serializes the fleet and chunked prefill pays off.
    Deterministic for a fixed seed, like every trace here.
    """
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        short = int(np.clip(rng.zipf(alpha) - 1, 0, max_prompt * 3 // 4))
        plen = _bucket(max_prompt - short, max_prompt)
        nnew = int(np.clip(rng.zipf(alpha), 1, max_new))
        prompt = rng.randint(1, max(vocab_size - 1, 2),
                             size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=nnew,
                            temperature=temperature, top_k=top_k,
                            top_p=top_p))
    return reqs


def sharedprefix_trace(n: int, vocab_size: int, *, n_heads: int = 4,
                       head_len: int = 32, max_suffix: int = 24,
                       max_new: int = 8, alpha: float = 1.2, seed: int = 0,
                       temperature: float = 0.0, top_k: int = 0,
                       top_p: float = 1.0) -> list[Request]:
    """n requests whose prompts open with one of ``n_heads`` shared heads.

    Head popularity is Zipf-clustered (head 0 dominates, like a fleet
    where most traffic shares one system preamble and a tail of few-shot
    templates splits the rest), and each request appends a private
    Zipf-length suffix of at least one token.  ``head_len`` defaults to
    two 16-token KV pages, so a page-aligned prefix cache has whole
    pages to reuse — the regime the shared-prefix cache is judged in.
    Deterministic for a fixed seed, like every trace here.
    """
    rng = np.random.RandomState(seed)
    heads = rng.randint(1, max(vocab_size - 1, 2),
                        size=(n_heads, head_len)).astype(np.int32)
    reqs = []
    for i in range(n):
        h = min(int(rng.zipf(alpha)) - 1, n_heads - 1)
        slen = int(np.clip(rng.zipf(alpha), 1, max_suffix))
        suffix = rng.randint(1, max(vocab_size - 1, 2),
                             size=(slen,)).astype(np.int32)
        nnew = int(np.clip(rng.zipf(alpha), 1, max_new))
        reqs.append(Request(rid=i,
                            prompt=np.concatenate([heads[h], suffix]),
                            max_new_tokens=nnew,
                            temperature=temperature, top_k=top_k,
                            top_p=top_p))
    return reqs


def uniform_trace(n: int, vocab_size: int, *, prompt_len: int = 16,
                  max_new: int = 8, seed: int = 0,
                  temperature: float = 0.0, top_k: int = 0,
                  top_p: float = 1.0) -> list[Request]:
    """n same-length requests — the static/continuous equivalence case."""
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(1, max(vocab_size - 1, 2),
                                       size=(prompt_len,)).astype(np.int32),
                    max_new_tokens=max_new,
                    temperature=temperature, top_k=top_k, top_p=top_p)
            for i in range(n)]


def repetitive_trace(n: int, vocab_size: int, *, prompt_len: int = 8,
                     max_new: int = 48, seed: int = 0,
                     temperature: float = 0.0, top_k: int = 0,
                     top_p: float = 1.0) -> list[Request]:
    """n requests in the draft-then-verify sweet spot: short prompts,
    LONG greedy generations over a small effective vocabulary.

    The prompts cycle a short random period, so both the prompt and (for
    small-vocab models like ``picolm-4-smoke``) the greedy continuation
    are n-gram-predictable — the stand-in for repetitive real text
    (template fill-in, boilerplate, list continuation), which is where a
    history drafter's accepted-tokens/verify-step clears 1.  On a big
    random-init vocab the streams are chaotic and acceptance drops to
    ~chance — exactly the regime the tuner keeps spec off for.
    """
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        period = int(rng.randint(2, 5))
        base = rng.randint(1, max(vocab_size - 1, 2), size=(period,))
        prompt = np.resize(base, prompt_len).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new,
                            temperature=temperature, top_k=top_k,
                            top_p=top_p))
    return reqs


ARRIVAL_MODES = ("closed", "poisson", "bursty")


def poisson_arrivals(requests, *, mean_gap: float = 4.0,
                     seed: int = 0) -> list[Request]:
    """Stamp ``arrival_vstep`` with a Poisson arrival process.

    Inter-arrival gaps are exponential with mean ``mean_gap`` virtual
    steps; arrivals are the floored cumulative sum, so the first request
    can land at vstep 0 and ties are possible (a burst admitted in one
    round).  Mutates and returns ``requests`` in trace order.
    """
    rng = np.random.RandomState(seed)
    t = 0.0
    for req in requests:
        t += float(rng.exponential(mean_gap))
        req.arrival_vstep = int(t)
    return requests


def bursty_arrivals(requests, *, mean_gap: float = 4.0, burst: float = 4.0,
                    period: float = 64.0, seed: int = 0) -> list[Request]:
    """Stamp ``arrival_vstep`` with a diurnally modulated Poisson process.

    The instantaneous rate swings sinusoidally with ``period`` (vsteps):
    at the peak the mean gap is ``mean_gap / burst`` (a rush), at the
    trough it is ``mean_gap`` (quiet) — the day/night shape production
    admission has to absorb.  Deterministic for a fixed seed.
    """
    if burst < 1.0:
        raise ValueError(f"burst must be >= 1, got {burst}")
    rng = np.random.RandomState(seed)
    t = 0.0
    for req in requests:
        phase = 0.5 * (1.0 + np.sin(2.0 * np.pi * t / period))
        local_gap = mean_gap / (1.0 + (burst - 1.0) * phase)
        t += float(rng.exponential(local_gap))
        req.arrival_vstep = int(t)
    return requests


def with_arrivals(requests, mode: str = "closed", *, mean_gap: float = 4.0,
                  seed: int = 0, **kw) -> list[Request]:
    """Dispatch on ``mode`` in ``ARRIVAL_MODES``; ``closed`` zeroes stamps."""
    if mode == "closed":
        for req in requests:
            req.arrival_vstep = 0
        return requests
    if mode == "poisson":
        return poisson_arrivals(requests, mean_gap=mean_gap, seed=seed, **kw)
    if mode == "bursty":
        return bursty_arrivals(requests, mean_gap=mean_gap, seed=seed, **kw)
    raise ValueError(f"unknown arrival mode {mode!r}; "
                     f"choose from {ARRIVAL_MODES}")


def trace_repetitiveness(requests, max_n: int = 3) -> float:
    """Mean n-gram self-overlap of a trace's prompts, in [0, 1].

    For each prompt position past the first ``max_n`` tokens: does the
    ``max_n``-gram ending there occur earlier in the prompt?  The hit
    fraction is exactly the n-gram drafter's hit condition evaluated on
    the only tokens known before generation starts, so it proxies the
    per-draft accept probability — the tuner turns it into
    ``plan.serve_spec_k`` via the napkin estimate in
    ``core/tuning.spec_k_for``.
    """
    hits = total = 0
    for req in requests:
        p = [int(t) for t in np.asarray(req.prompt)]
        for i in range(max_n, len(p)):
            gram = p[i - max_n + 1:i + 1]
            # every earlier start, including the window ending at i-1
            # (j = i - max_n); excluding it undercounts short-period
            # cycles and the tuner picks too-small serve_spec_k
            found = any(p[j:j + max_n] == gram
                        for j in range(i - max_n + 1))
            hits += bool(found)
            total += 1
    return hits / total if total else 0.0
