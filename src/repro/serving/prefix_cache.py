"""Shared-prefix KV page cache — refcounted page-run reuse for paged pools.

EASEY's thesis is layered reuse of prior work: cached container builds,
auto-tuned job configs, generated batch files — each layer turning a
repeated expensive step into a lookup.  One layer down, serving traffic
repeats too: shared-prefix prompts (system preambles, few-shot headers,
templated documents) re-ingest bit-identical KV on every admission.  This
module is the serving analogue of the paper's build cache: a per-replica
map from a prompt-prefix key to a **refcounted run of pages** already
resident in a ``PagedKVCachePool``, so a cache hit turns re-prefill of
the shared prefix into page-table pointer copies — zero chunk steps,
zero KV writes — and only the cold suffix runs through the
``PrefillManager``.

Keying
------
One cache cell per *full page* of a prompt, keyed by the cumulative token
bytes up to that page boundary (``prefix_key(prompt, (i+1) * page_size)``
— the exact bytes ``prefix_affinity`` routing hashes, so routing and
caching can never drift apart).  A probe walks page keys from the front
and returns the longest run of consecutively cached pages, capped at
``(len(prompt) - 1) // page_size`` so at least one suffix token always
goes through prefill (the final chunk's logits seed the first sampled
token).  Chained cumulative keys make nesting free: a prompt sharing
only the first page of a deeper cached prefix still hits that page.

Why whole pages, and why they are safe to share
-----------------------------------------------
KV at position ``j`` depends only on tokens ``[0, j]`` (causal masking
at every layer), so two prompts agreeing on their first ``k`` tokens
have bit-identical KV there — and a page wholly covered by a prompt is
never written again: suffix chunks scatter at positions ``>= done`` and
decode writes at ``index >= prompt_len``, both past the shared run.
(The paged decode step additionally masks inactive slots' page-table
rows to the junk page, so a stale device index can never scribble into
a page another request reads.)

Refcount lifecycle
------------------
``pool.page_refs`` counts owners per page: the allocating request (1),
each later sharer (+1 on ``attach``), and the cache itself (+1 on
``insert``).  ``pool.free(slot)`` *decrements* instead of freeing — a
page returns to the free list only at refcount zero, so a preempted
sharer can never free pages another request still references.  Under
page pressure the pool reclaims here first (``reclaim`` — LRU by probe/
insert stamp, deepest page first within a chain, and **never** while a
request still shares the page, i.e. only at refcount 1) before anyone
is preempted.  ``max_pages`` caps the pages pinned *only* by the cache;
pages also held by live requests cost the cache nothing.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def prefix_key(prompt, n_tokens: int | None = None) -> bytes:
    """Canonical prompt-prefix key: the first ``n_tokens`` token ids as
    little-endian int32 bytes (the whole prompt when ``None``).

    Single source of truth for every prefix keying in the serving stack —
    ``prefix_affinity`` routing and the prefix KV cache hash the same
    bytes, so a prompt that routes by its prefix also caches by it.
    """
    arr = np.ascontiguousarray(np.asarray(prompt, np.int32))
    if n_tokens is not None:
        arr = arr[:n_tokens]
    return arr.tobytes()


@dataclasses.dataclass
class PrefixHit:
    """A probe result: the longest cached page run for a prompt.

    ``pinned`` counts hit pages currently held *only* by the cache —
    attaching converts them from reclaimable to shared, which admission
    accounting must not double-count as spendable headroom."""
    n_tokens: int = 0
    pages: list = dataclasses.field(default_factory=list)
    pinned: int = 0

    def __bool__(self) -> bool:
        return bool(self.pages)


@dataclasses.dataclass
class _Cell:
    page: int                     # pool page id this cell pins
    depth: int                    # page index within its prompt chain
    stamp: int                    # LRU clock at last probe-hit / insert


class PrefixCache:
    """Prefix -> page-run cache over one ``PagedKVCachePool``.

    Construction attaches the cache to the pool (``pool.prefix_cache``),
    which is how the scheduler, prefill manager, and the pool's own
    allocator discover it — no extra plumbing through call sites.
    """

    def __init__(self, pool, max_pages: int = 0):
        if getattr(pool, "layout", None) != "paged":
            raise ValueError(
                "PrefixCache needs a paged pool (page-run sharing has no "
                f"meaning for layout {getattr(pool, 'layout', None)!r})")
        if max_pages < 0:
            raise ValueError(f"max_pages {max_pages} < 0")
        self.pool = pool
        self.page_size = pool.page_size
        self.max_pages = max_pages    # cap on cache-only (refcount-1) pages
        self._cells: dict[bytes, _Cell] = {}
        self._tick = 0
        # observability: the CI gate and the tuner's budget choice are
        # judged on these
        self.hits = 0
        self.misses = 0
        self.tokens_saved = 0         # prefill tokens skipped via hits
        self.inserts = 0
        self.evictions = 0
        # telemetry hook — bound by the scheduler (bind_tracer), None = off
        self.tracer = None
        self.vclock = None
        self.replica_id = 0
        pool.prefix_cache = self

    def bind_tracer(self, tracer, vclock=None, replica_id: int = 0) -> None:
        """Attach the serving tracer so reclaims show up as ring events.
        The scheduler calls this at reset; a None tracer unbinds."""
        self.tracer = tracer
        self.vclock = vclock
        self.replica_id = int(replica_id)

    def __len__(self) -> int:
        return len(self._cells)

    # -- probing -------------------------------------------------------------
    def probe(self, prompt) -> PrefixHit:
        """Longest cached page run for ``prompt`` (read-only: no refcount,
        counter, or LRU mutation — safe to call speculatively from
        ``can_admit`` for every replica)."""
        prompt = np.asarray(prompt, np.int32)
        limit = (len(prompt) - 1) // self.page_size   # >= 1 cold token
        pages = []
        for i in range(limit):
            cell = self._cells.get(prefix_key(prompt, (i + 1) * self.page_size))
            if cell is None:
                break
            pages.append(cell.page)
        pinned = sum(1 for p in pages if self.pool.page_refs[p] == 1)
        return PrefixHit(n_tokens=len(pages) * self.page_size,
                         pages=pages, pinned=pinned)

    # -- request lifecycle ---------------------------------------------------
    def attach(self, slot: int, prompt, hit: PrefixHit | None = None) -> int:
        """Install ``hit``'s page run (probed fresh when not given) as the
        head of ``slot``'s page table, taking a reference on every shared
        page; returns the cached token count (0 on a miss).  Must run
        before ``reserve_prefix`` extends the slot with cold pages."""
        if hit is None:
            hit = self.probe(prompt)
        self._tick += 1
        if not hit:
            self.misses += 1
            return 0
        self.hits += 1
        self.tokens_saved += hit.n_tokens
        prompt = np.asarray(prompt, np.int32)
        for i in range(len(hit.pages)):   # touch for LRU recency
            self._cells[prefix_key(prompt, (i + 1) * self.page_size)] \
                .stamp = self._tick
        self.pool.adopt_run(slot, hit.pages)
        return hit.n_tokens

    def insert(self, prompt, slot: int) -> int:
        """Register every *fully prompt-covered* page of ``slot``'s run
        (a page holding positions past the prompt still takes decode
        writes, so it is mutable and never cacheable).  Called when the
        prompt's final chunk lands — the run is fully written and can
        only be read from here on.  Returns pages newly pinned."""
        prompt = np.asarray(prompt, np.int32)
        self._tick += 1
        fresh = 0
        for i in range(len(prompt) // self.page_size):
            key = prefix_key(prompt, (i + 1) * self.page_size)
            cell = self._cells.get(key)
            if cell is not None:          # already cached (possibly by a
                cell.stamp = self._tick   # concurrent miss) — just touch
                continue
            page = int(self.pool.page_table[slot, i])
            self.pool.pin_page(page)
            self._cells[key] = _Cell(page=page, depth=i, stamp=self._tick)
            fresh += 1
        self.inserts += 1
        self.enforce_budget()
        return fresh

    def enforce_budget(self) -> None:
        """LRU back under the tuner's pin cap.  Called after every insert
        and after every ``pool.free`` — the two moments pages can become
        cache-only (a request releasing its references turns shared
        pages into pinned ones without touching the cache directly)."""
        if not self.max_pages:
            return
        over = self.reclaimable_pages - self.max_pages
        if over > 0:
            self.reclaim(over)

    # -- page-pressure eviction ----------------------------------------------
    @property
    def reclaimable_pages(self) -> int:
        """Pages the cache could hand back right now: cells whose page no
        live request shares (refcount exactly 1 — the cache's own).
        O(1): the pool maintains the count on refcount transitions."""
        return self.pool.reclaimable_pages

    def reclaim(self, n_pages: int) -> int:
        """Evict LRU cells until ``n_pages`` pages returned to the free
        list (or nothing evictable remains).  Never evicts a cell whose
        page a request still references (refcount > 1); within one
        stamp (a chain inserted together) the deepest page goes first,
        so surviving chains stay probe-reachable from the front."""
        freed = 0
        refs = self.pool.page_refs
        while freed < n_pages:
            victim = None
            for key, cell in self._cells.items():
                if refs[cell.page] != 1:
                    continue
                if victim is None or \
                        (cell.stamp, -cell.depth, key) < \
                        (victim[1].stamp, -victim[1].depth, victim[0]):
                    victim = (key, cell)
            if victim is None:
                break
            del self._cells[victim[0]]
            self.pool.unpin_page(victim[1].page)
            self.evictions += 1
            freed += 1
        if freed and self.tracer is not None:
            self.tracer.instant(
                "prefix_reclaim",
                self.vclock.t if self.vclock is not None else 0,
                replica=self.replica_id, pages=freed,
                asked=n_pages, cells_left=len(self._cells))
        return freed
