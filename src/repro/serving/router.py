"""ReplicaRouter — N serving engines behind one admission queue.

The single-engine stack tunes one KV pool from one HBM budget; the
north-star traffic level needs the same automatic sizing across a fleet.
The router fronts N ``ServeEngine`` replicas (mixed KV layouts allowed —
e.g. two paged and one contiguous) and owns admission:

* every request enters a router-level FIFO;
* a **routing policy** picks the replica for the queue head among the
  replicas that can admit it *right now* (``pool.can_admit``):

  - ``round_robin``      — ring order, skipping full replicas;
  - ``least_loaded``     — the replica with the most free KV *tokens*
                           (``pool.free_tokens`` — worst-case slots for
                           contiguous pools, free pages for paged ones);
  - ``prefix_affinity``  — rendezvous (highest-random-weight) hash of the
                           prompt prefix, so likely-shared prefixes land
                           on the same replica and the mapping is *stable
                           under replica count*: adding a replica only
                           moves the keys that move to it.

* a replica that cannot take the head does not reject it — the request
  **waits in the router queue** (overflow queuing) until capacity frees;
* with chunked prefill (the engine default), dispatch only *reserves* a
  replica's slot/pages and queues the prompt's chunks there: the replica
  ingests at most one chunk budget per lockstep round while still taking
  its decode tick, so replica A's prompt ingestion overlaps B/C's decode
  — the serialization the blocking lockstep loop suffered (every
  admission ran its whole prefill on the driver thread before any
  replica could step) is gone.  ``least_loaded`` charges a replica's
  queued-but-unprocessed chunk backlog against its free tokens
  (``Scheduler.free_tokens``), so a mid-ingest replica stops looking as
  free as an idle one;
* a replica's ``PoolExhausted``-grade starvation (the sole resident
  request needs a page the pool cannot supply) **re-routes** instead of
  rejecting: the scheduler evicts the request
  (``step(evict_on_starvation=True)``) and the router re-dispatches it,
  preferring a replica whose pool can actually hold its worst case.
  Re-prefill resume keeps the token stream exactly as an uninterrupted
  run would have produced it, so routing never changes output — an N=1
  router is token-identical to a bare ``ServeEngine``.

The run loop is lockstep and host-driven: each tick dispatches from the
router queue, then advances every busy replica by one slot-wise decode
step.  Everything is deterministic for a fixed trace, fleet, and policy.

Replica lists may repeat the *same* ``ServeEngine`` object: each run
builds a fresh pool + scheduler per replica slot, so duplicates share
jitted steps and weights (one compile) while keeping independent KV
state — the cheap way to spin up N homogeneous replicas.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque

import numpy as np

from repro.serving.pool import PoolExhausted
from repro.serving.prefix_cache import prefix_key
from repro.serving.sampling import K_CAP
from repro.serving.scheduler import (RoundClock, Scheduler, VirtualClock,
                                     _Entry)

ROUTE_POLICIES = ("round_robin", "least_loaded", "prefix_affinity")


def prefix_replica(prompt, n_replicas: int, prefix_len: int = 8) -> int:
    """Rendezvous hash of the prompt prefix over ``n_replicas``.

    Every (prefix, replica) pair gets an independent deterministic score
    (SHA-256 — stable across processes, unlike ``hash()``); the replica
    with the highest score wins.  Growing the fleet from N to N+1 only
    ever moves a prefix *to the new replica*, never between survivors.
    The hashed bytes are ``prefix_key`` — the same key the per-replica
    prefix KV cache uses, so a prompt that routes by its prefix lands on
    the replica whose cache holds that prefix.
    """
    if n_replicas < 1:
        raise ValueError(n_replicas)
    key = prefix_key(prompt, prefix_len)
    return max(range(n_replicas), key=lambda i: _affinity_score(key, i))


def _affinity_score(key: bytes, replica: int) -> int:
    h = hashlib.sha256(key + replica.to_bytes(4, "little")).digest()
    return int.from_bytes(h[:8], "little")


@dataclasses.dataclass
class RouterStats:
    """Fleet-level drain statistics plus the per-replica breakdown."""
    results: list                  # merged RequestResults, sorted by rid
    replica_stats: list            # per-replica ServeStats
    replica_of: dict               # rid -> index of the completing replica
    wall_s: float
    reroutes: int = 0              # starvation evictions re-dispatched
    peak_in_flight: int = 0        # max concurrent requests, fleet-wide

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.results)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)

    @property
    def imbalance(self) -> float:
        """Load imbalance: max/mean of per-replica peak resident KV tokens
        (1.0 = perfectly balanced; only meaningful for N > 1).  A fleet
        that saw no traffic at all has no balance to speak of — that is
        ``nan``, not a fake-perfect 1.0 a dashboard would wave through."""
        peaks = [s.peak_resident_tokens for s in self.replica_stats]
        mean = sum(peaks) / max(len(peaks), 1)
        return max(peaks) / mean if mean > 0 else float("nan")

    @property
    def mean_ttft_steps(self) -> float:
        """Mean time-to-first-token on the fleet's shared virtual step
        clock — the deterministic proxy blocking-vs-chunked prefill is
        compared on."""
        ttfts = [r.ttft_steps for r in self.results if r.v_first >= 0]
        return float(np.mean(ttfts)) if ttfts else 0.0

    @property
    def prefill_chunks(self) -> int:
        return sum(s.prefill_chunks for s in self.replica_stats)

    @property
    def prefill_tokens(self) -> int:
        """Prompt tokens the fleet actually ran through chunk steps —
        cache hits shrink this without touching the token streams."""
        return sum(s.prefill_tokens for s in self.replica_stats)

    @property
    def prefix_hits(self) -> int:
        return sum(s.prefix_hits for s in self.replica_stats)

    @property
    def prefix_misses(self) -> int:
        return sum(s.prefix_misses for s in self.replica_stats)

    @property
    def prefill_tokens_saved(self) -> int:
        return sum(s.prefill_tokens_saved for s in self.replica_stats)

    @property
    def prefix_hit_rate(self) -> float:
        n = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / n if n else 0.0

    @property
    def overlap_steps(self) -> int:
        """Scheduler ticks, fleet-wide, that ingested a prompt chunk AND
        decoded — the overlap chunked prefill exists to create."""
        return sum(s.overlap_steps for s in self.replica_stats)

    @property
    def spec_verify_steps(self) -> int:
        return sum(s.spec_verify_steps for s in self.replica_stats)

    @property
    def spec_drafted_tokens(self) -> int:
        return sum(s.spec_drafted_tokens for s in self.replica_stats)

    @property
    def spec_accepted_tokens(self) -> int:
        return sum(s.spec_accepted_tokens for s in self.replica_stats)

    @property
    def accepted_per_verify(self) -> float:
        """Fleet-wide tokens emitted per speculative verify event — the
        same >1-means-spec-pays figure as ``ServeStats``, summed over
        replicas before the ratio so busy and idle replicas weight by
        their actual verify traffic."""
        if not self.spec_verify_steps:
            return 0.0
        return ((self.spec_verify_steps + self.spec_accepted_tokens)
                / self.spec_verify_steps)

    @property
    def effective_top_k(self) -> dict:
        """rid -> effective top-k, merged across replicas (a rid completes
        on exactly one replica, so the union is disjoint)."""
        out: dict = {}
        for s in self.replica_stats:
            out.update(s.effective_top_k)
        return out

    def summary(self) -> str:
        per = ", ".join(f"r{i}:{s.generated_tokens}t"
                        for i, s in enumerate(self.replica_stats))
        re = f", {self.reroutes} reroutes" if self.reroutes else ""
        if self.prefix_hits:
            re += (f", {self.prefix_hits} prefix hits "
                   f"({self.prefill_tokens_saved}t prefill saved)")
        if self.spec_verify_steps:
            re += (f", spec {self.accepted_per_verify:.2f} tok/verify "
                   f"({self.spec_accepted_tokens}/"
                   f"{self.spec_drafted_tokens} drafts accepted)")
        return (f"{len(self.results)} requests over "
                f"{len(self.replica_stats)} replicas, "
                f"{self.generated_tokens} tokens in {self.wall_s:.3f}s -> "
                f"{self.tokens_per_s:.1f} tok/s fleet | peak "
                f"{self.peak_in_flight} in flight, imbalance "
                f"{self.imbalance:.2f}{re} | {per}")


class ReplicaRouter:
    """Route request traces across N ``ServeEngine`` replicas."""

    def __init__(self, engines, policy: str = "least_loaded",
                 prefix_len: int = 8, log=print,
                 clock=time.perf_counter):
        engines = list(engines)
        if not engines:
            raise ValueError("router needs at least one replica engine")
        if policy not in ROUTE_POLICIES:
            raise ValueError(f"policy {policy!r} not in {ROUTE_POLICIES}")
        names = {e.cfg.name for e in engines}
        if len(names) > 1:
            raise ValueError(
                f"replicas must share one architecture, got {sorted(names)}")
        lens = {e.max_len for e in engines}
        if len(lens) > 1:
            # max_len clamps a request's generation budget at admission
            # (sticky on the result), so a mixed-max_len fleet would make
            # output depend on which replica the policy picked
            raise ValueError(
                f"replicas must share one max_len, got {sorted(lens)}")
        # same failure class: eos decides when a stream stops, the seed
        # decides weights and sampler draws — either differing per replica
        # would make output depend on the routing decision
        eos = {e.eos_id for e in engines}
        if len(eos) > 1:
            raise ValueError(
                f"replicas must share one eos_id, got {sorted(map(str, eos))}")
        seeds = {e.seed for e in engines}
        if len(seeds) > 1:
            raise ValueError(
                f"replicas must share one seed, got {sorted(seeds)}")
        self.engines = engines
        self.policy = policy
        self.prefix_len = prefix_len
        self.log = log
        self.clock = clock

    @classmethod
    def build(cls, arch: str = "deepseek-7b-smoke",
              target: str = "local:cpu", replicas: int = 2,
              kv_layout: str = "contiguous", num_slots: int = 8,
              max_len: int = 128, seed: int = 0, eos_id: int | None = None,
              policy: str = "least_loaded", page_size: int = 0,
              num_pages: int = 0, prefill_chunk: int | None = None,
              prefix_cache: bool = False, kv_kernel: str = "auto",
              spec_k: int | None = 0, drafter=None,
              repetitiveness: float = 0.0, log=print) -> "ReplicaRouter":
        """Build an N-replica fleet, splitting the tuner budget N ways.

        ``kv_layout`` may be comma-separated (``"paged,contiguous"``) and
        is cycled across replica slots — one engine is built per distinct
        layout and *shared* between its slots (jitted steps and weights
        compile once; pools stay per-replica).
        """
        from repro.serving.engine import ServeEngine
        if replicas < 1:
            raise ValueError(f"replicas {replicas} < 1")
        layouts = [l.strip() for l in kv_layout.split(",") if l.strip()]
        if not layouts:
            raise ValueError(f"no kv layout in {kv_layout!r}")
        built: dict[str, object] = {}
        fleet = []
        for i in range(replicas):
            lay = layouts[i % len(layouts)]
            if lay not in built:
                built[lay] = ServeEngine(
                    arch=arch, target=target, num_slots=num_slots,
                    max_len=max_len, seed=seed, eos_id=eos_id,
                    kv_layout=lay, page_size=page_size, num_pages=num_pages,
                    replicas=replicas, prefill_chunk=prefill_chunk,
                    # mixed fleets: the cache / fused decode kernel only
                    # apply to paged slots
                    prefix_cache=prefix_cache and lay == "paged",
                    kv_kernel=kv_kernel if lay == "paged" else "auto",
                    spec_k=spec_k, drafter=drafter,
                    repetitiveness=repetitiveness, log=log)
            fleet.append(built[lay])
        return cls(fleet, policy=policy, log=log)

    # -- validation ---------------------------------------------------------
    def _validate(self, requests, scheds) -> None:
        """Router-level fail-fast: a request is serveable if *some* replica
        can ever hold it (the single-engine rules, any-replica quantified)."""
        for req in requests:
            if not 0 <= req.top_k <= K_CAP:
                raise ValueError(
                    f"request {req.rid}: top_k {req.top_k} not in "
                    f"[0, {K_CAP}]")
            top_p = getattr(req, "top_p", 1.0)
            if not 0.0 < top_p <= 1.0:
                raise ValueError(
                    f"request {req.rid}: top_p {top_p} not in (0, 1]")
            if all(len(req.prompt) > s.pool.max_len for s in scheds):
                raise ValueError(
                    f"request {req.rid}: prompt ({len(req.prompt)}) does "
                    f"not fit any replica's max_len")
            en = _Entry(req)
            if not any(s.pool.can_ever_serve(s.worst_resident(en))
                       for s in scheds):
                raise PoolExhausted(
                    f"request {req.rid} needs "
                    f"{min(s.worst_resident(en) for s in scheds)} resident "
                    f"KV tokens but no replica can ever hold that many")

    # -- policy -------------------------------------------------------------
    def _pick(self, entry: _Entry, ready: list[int], scheds) -> int:
        if self.policy == "round_robin":
            n = len(scheds)
            ready_set = set(ready)
            for off in range(n):
                i = (self._rr + off) % n
                if i in ready_set:
                    self._rr = (i + 1) % n
                    return i
        if self.policy == "least_loaded":
            # most free KV tokens wins; ties go to the lowest index.  The
            # scheduler-level figure charges a replica's queued prefill
            # chunks against its pool capacity, so a replica mid-ingest
            # does not masquerade as free
            return max(ready, key=lambda i: (scheds[i].free_tokens, -i))
        # prefix_affinity: highest rendezvous score among the admittable —
        # the preferred replica when it has room, its runner-up otherwise.
        # Keyed by prefix_key, the same bytes the per-replica prefix KV
        # cache hashes, so sharers colocate with their cached run.
        key = prefix_key(entry.req.prompt, self.prefix_len)
        return max(ready, key=lambda i: _affinity_score(key, i))

    # -- dispatch ------------------------------------------------------------
    def _worst_for(self, sched, entry) -> int:
        """Residency bound used to place `entry` on `sched`'s replica.

        A starvation-evicted (rerouted) entry just proved a pool holding
        nothing else cannot finish it, so it must land where its FULL
        remaining generation fits — the optimistic eos bound
        (``worst_resident`` = pending only) would keep the starved
        replica "feasible" and let the fleet grind one token per
        re-prefill bounce instead of re-routing or failing fast."""
        if entry.rerouted:
            return min(entry.pending_len + entry.remaining_new() - 1,
                       sched.pool.max_len)
        return sched.worst_resident(entry)

    def _dispatch(self, queue: deque, scheds, accepting) -> bool:
        """Admit from the queue head while some accepting replica has room
        (head-of-line, like the single-engine scheduler).  Returns whether
        anything was admitted."""
        progressed = False
        while queue:
            entry = queue[0]
            feasible = [i for i in accepting
                        if scheds[i].pool.can_ever_serve(
                            self._worst_for(scheds[i], entry))]
            if not any(
                    s.pool.can_ever_serve(self._worst_for(s, entry))
                    for s in scheds):
                raise PoolExhausted(
                    f"request {entry.req.rid} ({entry.pending_len} resident "
                    f"tokens) can no longer fit any replica's pool")
            ready = [i for i in feasible if scheds[i].can_admit(entry)]
            if not ready:
                return progressed
            idx = self._pick(entry, ready, scheds)
            if not scheds[idx].try_admit(entry):
                return progressed   # unreachable: `ready` just re-checked
            queue.popleft()
            progressed = True
        return progressed

    # -- main loop -----------------------------------------------------------
    def run(self, requests, policy: str = "continuous",
            prefill_chunk: int | None = None,
            prefix_cache: bool | None = None) -> RouterStats:
        """Drain `requests` across the fleet under scheduling `policy`
        (``continuous`` refills replicas between steps; ``static`` gang-
        fills only idle replicas).  Fresh pools per run, like the engine.

        ``prefill_chunk`` overrides every replica's prompt-ingestion
        grain (None: each engine's own setting; 0: blocking full-prompt
        prefill at dispatch — the old fleet-stalling cadence, kept as
        the TTFT baseline).  ``prefix_cache`` likewise overrides the
        per-replica shared-prefix KV cache (None: each engine's own
        setting) — caches are per replica, which composes with
        ``prefix_affinity`` colocating sharers on one replica.  In a
        mixed-layout fleet the override applies to the paged replicas
        only; contiguous pools have no pages to share.

        The fleet shares one virtual step clock: blocking prefills at
        dispatch advance it serially (they run one after another on the
        driver thread, stalling every replica), while each round's
        parallel work advances it by the busiest replica's invocation
        count — replicas are independent hosts, so a round costs the max,
        not the sum."""
        requests = list(requests)
        shared = VirtualClock()
        scheds = [Scheduler(e.make_pool(prefix_cache=(
                                prefix_cache if e.kv_layout == "paged"
                                else None)),
                            e.prefill_fn, e.decode_fn,
                            eos_id=e.eos_id, policy=policy,
                            sampler=e.sampler, clock=self.clock,
                            chunk_step_fn=getattr(e, "chunk_fn", None),
                            prefill_chunk=(getattr(e, "prefill_chunk", 0)
                                           if prefill_chunk is None
                                           else prefill_chunk),
                            prefill_chunk_unit=getattr(e, "chunk_unit", 16),
                            verify_fn=(e.verify_fn
                                       if getattr(e, "spec_k", 0) else None),
                            spec_k=getattr(e, "spec_k", 0),
                            drafter=getattr(e, "drafter", None),
                            vocab_size=e.cfg.vocab_size,
                            vclock=RoundClock(shared))
                  for e in self.engines]
        self._validate(requests, scheds)
        all_greedy = all(r.temperature <= 0 or r.top_k == 1
                         for r in requests)
        t0 = self.clock()
        for s in scheds:
            s.all_greedy = all_greedy
            s.reset(t0)
        for r in requests:
            r._t_submit = t0
        queue: deque = deque(_Entry(r) for r in requests)
        self._rr = 0
        reroutes = 0
        peak_in_flight = 0
        while queue or any(s.active or s.prefill_backlog for s in scheds):
            if policy == "continuous":
                accepting = list(range(len(scheds)))
            else:      # static: gang-fill only replicas idle at phase start
                # (mid-prefill counts as busy — its gang is still forming)
                accepting = [i for i, s in enumerate(scheds)
                             if not (s.active or s.prefill_backlog)]
            progressed = self._dispatch(queue, scheds, accepting)
            in_flight = sum(s.in_flight for s in scheds)
            peak_in_flight = max(peak_in_flight, in_flight)
            stepped = False
            for s in scheds:
                # a replica mid-prefill still takes its tick: it ingests
                # the next chunk AND decodes its active slots — prompt
                # ingestion on one replica no longer stalls the others
                if not (s.active or s.prefill_backlog):
                    continue
                stepped = True
                # solo page starvation: evict for re-route (front of the
                # router queue, like a local preemption resume); marked so
                # dispatch places it by the pessimistic residency bound
                for en in reversed(s.step(evict_on_starvation=True)):
                    en.rerouted = True
                    reroutes += 1
                    queue.appendleft(en)
                # ordinary preemptions also resume through the router, so
                # a request squeezed out of one replica may land on another
                while s.queue:
                    queue.appendleft(s.queue.pop())
            # the round costs what the busiest replica did this round
            shared.advance(max((s.vclock.take() for s in scheds), default=0))
            if not stepped and not progressed:
                en = queue[0]
                raise PoolExhausted(
                    f"request {en.req.rid} ({en.pending_len} tokens) cannot "
                    f"be admitted into an otherwise idle fleet — every "
                    f"replica's pool is too small for it")

        wall = self.clock() - t0
        stats = [s.stats() for s in scheds]
        replica_of = {r.rid: i for i, s in enumerate(stats)
                      for r in s.results}
        results = sorted((r for s in stats for r in s.results),
                         key=lambda r: r.rid)
        out = RouterStats(results=results, replica_stats=stats,
                          replica_of=replica_of, wall_s=wall,
                          reroutes=reroutes, peak_in_flight=peak_in_flight)
        self.log(f"[route:{self.policy}:{policy}] {out.summary()}")
        return out
