"""ReplicaRouter — N serving engines behind one admission queue.

The single-engine stack tunes one KV pool from one HBM budget; the
north-star traffic level needs the same automatic sizing across a fleet.
The router fronts N ``ServeEngine`` replicas (mixed KV layouts allowed —
e.g. two paged and one contiguous) and owns admission:

* every request enters a router-level FIFO;
* a **routing policy** picks the replica for the queue head among the
  replicas that can admit it *right now* (``pool.can_admit``):

  - ``round_robin``      — ring order, skipping full replicas;
  - ``least_loaded``     — the replica with the most free KV *tokens*
                           (``pool.free_tokens`` — worst-case slots for
                           contiguous pools, free pages for paged ones);
  - ``prefix_affinity``  — rendezvous (highest-random-weight) hash of the
                           prompt prefix, so likely-shared prefixes land
                           on the same replica and the mapping is *stable
                           under replica count*: adding a replica only
                           moves the keys that move to it.

* a replica that cannot take the head does not reject it — the request
  **waits in the router queue** (overflow queuing) until capacity frees;
* with chunked prefill (the engine default), dispatch only *reserves* a
  replica's slot/pages and queues the prompt's chunks there: the replica
  ingests at most one chunk budget per lockstep round while still taking
  its decode tick, so replica A's prompt ingestion overlaps B/C's decode
  — the serialization the blocking lockstep loop suffered (every
  admission ran its whole prefill on the driver thread before any
  replica could step) is gone.  ``least_loaded`` charges a replica's
  queued-but-unprocessed chunk backlog against its free tokens
  (``Scheduler.free_tokens``), so a mid-ingest replica stops looking as
  free as an idle one;
* a replica's ``PoolExhausted``-grade starvation (the sole resident
  request needs a page the pool cannot supply) **re-routes** instead of
  rejecting: the scheduler evicts the request
  (``step(evict_on_starvation=True)``) and the router re-dispatches it,
  preferring a replica whose pool can actually hold its worst case.
  Re-prefill resume keeps the token stream exactly as an uninterrupted
  run would have produced it, so routing never changes output — an N=1
  router is token-identical to a bare ``ServeEngine``.

The run loop is lockstep and host-driven: each tick dispatches from the
router queue, then advances every busy replica by one slot-wise decode
step.  Everything is deterministic for a fixed trace, fleet, and policy.

Replica lists may repeat the *same* ``ServeEngine`` object: each run
builds a fresh pool + scheduler per replica slot, so duplicates share
jitted steps and weights (one compile) while keeping independent KV
state — the cheap way to spin up N homogeneous replicas.

**Open-loop traffic.**  Requests carry ``arrival_vstep`` (stamped by
``serving/trace.poisson_arrivals`` / ``bursty_arrivals``): the router
releases a request into its admission queue only once the fleet's shared
virtual step clock reaches the arrival, and an idle fleet with only
future arrivals fast-forwards the clock to the next one.  Because the
samplers key on (request id, generation step), admission *timing* never
changes token streams — an open-loop run is bit-identical to a
closed-loop replay of the same requests.

**SLO-aware admission** (``admission="reject"`` + ``slo_ttft_steps`` /
``slo_e2e_steps``): each round, queued fresh requests are held against
the tuner's TTFT napkin (``core/tuning.ttft_napkin_steps``: steps
already waited + the accepting replicas' prefill backlog share + the
request's own chunk cost); one predicted to blow its deadline is
rejected-with-reason (``RouterStats.rejected``) instead of queued
forever.  Preempted/rerouted entries already hold tokens and are never
rejected.  All deadlines are virtual steps — wall-clock never judges an
SLO.

**Autoscaling** (``autoscale=AutoscalePolicy(...)``): the fleet starts
at ``min_replicas`` serving replicas (the rest dormant) and, once per
``cooldown_rounds``, grows one replica when the queue is
``up_queue_depth`` deep or the queue head's predicted TTFT exceeds
``slo_headroom`` x the TTFT deadline; after ``drain_idle_rounds`` quiet
rounds it *drains* the highest-index serving replica — the replica
stops admitting but keeps stepping until its in-flight requests finish
(never dropped, never migrated mid-stream), then parks dormant.  Every
transition resizes the fleet's admission cap through
``runtime/elastic.rebalance_batch_size`` (the same resize scaffolding
training elasticity uses) and is recorded as an ``AutoscaleEvent``.

``RouterStats.to_metrics()`` flattens a drain into one flat dict of
gauge/counter snapshots a dashboard could scrape.  Key schema (all
values plain numbers; virtual-step gauges are NaN when nothing
completed — JSON writers map NaN to null):

=============================  =======  ================================
key                            kind     meaning
=============================  =======  ================================
router_requests_completed      counter  requests fully served
router_requests_rejected       counter  SLO admission rejections
router_generated_tokens        counter  tokens emitted fleet-wide
router_goodput_tokens          counter  tokens from requests meeting SLO
router_slo_ttft_steps          gauge    TTFT deadline judged by (0=unset)
router_slo_e2e_steps           gauge    e2e deadline judged by (0=unset)
router_ttft_p50_steps          gauge    median TTFT, virtual steps
router_ttft_p99_steps          gauge    p99 TTFT, virtual steps
router_e2e_p50_steps           gauge    median e2e latency, virtual steps
router_e2e_p99_steps           gauge    p99 e2e latency, virtual steps
router_mean_ttft_steps         gauge    mean TTFT, virtual steps
router_total_vsteps            counter  shared clock at drain end
router_peak_in_flight          gauge    max concurrent requests
router_peak_replicas           gauge    max replicas serving/draining
router_reroutes                counter  starvation re-dispatches
router_autoscale_grows         counter  replicas activated
router_autoscale_drains        counter  drains initiated
router_load_imbalance          gauge    max/mean peak resident KV tokens
router_wall_s                  gauge    wall time (ADVISORY only)
router_tokens_per_s            gauge    wall throughput (ADVISORY only)
replica{i}_generated_tokens    counter  per-replica tokens
replica{i}_decode_steps        counter  per-replica scheduler ticks
replica{i}_peak_resident_kv    gauge    per-replica peak resident tokens
replica{i}_preemptions         counter  per-replica page-pressure evicts
replica{i}_occupancy           gauge    per-replica mean slot occupancy
=============================  =======  ================================
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque

import numpy as np

from repro.core.tuning import ttft_napkin_steps
from repro.runtime.elastic import rebalance_batch_size
from repro.serving.pool import PoolExhausted
from repro.serving.prefix_cache import prefix_key
from repro.serving.sampling import K_CAP
from repro.serving.scheduler import (RoundClock, Scheduler, VirtualClock,
                                     _Entry, percentile_steps)

ROUTE_POLICIES = ("round_robin", "least_loaded", "prefix_affinity")
ADMISSION_MODES = ("queue", "reject")


@dataclasses.dataclass
class RejectedRequest:
    """An SLO admission rejection — returned instead of silent queueing."""
    rid: int
    reason: str
    v_reject: int                  # shared virtual clock at rejection
    predicted_ttft_steps: int      # the napkin figure that condemned it


@dataclasses.dataclass
class AutoscaleEvent:
    """One fleet-size transition, stamped on the shared virtual clock."""
    vstep: int
    action: str                    # "grow" | "drain" | "stop"
    replica: int
    serving: int                   # actively-admitting replicas after it
    per_replica_cap: int           # admission cap from rebalance_batch_size


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Deterministic grow/drain policy for an elastic router fleet."""
    min_replicas: int = 1
    max_replicas: int = 0          # 0 = the whole fleet may activate
    up_queue_depth: int = 2        # queued requests that trigger a grow
    cooldown_rounds: int = 4       # min rounds between scaling decisions
    drain_idle_rounds: int = 8     # empty-queue rounds before a drain
    slo_headroom: float = 0.8      # grow when predicted TTFT > this x SLO


class _Autoscaler:
    """Replica lifecycle (active / draining / dormant) for one drain.

    Grow activates the lowest-index non-active replica (a draining one —
    still warm — beats a dormant one); drain marks the highest-index
    active replica: it leaves the accepting set but keeps stepping until
    its in-flight requests finish in place, then parks dormant.  Every
    transition re-derives the per-replica admission cap by pushing the
    fleet's slot budget through ``rebalance_batch_size`` — the same
    keep-the-global-batch resize semantics training elasticity uses.
    """

    def __init__(self, pol: AutoscalePolicy, scheds, shared, tracer=None):
        n = len(scheds)
        self.max_r = pol.max_replicas or n
        if not 1 <= pol.min_replicas <= self.max_r <= n:
            raise ValueError(
                f"autoscale needs 1 <= min_replicas {pol.min_replicas} <= "
                f"max_replicas {self.max_r} <= fleet size {n}")
        self.pol = pol
        self.scheds = scheds
        self.shared = shared
        self.state = ["active" if i < pol.min_replicas else "dormant"
                      for i in range(n)]
        self.fleet_slots = sum(s.pool.num_slots for s in scheds)
        self.events: list[AutoscaleEvent] = []
        self.tracer = tracer
        # a fresh fleet may scale immediately; cooldown gates *subsequent*
        # moves so one burst cannot slam the fleet to max in one round
        self.rounds_since_scale = pol.cooldown_rounds
        self.idle_rounds = 0
        self.per_cap, _ = rebalance_batch_size(
            self.fleet_slots, n, max(self.serving, 1), allow_shrink=True)

    @property
    def serving(self) -> int:
        return sum(1 for st in self.state if st == "active")

    @property
    def working(self) -> int:
        """Replicas doing work: admitting or draining (not dormant)."""
        return sum(1 for st in self.state if st != "dormant")

    def accepting(self) -> list[int]:
        return [i for i, st in enumerate(self.state) if st == "active"]

    def _scale(self, action: str, idx: int, new_state: str) -> None:
        old = max(self.serving, 1)
        self.state[idx] = new_state
        self.per_cap, _ = rebalance_batch_size(
            self.fleet_slots, old, max(self.serving, 1), allow_shrink=True)
        self.events.append(AutoscaleEvent(
            vstep=self.shared.t, action=action, replica=idx,
            serving=self.serving, per_replica_cap=self.per_cap))
        if self.tracer is not None:
            self.tracer.instant(f"autoscale_{action}", self.shared.t,
                                replica=idx, serving=self.serving,
                                per_replica_cap=self.per_cap)
        self.rounds_since_scale = 0

    def try_grow(self) -> bool:
        """Activate one more replica if the cap allows; False at max."""
        if self.serving >= self.max_r:
            return False
        for want in ("draining", "dormant"):
            for i, st in enumerate(self.state):
                if st == want:
                    self._scale("grow", i, "active")
                    return True
        return False

    def tick(self, queue_depth: int, predicted_ttft: int | None,
             slo_ttft_steps: int) -> None:
        """One per-round scaling decision (after dispatch, so the depth
        seen is what the current fleet genuinely could not place)."""
        self.rounds_since_scale += 1
        self.idle_rounds = 0 if queue_depth else self.idle_rounds + 1
        for i, st in enumerate(self.state):
            if st == "draining" and not self.scheds[i].has_work:
                # drained dry: park it (cooldown untouched — finishing a
                # drain is completion, not a new decision)
                self.state[i] = "dormant"
                self.events.append(AutoscaleEvent(
                    vstep=self.shared.t, action="stop", replica=i,
                    serving=self.serving, per_replica_cap=self.per_cap))
                if self.tracer is not None:
                    self.tracer.instant("autoscale_stop", self.shared.t,
                                        replica=i, serving=self.serving,
                                        per_replica_cap=self.per_cap)
        if self.rounds_since_scale < self.pol.cooldown_rounds:
            return
        if queue_depth:
            overloaded = queue_depth >= self.pol.up_queue_depth or (
                slo_ttft_steps > 0 and predicted_ttft is not None and
                predicted_ttft > self.pol.slo_headroom * slo_ttft_steps)
            if overloaded:
                self.try_grow()
            return
        if self.idle_rounds >= self.pol.drain_idle_rounds and \
                self.serving > self.pol.min_replicas:
            idx = max(i for i, st in enumerate(self.state)
                      if st == "active")
            self._scale("drain", idx, "draining")


def replay_peak_replicas(events, min_replicas: int) -> int:
    """Reconstruct ``RouterStats.peak_replicas`` from the AutoscaleEvent
    log alone — the audit that the event stream is complete: every fleet
    transition must appear, or the replayed peak diverges from the live
    counter.  Start state is ``min_replicas`` active (replicas 0..min-1,
    by construction); grow re-activates a draining replica or wakes a
    dormant one, drain moves active -> draining (still working), stop
    parks a drained-dry replica dormant."""
    active = set(range(min_replicas))
    draining: set = set()
    peak = len(active)
    for e in events:
        if e.action == "grow":
            draining.discard(e.replica)
            active.add(e.replica)
        elif e.action == "drain":
            active.discard(e.replica)
            draining.add(e.replica)
        elif e.action == "stop":
            draining.discard(e.replica)
        else:
            raise ValueError(f"unknown autoscale action {e.action!r}")
        if len(active) != e.serving:
            raise ValueError(
                f"event log inconsistent at vstep {e.vstep}: replay has "
                f"{len(active)} serving, event recorded {e.serving}")
        peak = max(peak, len(active) + len(draining))
    return peak


def prefix_replica(prompt, n_replicas: int, prefix_len: int = 8) -> int:
    """Rendezvous hash of the prompt prefix over ``n_replicas``.

    Every (prefix, replica) pair gets an independent deterministic score
    (SHA-256 — stable across processes, unlike ``hash()``); the replica
    with the highest score wins.  Growing the fleet from N to N+1 only
    ever moves a prefix *to the new replica*, never between survivors.
    The hashed bytes are ``prefix_key`` — the same key the per-replica
    prefix KV cache uses, so a prompt that routes by its prefix lands on
    the replica whose cache holds that prefix.
    """
    if n_replicas < 1:
        raise ValueError(n_replicas)
    key = prefix_key(prompt, prefix_len)
    return max(range(n_replicas), key=lambda i: _affinity_score(key, i))


def _affinity_score(key: bytes, replica: int) -> int:
    h = hashlib.sha256(key + replica.to_bytes(4, "little")).digest()
    return int.from_bytes(h[:8], "little")


@dataclasses.dataclass
class RouterStats:
    """Fleet-level drain statistics plus the per-replica breakdown.

    Latency percentiles, goodput, and every SLO judgement are derived
    from the shared **virtual step clock** only; ``wall_s`` and
    ``tokens_per_s`` are advisory wall-clock figures a regression gate
    must never enforce."""
    results: list                  # merged RequestResults, sorted by rid
    replica_stats: list            # per-replica ServeStats
    replica_of: dict               # rid -> index of the completing replica
    wall_s: float
    reroutes: int = 0              # starvation evictions re-dispatched
    peak_in_flight: int = 0        # max concurrent requests, fleet-wide
    rejected: list = dataclasses.field(default_factory=list)
    #                                SLO admission RejectedRequests
    autoscale_events: list = dataclasses.field(default_factory=list)
    peak_replicas: int = 0         # max replicas serving or draining
    total_vsteps: int = 0          # shared virtual clock at drain end
    slo_ttft_steps: int = 0        # deadlines goodput was judged by
    slo_e2e_steps: int = 0         #   (0 = unset: every completion counts)

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.results)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)

    @property
    def p50_ttft_steps(self) -> float:
        return percentile_steps(
            [r.ttft_steps for r in self.results if r.v_first >= 0], 50)

    @property
    def p99_ttft_steps(self) -> float:
        return percentile_steps(
            [r.ttft_steps for r in self.results if r.v_first >= 0], 99)

    @property
    def p50_e2e_steps(self) -> float:
        return percentile_steps(
            [r.e2e_steps for r in self.results if r.v_done >= 0], 50)

    @property
    def p99_e2e_steps(self) -> float:
        return percentile_steps(
            [r.e2e_steps for r in self.results if r.v_done >= 0], 99)

    @property
    def goodput_tokens(self) -> int:
        """Tokens from requests that met the virtual-step deadlines —
        the figure an SLO-bound deployment actually gets paid for."""
        return sum(len(r.tokens) for r in self.results
                   if r.meets_slo(self.slo_ttft_steps, self.slo_e2e_steps))

    @property
    def autoscale_grows(self) -> int:
        return sum(1 for e in self.autoscale_events if e.action == "grow")

    @property
    def autoscale_drains(self) -> int:
        return sum(1 for e in self.autoscale_events if e.action == "drain")

    def to_metrics(self) -> dict:
        """Flat gauge/counter snapshot (see the module docstring for the
        key schema) — plain numbers only, ready for a metrics scrape.

        The keys are declared once in ``telemetry.ROUTER_SCHEMA`` and
        this method is a *view* over that registry: setting a key the
        schema does not declare, or leaving a declared key unset, raises
        — so this table and the docstring schema cannot silently drift
        (a unit test parses the docstring against the schema too)."""
        from repro.serving.telemetry import ROUTER_SCHEMA, MetricsRegistry
        reg = MetricsRegistry(ROUTER_SCHEMA)
        reg.set("router_requests_completed", len(self.results))
        reg.set("router_requests_rejected", len(self.rejected))
        reg.set("router_generated_tokens", self.generated_tokens)
        reg.set("router_goodput_tokens", self.goodput_tokens)
        reg.set("router_slo_ttft_steps", self.slo_ttft_steps)
        reg.set("router_slo_e2e_steps", self.slo_e2e_steps)
        reg.set("router_ttft_p50_steps", self.p50_ttft_steps)
        reg.set("router_ttft_p99_steps", self.p99_ttft_steps)
        reg.set("router_e2e_p50_steps", self.p50_e2e_steps)
        reg.set("router_e2e_p99_steps", self.p99_e2e_steps)
        reg.set("router_mean_ttft_steps", self.mean_ttft_steps)
        reg.set("router_total_vsteps", self.total_vsteps)
        reg.set("router_peak_in_flight", self.peak_in_flight)
        reg.set("router_peak_replicas", self.peak_replicas)
        reg.set("router_reroutes", self.reroutes)
        reg.set("router_autoscale_grows", self.autoscale_grows)
        reg.set("router_autoscale_drains", self.autoscale_drains)
        reg.set("router_load_imbalance", self.imbalance)
        # wall-clock figures are ADVISORY — never gate on them
        reg.set("router_wall_s", self.wall_s)
        reg.set("router_tokens_per_s", self.tokens_per_s)
        for i, s in enumerate(self.replica_stats):
            reg.set(f"replica{i}_generated_tokens", s.generated_tokens)
            reg.set(f"replica{i}_decode_steps", s.decode_steps)
            reg.set(f"replica{i}_peak_resident_kv", s.peak_resident_tokens)
            reg.set(f"replica{i}_preemptions", s.preemptions)
            reg.set(f"replica{i}_occupancy", s.occupancy)
        return reg.snapshot()

    @property
    def imbalance(self) -> float:
        """Load imbalance: max/mean of per-replica peak resident KV tokens
        (1.0 = perfectly balanced; only meaningful for N > 1).  A fleet
        that saw no traffic at all has no balance to speak of — that is
        ``nan``, not a fake-perfect 1.0 a dashboard would wave through."""
        peaks = [s.peak_resident_tokens for s in self.replica_stats]
        mean = sum(peaks) / max(len(peaks), 1)
        return max(peaks) / mean if mean > 0 else float("nan")

    @property
    def mean_ttft_steps(self) -> float:
        """Mean time-to-first-token on the fleet's shared virtual step
        clock — the deterministic proxy blocking-vs-chunked prefill is
        compared on."""
        ttfts = [r.ttft_steps for r in self.results if r.v_first >= 0]
        return float(np.mean(ttfts)) if ttfts else 0.0

    @property
    def prefill_chunks(self) -> int:
        return sum(s.prefill_chunks for s in self.replica_stats)

    @property
    def prefill_tokens(self) -> int:
        """Prompt tokens the fleet actually ran through chunk steps —
        cache hits shrink this without touching the token streams."""
        return sum(s.prefill_tokens for s in self.replica_stats)

    @property
    def prefix_hits(self) -> int:
        return sum(s.prefix_hits for s in self.replica_stats)

    @property
    def prefix_misses(self) -> int:
        return sum(s.prefix_misses for s in self.replica_stats)

    @property
    def prefill_tokens_saved(self) -> int:
        return sum(s.prefill_tokens_saved for s in self.replica_stats)

    @property
    def prefix_hit_rate(self) -> float:
        n = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / n if n else 0.0

    @property
    def overlap_steps(self) -> int:
        """Scheduler ticks, fleet-wide, that ingested a prompt chunk AND
        decoded — the overlap chunked prefill exists to create."""
        return sum(s.overlap_steps for s in self.replica_stats)

    @property
    def spec_verify_steps(self) -> int:
        return sum(s.spec_verify_steps for s in self.replica_stats)

    @property
    def spec_drafted_tokens(self) -> int:
        return sum(s.spec_drafted_tokens for s in self.replica_stats)

    @property
    def spec_accepted_tokens(self) -> int:
        return sum(s.spec_accepted_tokens for s in self.replica_stats)

    @property
    def accepted_per_verify(self) -> float:
        """Fleet-wide tokens emitted per speculative verify event — the
        same >1-means-spec-pays figure as ``ServeStats``, summed over
        replicas before the ratio so busy and idle replicas weight by
        their actual verify traffic."""
        if not self.spec_verify_steps:
            return 0.0
        return ((self.spec_verify_steps + self.spec_accepted_tokens)
                / self.spec_verify_steps)

    @property
    def effective_top_k(self) -> dict:
        """rid -> effective top-k, merged across replicas (a rid completes
        on exactly one replica, so the union is disjoint)."""
        out: dict = {}
        for s in self.replica_stats:
            out.update(s.effective_top_k)
        return out

    def summary(self) -> str:
        per = ", ".join(f"r{i}:{s.generated_tokens}t"
                        for i, s in enumerate(self.replica_stats))
        re = f", {self.reroutes} reroutes" if self.reroutes else ""
        if self.rejected:
            re += f", {len(self.rejected)} SLO-rejected"
        if self.autoscale_events:
            re += (f", autoscale {self.autoscale_grows} grows/"
                   f"{self.autoscale_drains} drains "
                   f"(peak {self.peak_replicas} replicas)")
        if self.slo_ttft_steps or self.slo_e2e_steps:
            re += (f", goodput {self.goodput_tokens}t under SLO "
                   f"(p99 ttft {self.p99_ttft_steps:.0f} vsteps)")
        if self.prefix_hits:
            re += (f", {self.prefix_hits} prefix hits "
                   f"({self.prefill_tokens_saved}t prefill saved)")
        if self.spec_verify_steps:
            re += (f", spec {self.accepted_per_verify:.2f} tok/verify "
                   f"({self.spec_accepted_tokens}/"
                   f"{self.spec_drafted_tokens} drafts accepted)")
        return (f"{len(self.results)} requests over "
                f"{len(self.replica_stats)} replicas, "
                f"{self.generated_tokens} tokens in {self.wall_s:.3f}s -> "
                f"{self.tokens_per_s:.1f} tok/s fleet | peak "
                f"{self.peak_in_flight} in flight, imbalance "
                f"{self.imbalance:.2f}{re} | {per}")


class ReplicaRouter:
    """Route request traces across N ``ServeEngine`` replicas."""

    def __init__(self, engines, policy: str = "least_loaded",
                 prefix_len: int = 8, log=print,
                 # advisory wall_s only; gated metrics are vstep-clocked
                 clock=time.perf_counter):  # easeylint: allow[wall-clock]
        engines = list(engines)
        if not engines:
            raise ValueError("router needs at least one replica engine")
        if policy not in ROUTE_POLICIES:
            raise ValueError(f"policy {policy!r} not in {ROUTE_POLICIES}")
        names = {e.cfg.name for e in engines}
        if len(names) > 1:
            raise ValueError(
                f"replicas must share one architecture, got {sorted(names)}")
        lens = {e.max_len for e in engines}
        if len(lens) > 1:
            # max_len clamps a request's generation budget at admission
            # (sticky on the result), so a mixed-max_len fleet would make
            # output depend on which replica the policy picked
            raise ValueError(
                f"replicas must share one max_len, got {sorted(lens)}")
        # same failure class: eos decides when a stream stops, the seed
        # decides weights and sampler draws — either differing per replica
        # would make output depend on the routing decision
        eos = {e.eos_id for e in engines}
        if len(eos) > 1:
            raise ValueError(
                f"replicas must share one eos_id, got {sorted(map(str, eos))}")
        seeds = {e.seed for e in engines}
        if len(seeds) > 1:
            raise ValueError(
                f"replicas must share one seed, got {sorted(seeds)}")
        self.engines = engines
        self.policy = policy
        self.prefix_len = prefix_len
        self.log = log
        self.clock = clock

    @classmethod
    def build(cls, arch: str = "deepseek-7b-smoke",
              target: str = "local:cpu", replicas: int = 2,
              kv_layout: str = "contiguous", num_slots: int = 8,
              max_len: int = 128, seed: int = 0, eos_id: int | None = None,
              policy: str = "least_loaded", page_size: int = 0,
              num_pages: int = 0, prefill_chunk: int | None = None,
              prefix_cache: bool = False, kv_kernel: str = "auto",
              spec_k: int | None = 0, drafter=None,
              repetitiveness: float = 0.0, log=print) -> "ReplicaRouter":
        """Build an N-replica fleet, splitting the tuner budget N ways.

        ``kv_layout`` may be comma-separated (``"paged,contiguous"``) and
        is cycled across replica slots — one engine is built per distinct
        layout and *shared* between its slots (jitted steps and weights
        compile once; pools stay per-replica).
        """
        from repro.serving.engine import ServeEngine
        if replicas < 1:
            raise ValueError(f"replicas {replicas} < 1")
        layouts = [l.strip() for l in kv_layout.split(",") if l.strip()]
        if not layouts:
            raise ValueError(f"no kv layout in {kv_layout!r}")
        built: dict[str, object] = {}
        fleet = []
        for i in range(replicas):
            lay = layouts[i % len(layouts)]
            if lay not in built:
                built[lay] = ServeEngine(
                    arch=arch, target=target, num_slots=num_slots,
                    max_len=max_len, seed=seed, eos_id=eos_id,
                    kv_layout=lay, page_size=page_size, num_pages=num_pages,
                    replicas=replicas, prefill_chunk=prefill_chunk,
                    # mixed fleets: the cache / fused decode kernel only
                    # apply to paged slots
                    prefix_cache=prefix_cache and lay == "paged",
                    kv_kernel=kv_kernel if lay == "paged" else "auto",
                    spec_k=spec_k, drafter=drafter,
                    repetitiveness=repetitiveness, log=log)
            fleet.append(built[lay])
        return cls(fleet, policy=policy, log=log)

    # -- validation ---------------------------------------------------------
    def _validate(self, requests, scheds) -> None:
        """Router-level fail-fast: a request is serveable if *some* replica
        can ever hold it (the single-engine rules, any-replica quantified)."""
        for req in requests:
            if not 0 <= req.top_k <= K_CAP:
                raise ValueError(
                    f"request {req.rid}: top_k {req.top_k} not in "
                    f"[0, {K_CAP}]")
            top_p = getattr(req, "top_p", 1.0)
            if not 0.0 < top_p <= 1.0:
                raise ValueError(
                    f"request {req.rid}: top_p {top_p} not in (0, 1]")
            if all(len(req.prompt) > s.pool.max_len for s in scheds):
                raise ValueError(
                    f"request {req.rid}: prompt ({len(req.prompt)}) does "
                    f"not fit any replica's max_len")
            en = _Entry(req)
            if not any(s.pool.can_ever_serve(s.worst_resident(en))
                       for s in scheds):
                raise PoolExhausted(
                    f"request {req.rid} needs "
                    f"{min(s.worst_resident(en) for s in scheds)} resident "
                    f"KV tokens but no replica can ever hold that many")

    # -- policy -------------------------------------------------------------
    def _pick(self, entry: _Entry, ready: list[int], scheds) -> int:
        if self.policy == "round_robin":
            n = len(scheds)
            ready_set = set(ready)
            for off in range(n):
                i = (self._rr + off) % n
                if i in ready_set:
                    self._rr = (i + 1) % n
                    return i
        if self.policy == "least_loaded":
            # most free KV tokens wins; ties go to the lowest index.  The
            # scheduler-level figure charges a replica's queued prefill
            # chunks against its pool capacity, so a replica mid-ingest
            # does not masquerade as free
            return max(ready, key=lambda i: (scheds[i].free_tokens, -i))
        # prefix_affinity: highest rendezvous score among the admittable —
        # the preferred replica when it has room, its runner-up otherwise.
        # Keyed by prefix_key, the same bytes the per-replica prefix KV
        # cache hashes, so sharers colocate with their cached run.
        key = prefix_key(entry.req.prompt, self.prefix_len)
        return max(ready, key=lambda i: _affinity_score(key, i))

    # -- dispatch ------------------------------------------------------------
    def _worst_for(self, sched, entry) -> int:
        """Residency bound used to place `entry` on `sched`'s replica.

        A starvation-evicted (rerouted) entry just proved a pool holding
        nothing else cannot finish it, so it must land where its FULL
        remaining generation fits — the optimistic eos bound
        (``worst_resident`` = pending only) would keep the starved
        replica "feasible" and let the fleet grind one token per
        re-prefill bounce instead of re-routing or failing fast."""
        if entry.rerouted:
            return min(entry.pending_len + entry.remaining_new() - 1,
                       sched.pool.max_len)
        return sched.worst_resident(entry)

    def _dispatch(self, queue: deque, scheds, accepting,
                  cap: int | None = None) -> bool:
        """Admit from the queue head while some accepting replica has room
        (head-of-line, like the single-engine scheduler).  ``cap`` is the
        autoscaler's per-replica in-flight admission cap (from
        ``rebalance_batch_size``).  Returns whether anything was admitted."""
        progressed = False
        while queue:
            entry = queue[0]
            feasible = [i for i in accepting
                        if scheds[i].pool.can_ever_serve(
                            self._worst_for(scheds[i], entry))]
            if not any(
                    s.pool.can_ever_serve(self._worst_for(s, entry))
                    for s in scheds):
                raise PoolExhausted(
                    f"request {entry.req.rid} ({entry.pending_len} resident "
                    f"tokens) can no longer fit any replica's pool")
            ready = [i for i in feasible
                     if (cap is None or scheds[i].in_flight < cap)
                     and scheds[i].can_admit(entry)]
            if not ready:
                return progressed
            idx = self._pick(entry, ready, scheds)
            if not scheds[idx].try_admit(entry):
                return progressed   # unreachable: `ready` just re-checked
            queue.popleft()
            progressed = True
        return progressed

    # -- SLO admission --------------------------------------------------------
    def _napkin(self, entry, scheds, accepting, shared,
                ahead_chunks: int = 0) -> int:
        """Predicted TTFT (virtual steps) for a queued entry: waited so
        far + the accepting replicas' prefill-backlog share + its own
        chunk cost — the tuner's napkin, fed live fleet state."""
        unit = max(min(scheds[i].chunk_unit for i in accepting), 1)
        backlog = sum(-(-scheds[i].prefill_backlog_tokens // unit)
                      for i in accepting)
        waited = max(shared.t - getattr(entry.req, "arrival_vstep", 0), 0)
        share = -(-(backlog + ahead_chunks) // len(accepting))
        return ttft_napkin_steps(entry.pending_len, unit,
                                 backlog_chunks=share, waited_steps=waited)

    def _reject_slo(self, queue: deque, scheds, accepting, shared,
                    rejected: list, slo_ttft_steps: int,
                    slo_e2e_steps: int, tracer=None) -> None:
        """Reject-with-reason every queued FRESH request whose predicted
        TTFT/e2e blows its deadline (preempted or rerouted entries
        already emitted tokens — those are never rejected; they resume).
        The napkin charges each entry the queue ahead of it, so one
        hopeless deep queue rejects its tail, not just its head."""
        if not accepting:
            return
        unit = max(min(scheds[i].chunk_unit for i in accepting), 1)
        kept: list = []
        ahead = 0                     # chunk-equivalents queued ahead
        while queue:
            en = queue.popleft()
            if en.st is not None or en.rerouted:
                kept.append(en)
                ahead += -(-en.pending_len // unit)
                continue
            predicted = self._napkin(en, scheds, accepting, shared,
                                     ahead_chunks=ahead)
            reason = None
            if slo_ttft_steps > 0 and predicted > slo_ttft_steps:
                reason = (f"predicted TTFT {predicted} vsteps > slo_ttft "
                          f"{slo_ttft_steps}")
            elif slo_e2e_steps > 0 and \
                    predicted + en.remaining_new() > slo_e2e_steps:
                reason = (f"predicted e2e "
                          f"{predicted + en.remaining_new()} vsteps > "
                          f"slo_e2e {slo_e2e_steps}")
            if reason is None:
                kept.append(en)
                ahead += -(-en.pending_len // unit)
            else:
                rejected.append(RejectedRequest(
                    rid=en.req.rid, reason=reason, v_reject=shared.t,
                    predicted_ttft_steps=predicted))
                if tracer is not None:
                    tracer.end("queued", en.req.rid, shared.t,
                               rejected=True)
                    tracer.instant("reject", shared.t, rid=en.req.rid,
                                   predicted_ttft_steps=predicted)
        queue.extend(kept)

    # -- main loop -----------------------------------------------------------
    def run(self, requests, policy: str = "continuous",
            prefill_chunk: int | None = None,
            prefix_cache: bool | None = None,
            slo_ttft_steps: int = 0, slo_e2e_steps: int = 0,
            admission: str = "queue",
            autoscale: AutoscalePolicy | None = None,
            tracer=None) -> RouterStats:
        """Drain `requests` across the fleet under scheduling `policy`
        (``continuous`` refills replicas between steps; ``static`` gang-
        fills only idle replicas).  Fresh pools per run, like the engine.

        ``prefill_chunk`` overrides every replica's prompt-ingestion
        grain (None: each engine's own setting; 0: blocking full-prompt
        prefill at dispatch — the old fleet-stalling cadence, kept as
        the TTFT baseline).  ``prefix_cache`` likewise overrides the
        per-replica shared-prefix KV cache (None: each engine's own
        setting) — caches are per replica, which composes with
        ``prefix_affinity`` colocating sharers on one replica.  In a
        mixed-layout fleet the override applies to the paged replicas
        only; contiguous pools have no pages to share.

        The fleet shares one virtual step clock: blocking prefills at
        dispatch advance it serially (they run one after another on the
        driver thread, stalling every replica), while each round's
        parallel work advances it by the busiest replica's invocation
        count — replicas are independent hosts, so a round costs the max,
        not the sum.

        Open loop: requests with ``arrival_vstep > 0`` join the router
        queue only once the shared clock reaches their arrival.
        ``slo_ttft_steps`` / ``slo_e2e_steps`` set the virtual-step
        deadlines goodput is judged by; with ``admission="reject"`` a
        queued request predicted (TTFT napkin) to blow them is rejected
        with a reason instead of waiting forever.  ``autoscale`` hands
        replica lifecycle to an ``AutoscalePolicy`` (grow on queue
        depth / SLO headroom, drain when quiet) — continuous policy
        only, since a draining replica must keep stepping while closed
        to admission.

        ``tracer`` (a ``serving.telemetry.Tracer``) records per-request
        spans (one Chrome-trace "process" per replica, one "thread" per
        slot) and fleet ring events — host-side only, behind None-guards,
        so tracing cannot perturb a single token."""
        requests = list(requests)
        if admission not in ADMISSION_MODES:
            raise ValueError(
                f"admission {admission!r} not in {ADMISSION_MODES}")
        if admission == "reject" and not (slo_ttft_steps or slo_e2e_steps):
            raise ValueError(
                "admission='reject' needs slo_ttft_steps or slo_e2e_steps "
                "— with no deadline there is nothing to reject against")
        if autoscale is not None and policy != "continuous":
            raise ValueError(
                "autoscale requires the continuous scheduling policy (a "
                "draining replica keeps stepping while closed to admission)")
        shared = VirtualClock()
        scheds = [Scheduler(e.make_pool(prefix_cache=(
                                prefix_cache if e.kv_layout == "paged"
                                else None)),
                            e.prefill_fn, e.decode_fn,
                            eos_id=e.eos_id, policy=policy,
                            sampler=e.sampler, clock=self.clock,
                            chunk_step_fn=getattr(e, "chunk_fn", None),
                            prefill_chunk=(getattr(e, "prefill_chunk", 0)
                                           if prefill_chunk is None
                                           else prefill_chunk),
                            prefill_chunk_unit=getattr(e, "chunk_unit", 16),
                            verify_fn=(e.verify_fn
                                       if getattr(e, "spec_k", 0) else None),
                            spec_k=getattr(e, "spec_k", 0),
                            drafter=getattr(e, "drafter", None),
                            vocab_size=e.cfg.vocab_size,
                            vclock=RoundClock(shared),
                            slo_ttft_steps=slo_ttft_steps,
                            slo_e2e_steps=slo_e2e_steps,
                            tracer=tracer, replica_id=i)
                  for i, e in enumerate(self.engines)]
        self._validate(requests, scheds)
        all_greedy = all(r.temperature <= 0 or r.top_k == 1
                         for r in requests)
        t0 = self.clock()
        for s in scheds:
            s.all_greedy = all_greedy
            s.reset(t0)
        for r in requests:
            r._t_submit = t0
        auto = None if autoscale is None else \
            _Autoscaler(autoscale, scheds, shared, tracer=tracer)
        # open loop: stable arrival sort — ties (and the all-zero closed
        # loop) keep trace order, so closed-loop behaviour is unchanged
        pending: deque = deque(sorted(
            (_Entry(r) for r in requests),
            key=lambda en: getattr(en.req, "arrival_vstep", 0)))
        queue: deque = deque()
        rejected: list = []
        self._rr = 0
        reroutes = 0
        peak_in_flight = 0
        peak_replicas = auto.working if auto else len(scheds)
        while pending or queue or \
                any(s.active or s.prefill_backlog for s in scheds):
            # release every request whose arrival the clock has reached
            while pending and \
                    getattr(pending[0].req, "arrival_vstep", 0) <= shared.t:
                en = pending.popleft()
                if tracer is not None:
                    # router-level wait starts at *arrival*; the span ends
                    # when some replica admits (or SLO admission rejects)
                    tracer.begin("queued", en.req.rid,
                                 getattr(en.req, "arrival_vstep", 0),
                                 prompt_len=len(en.req.prompt))
                queue.append(en)
            if auto is not None:
                accepting = auto.accepting()
                if policy == "static":      # unreachable (validated above)
                    accepting = [i for i in accepting
                                 if not (scheds[i].active or
                                         scheds[i].prefill_backlog)]
            elif policy == "continuous":
                accepting = list(range(len(scheds)))
            else:      # static: gang-fill only replicas idle at phase start
                # (mid-prefill counts as busy — its gang is still forming)
                accepting = [i for i, s in enumerate(scheds)
                             if not (s.active or s.prefill_backlog)]
            if admission == "reject" and queue:
                self._reject_slo(queue, scheds, accepting, shared,
                                 rejected, slo_ttft_steps, slo_e2e_steps,
                                 tracer=tracer)
            progressed = self._dispatch(
                queue, scheds, accepting,
                cap=auto.per_cap if auto is not None else None)
            if auto is not None:
                # scale on the leftover depth: what dispatch could not
                # place with the current fleet is the genuine pressure
                head_pred = self._napkin(queue[0], scheds, auto.accepting(),
                                         shared) \
                    if queue and auto.accepting() else None
                auto.tick(len(queue), head_pred, slo_ttft_steps)
                peak_replicas = max(peak_replicas, auto.working)
            in_flight = sum(s.in_flight for s in scheds)
            peak_in_flight = max(peak_in_flight, in_flight)
            stepped = False
            for s in scheds:
                # a replica mid-prefill still takes its tick: it ingests
                # the next chunk AND decodes its active slots — prompt
                # ingestion on one replica no longer stalls the others
                # (draining replicas keep stepping here too: closed to
                # admission, never to completion)
                if not (s.active or s.prefill_backlog):
                    continue
                stepped = True
                # solo page starvation: evict for re-route (front of the
                # router queue, like a local preemption resume); marked so
                # dispatch places it by the pessimistic residency bound
                for en in reversed(s.step(evict_on_starvation=True)):
                    en.rerouted = True
                    reroutes += 1
                    if tracer is not None:
                        tracer.instant("reroute", shared.t,
                                       replica=s.replica_id,
                                       rid=en.req.rid,
                                       tokens=len(en.st.tokens))
                    queue.appendleft(en)
                # ordinary preemptions also resume through the router, so
                # a request squeezed out of one replica may land on another
                while s.queue:
                    queue.appendleft(s.queue.pop())
            # the round costs what the busiest replica did this round
            shared.advance(max((s.vclock.take() for s in scheds), default=0))
            if not stepped and not progressed:
                if queue:
                    # an autoscaled fleet may just be scaled-in too far:
                    # wake a replica before declaring the fleet too small
                    if auto is not None and auto.try_grow():
                        continue
                    en = queue[0]
                    raise PoolExhausted(
                        f"request {en.req.rid} ({en.pending_len} tokens) "
                        f"cannot be admitted into an otherwise idle fleet "
                        f"— every replica's pool is too small for it")
                if pending:
                    # idle fleet, future arrivals only: fast-forward the
                    # shared clock to the next arrival (real time passes
                    # while nothing computes — deterministically)
                    nxt = getattr(pending[0].req, "arrival_vstep", 0)
                    shared.advance(nxt - shared.t)

        wall = self.clock() - t0
        if tracer is not None:
            tracer.close(shared.t)
        stats = [s.stats() for s in scheds]
        replica_of = {r.rid: i for i, s in enumerate(stats)
                      for r in s.results}
        results = sorted((r for s in stats for r in s.results),
                         key=lambda r: r.rid)
        out = RouterStats(results=results, replica_stats=stats,
                          replica_of=replica_of, wall_s=wall,
                          reroutes=reroutes, peak_in_flight=peak_in_flight,
                          rejected=rejected,
                          autoscale_events=auto.events if auto else [],
                          peak_replicas=peak_replicas,
                          total_vsteps=shared.t,
                          slo_ttft_steps=slo_ttft_steps,
                          slo_e2e_steps=slo_e2e_steps)
        self.log(f"[route:{self.policy}:{policy}] {out.summary()}")
        return out
