"""KVCachePool — a fixed-capacity, slot-indexed KV cache for serving.

The pool owns one donated cache tree shaped like the model's decode cache
but with a *slot* batch axis and a per-slot length vector:

    k, v : (layers, num_slots, max_len, kv_heads, head_dim)
    index: (num_slots,) int32 — tokens written per slot

Slots are handed out from a free list (LIFO, deterministic), a prefilled
request is scattered into its slot with ``insert`` and the whole pool rides
through one slot-wise decode step per iteration, so requests of different
lengths share every matmul.  Buffers are donated on both the insert and the
decode path; the engine swaps the tree via ``update``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


class PoolExhausted(RuntimeError):
    """alloc() on a pool with no free slots."""


@partial(jax.jit, donate_argnums=(0,))
def _scatter_insert(cache, slot, pk, pv):
    """Write a batch-1 prefill cache (L, 1, s, K, dh) into `slot`[0:s)."""
    s = pk.shape[2]
    k = jax.lax.dynamic_update_slice(cache["k"], pk, (0, slot, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], pv, (0, slot, 0, 0, 0))
    index = cache["index"].at[slot].set(s)
    return {"k": k, "v": v, "index": index}


class KVCachePool:
    """Fixed-capacity slot pool over a model's decode cache."""

    def __init__(self, model, num_slots: int, max_len: int):
        cfg = model.cfg
        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"KVCachePool serves attention-cache families (dense/moe), "
                f"not {cfg.family!r}")
        if cfg.window:
            raise NotImplementedError(
                "slot-wise decode does not apply sliding-window attention "
                "yet; a windowed config served here would silently attend "
                "the full history")
        if num_slots < 1 or max_len < 1:
            raise ValueError((num_slots, max_len))
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        kv_shape = (cfg.num_layers, num_slots, max_len,
                    cfg.num_kv_heads, cfg.head_dim)
        self.cache = {"k": jnp.zeros(kv_shape, cfg.activation_dtype),
                      "v": jnp.zeros(kv_shape, cfg.activation_dtype),
                      "index": jnp.zeros((num_slots,), jnp.int32)}
        # LIFO free list: alloc() pops slot 0 first; a freed slot is the
        # next one reissued (deterministic, cache-friendly).
        self._free = list(range(num_slots - 1, -1, -1))
        self.lengths = np.zeros((num_slots,), np.int64)  # host mirror

    # -- slot lifecycle ----------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_slots} KV slots are in flight")
        return self._free.pop()

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range")
        if slot in self._free:
            raise ValueError(f"slot {slot} is already free")
        self.lengths[slot] = 0
        self._free.append(slot)

    # -- cache plumbing ----------------------------------------------------
    def insert(self, slot: int, prefill_cache: dict) -> None:
        """Scatter a (batch=1) prefill cache into `slot` positions [0, s)."""
        pk, pv = prefill_cache["k"], prefill_cache["v"]
        s = pk.shape[2]
        if s > self.max_len:
            raise ValueError(f"prefill length {s} > pool max_len {self.max_len}")
        self.cache = _scatter_insert(self.cache, jnp.int32(slot), pk, pv)
        self.lengths[slot] = s

    def update(self, new_cache: dict, active_slots=()) -> None:
        """Adopt the cache returned by a (donating) decode step; the length
        mirror advances only for the slots that were active this step."""
        self.cache = new_cache
        for slot in active_slots:
            self.lengths[slot] += 1
