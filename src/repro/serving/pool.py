"""KV cache pools — the serving stack's memory layer, in two layouts.

``KVCachePool`` (contiguous) owns one donated cache tree shaped like the
model's decode cache but with a *slot* batch axis and a per-slot length
vector:

    k, v : (layers, num_slots, max_len, kv_heads, head_dim)
    index: (num_slots,) int32 — tokens written per slot

Every admitted request pins ``max_len`` positions of HBM for its whole
lifetime, whatever its actual length — simple, but the pool's capacity is
*worst cases*, not tokens.

``PagedKVCachePool`` breaks that reservation: KV storage is a pool of
fixed-size pages plus a per-slot page-table indirection,

    k, v      : (layers, num_pages, page_size, kv_heads, head_dim)
    index     : (num_slots,) int32 — tokens written per slot
    page_table: (num_slots, max_pages) int32 — host-side, shipped to the
                decode step each iteration as a plain argument

so a request only ever holds ``ceil(len / page_size)`` pages and the
tuner's HBM budget buys admitted *tokens* instead of admitted worst
cases.  Page 0 is a reserved junk page: inactive slots (zeroed
page-table rows) scatter their dead writes there and nothing ever reads
it through a live page table.  Pages grow on demand during decode
(``prepare_decode``); when the pool is out of pages the scheduler
preempts a request and resumes it later.

Pages are **refcounted** (``page_refs``): normally a page has one owner
and ``free`` returns it immediately, but an attached shared-prefix cache
(``serving/prefix_cache.PrefixCache``) lets several requests — and the
cache itself — reference one page at once.  ``free`` then only
*decrements*; the page rejoins the free list at refcount zero, so a
preempted sharer can never free a page another request still reads.
Under page pressure the allocator reclaims cache-only pages (LRU) before
reporting starvation.

Both pools hand out slots/pages from deterministic LIFO free lists with
an O(1) boolean free-mask (no linear membership scans), scatter prefilled
requests in with ``insert``, and ride the whole pool through one
slot-wise decode step per iteration so requests of different lengths
share every matmul.  Buffers are donated on both the insert and the
decode path; the engine swaps the tree via ``update``.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


class PoolExhausted(RuntimeError):
    """alloc() on a pool with no free slots / no free pages."""


class _FreeList:
    """Deterministic LIFO free list with an O(1) boolean free-mask.

    ``pop()`` hands out the lowest index first on a fresh pool; a freed
    index is the next one reissued (cache-friendly, reproducible).  The
    mask replaces the old O(n) ``idx in list`` membership scan on free.
    """

    def __init__(self, n: int, start: int = 0):
        self._items = list(range(n - 1 + start, start - 1, -1))
        self._mask = np.zeros((n + start,), bool)
        self._mask[start:] = True
        self.start = start

    def __len__(self) -> int:
        return len(self._items)

    def pop(self) -> int:
        idx = self._items.pop()
        self._mask[idx] = False
        return idx

    def push(self, idx: int) -> None:
        if self._mask[idx]:
            raise ValueError(f"index {idx} is already free")
        self._mask[idx] = True
        self._items.append(idx)

    def is_free(self, idx: int) -> bool:
        return bool(self._mask[idx])


def _check_servable(cfg):
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"KV pools serve attention-cache families (dense/moe), "
            f"not {cfg.family!r}")
    if cfg.window:
        raise NotImplementedError(
            "slot-wise decode does not apply sliding-window attention "
            "yet; a windowed config served here would silently attend "
            "the full history")


@partial(jax.jit, donate_argnums=(0,))
def _scatter_insert(cache, slot, pk, pv):
    """Write a batch-1 prefill cache (L, 1, s, K, dh) into `slot`[0:s)."""
    s = pk.shape[2]
    k = jax.lax.dynamic_update_slice(cache["k"], pk, (0, slot, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], pv, (0, slot, 0, 0, 0))
    index = cache["index"].at[slot].set(s)
    return {"k": k, "v": v, "index": index}


@partial(jax.jit, donate_argnums=(0,))
def _scatter_insert_paged(cache, slot, pages_row, pk, pv):
    """Write a batch-1 prefill cache (L, 1, s, K, dh) through `pages_row`.

    Token position j lands in page ``pages_row[j // page_size]`` at offset
    ``j % page_size`` — the same indirection the decode step reads back.
    """
    L, _, s, K, dh = pk.shape
    P, psize = cache["k"].shape[1], cache["k"].shape[2]
    pos = jnp.arange(s)
    fpos = pages_row[pos // psize] * psize + pos % psize  # (s,)
    k = cache["k"].reshape(L, P * psize, K, dh).at[:, fpos].set(pk[:, 0])
    v = cache["v"].reshape(L, P * psize, K, dh).at[:, fpos].set(pv[:, 0])
    index = cache["index"].at[slot].set(s)
    return {"k": k.reshape(L, P, psize, K, dh),
            "v": v.reshape(L, P, psize, K, dh), "index": index}


class KVCachePool:
    """Fixed-capacity contiguous slot pool over a model's decode cache."""

    layout = "contiguous"

    def __init__(self, model, num_slots: int, max_len: int):
        cfg = model.cfg
        _check_servable(cfg)
        if num_slots < 1 or max_len < 1:
            raise ValueError((num_slots, max_len))
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        kv_shape = (cfg.num_layers, num_slots, max_len,
                    cfg.num_kv_heads, cfg.head_dim)
        self.cache = {"k": jnp.zeros(kv_shape, cfg.activation_dtype),
                      "v": jnp.zeros(kv_shape, cfg.activation_dtype),
                      "index": jnp.zeros((num_slots,), jnp.int32)}
        self._free = _FreeList(num_slots)
        self.lengths = np.zeros((num_slots,), np.int64)  # host mirror

    # -- capacity ----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def free_tokens(self) -> int:
        """Admittable KV tokens left (contiguous: free worst-case slots) —
        the load signal a router's least-loaded policy balances."""
        return self.num_free * self.max_len

    def can_admit(self, prompt_len: int, active_slots=(),
                  hit=None) -> bool:
        """A contiguous slot IS the worst-case reservation: one free slot
        admits any prompt that fits max_len.  (``hit`` — a prefix-cache
        probe — only ever applies to paged pools and is ignored here.)"""
        return self.num_free > 0 and prompt_len <= self.max_len

    def can_ever_serve(self, n_tokens: int) -> bool:
        """Whether a request resident at `n_tokens` could ever fit an
        otherwise-empty pool (contiguous: max_len is the only bound)."""
        return n_tokens <= self.max_len

    # -- slot lifecycle ----------------------------------------------------
    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_slots} KV slots are in flight")
        return self._free.pop()

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range")
        if self._free.is_free(slot):
            raise ValueError(f"slot {slot} is already free")
        self.lengths[slot] = 0
        self._free.push(slot)

    # -- cache plumbing ----------------------------------------------------
    def insert(self, slot: int, prefill_cache: dict) -> None:
        """Scatter a (batch=1) prefill cache into `slot` positions [0, s).

        Legacy/test path: the serving engine now writes prompt KV straight
        into the pool from the chunked prefill step (``reserve_prefix`` +
        ``adopt``) and never materializes this intermediate cache."""
        pk, pv = prefill_cache["k"], prefill_cache["v"]
        s = pk.shape[2]
        if s > self.max_len:
            raise ValueError(f"prefill length {s} > pool max_len {self.max_len}")
        self.cache = _scatter_insert(self.cache, jnp.int32(slot), pk, pv)
        self.lengths[slot] = s

    def reserve_prefix(self, slot: int, n_tokens: int) -> None:
        """Reserve room for an `n_tokens` prompt before chunked prefill
        (contiguous: a slot IS the reservation — just bounds-check)."""
        if n_tokens > self.max_len:
            raise ValueError(
                f"prefix of {n_tokens} tokens > pool max_len {self.max_len}")

    def chunk_extras(self, slot: int) -> tuple:
        """Extra per-chunk arguments for the jitted chunk-prefill step."""
        return ()

    @property
    def kv_bound_cap(self) -> int:
        """Largest KV prefix a chunk could ever need to read back."""
        return self.max_len

    def adopt(self, new_cache: dict) -> None:
        """Take ownership of the cache returned by a (donating) chunk
        step; the host length mirror advances via ``set_length``."""
        self.cache = new_cache

    def set_length(self, slot: int, n_tokens: int) -> None:
        self.lengths[slot] = n_tokens

    def prepare_decode(self, active_slots) -> list:
        """Contiguous slots never grow — nothing can starve."""
        return []

    def decode_extras(self) -> tuple:
        """Extra per-step arguments for the jitted decode step."""
        return ()

    def grow_for_burst(self, slot: int, want_tokens: int) -> int:
        """KV positions backed for a speculative verify burst starting at
        the slot's current length.  Contiguous slots reserve max_len up
        front, so burst capacity is just the slot's length headroom."""
        return max(int(min(want_tokens, self.max_len - self.lengths[slot])),
                   0)

    def sync_index(self) -> None:
        """Re-upload the host length mirror as the device index vector.

        After a verify step the device index is stale by design (the step
        returns it unchanged — acceptance is a host decision), so the
        scheduler calls this once per spec step.  Free slots sync to 0,
        which is harmless: admission re-seeds their index before any
        decode reads it."""
        self.cache = dict(self.cache,
                          index=jnp.asarray(self.lengths, jnp.int32))

    def update(self, new_cache: dict, active_slots=()) -> None:
        """Adopt the cache returned by a (donating) decode step; the length
        mirror advances only for the slots that were active this step."""
        self.cache = new_cache
        for slot in active_slots:
            self.lengths[slot] += 1


class PagedKVCachePool:
    """Page-table KV pool: slots hold page lists, not max_len reservations.

    ``num_pages`` counts the whole pool *including* the reserved junk page
    0, so ``num_pages - 1`` pages are allocatable.  A slot may hold at most
    ``max_pages = ceil(max_len / page_size)`` pages (the same per-request
    cap as a contiguous slot).  The page table lives on the host (alloc /
    free are pure bookkeeping, no device traffic) and is shipped to the
    decode step as a small int32 array each iteration.
    """

    layout = "paged"

    def __init__(self, model, num_slots: int, max_len: int,
                 page_size: int = 16, num_pages: int = 0):
        cfg = model.cfg
        _check_servable(cfg)
        if num_slots < 1 or max_len < 1 or page_size < 1:
            raise ValueError((num_slots, max_len, page_size))
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.page_size = page_size
        self.max_pages = math.ceil(max_len / page_size)
        # default: worst case (every slot at max_len) + the junk page —
        # the tuner passes a budget-derived (smaller) pool instead
        self.num_pages = num_pages or num_slots * self.max_pages + 1
        if self.num_pages < 2:
            raise ValueError(f"num_pages {self.num_pages} < 2 "
                             f"(page 0 is reserved)")
        kv_shape = (cfg.num_layers, self.num_pages, page_size,
                    cfg.num_kv_heads, cfg.head_dim)
        self.cache = {"k": jnp.zeros(kv_shape, cfg.activation_dtype),
                      "v": jnp.zeros(kv_shape, cfg.activation_dtype),
                      "index": jnp.zeros((num_slots,), jnp.int32)}
        self.page_table = np.zeros((num_slots, self.max_pages), np.int32)
        self._pages_held = np.zeros((num_slots,), np.int64)
        self._free = _FreeList(num_slots)
        self._free_pages = _FreeList(self.num_pages - 1, start=1)
        self.lengths = np.zeros((num_slots,), np.int64)  # host mirror
        # owners per page: the allocating request, each prefix-cache
        # sharer, and the cache cell itself each hold one reference.
        # page_cached flags cache-pinned pages and _cache_only counts the
        # ones no request shares (refcount exactly 1) — maintained on the
        # 1<->2 refcount transitions so the admission/load-signal hot
        # paths never scan the cache.
        self.page_refs = np.zeros((self.num_pages,), np.int32)
        self.page_cached = np.zeros((self.num_pages,), bool)
        self._cache_only = 0
        self.prefix_cache = None     # attached by PrefixCache(pool, ...)

    # -- capacity ----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def reclaimable_pages(self) -> int:
        """Pages the attached prefix cache could hand back on demand
        (cache-pinned, shared with no live request) — spendable headroom
        for admission and the router's load signal.  O(1): a running
        count, not a cache scan."""
        return self._cache_only

    @property
    def free_tokens(self) -> int:
        """Admittable KV tokens left (paged: free pages worth of tokens,
        gated on a free page-table row existing at all).  Cache-only
        prefix pages count as free — they are reclaimed before anything
        starves — and a page shared by N requests is simply not free, so
        the router's least-loaded signal never double-counts it."""
        if not self.num_free:
            return 0
        return (self.free_pages + self.reclaimable_pages) * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size)

    def can_admit(self, prompt_len: int, active_slots=(),
                  hit=None) -> bool:
        """Admission needs a slot, pages for the prompt, and headroom for
        the in-flight requests that are about to cross a page boundary —
        reserving those avoids admit/preempt ping-pong under pressure.

        With a prefix-cache ``hit`` only the cold suffix's pages must be
        found: the shared run is already resident.  Spendable headroom is
        free pages plus what the cache can reclaim, *minus* the hit's
        cache-only pages — attaching pins those, so counting them as
        reclaimable too would promise the same page twice."""
        if self.num_free == 0 or prompt_len > self.max_len:
            return False
        imminent = sum(
            1 for s in active_slots
            if self.lengths[s] >= self._pages_held[s] * self.page_size)
        need = self.pages_for(prompt_len)
        avail = self.free_pages + self.reclaimable_pages
        if hit is not None and hit.pages:
            need -= len(hit.pages)
            avail -= hit.pinned
        return avail >= need + imminent

    def can_ever_serve(self, n_tokens: int) -> bool:
        """Whether a request resident at `n_tokens` could ever fit an
        otherwise-empty pool (needs its pages all at once)."""
        return n_tokens <= self.max_len and \
            self.pages_for(n_tokens) <= self.num_pages - 1

    # -- slot / page lifecycle ---------------------------------------------
    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_slots} KV slots are in flight")
        return self._free.pop()

    def free(self, slot: int) -> None:
        """Release `slot` and drop one reference on each of its pages —
        shared prefix pages another request (or the cache) still holds
        stay resident; sole-owner pages return to the free list."""
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range")
        if self._free.is_free(slot):
            raise ValueError(f"slot {slot} is already free")
        for i in range(int(self._pages_held[slot])):
            self.release_page(int(self.page_table[slot, i]))
        self.page_table[slot] = 0       # dead writes land in junk page 0
        self._pages_held[slot] = 0
        self.lengths[slot] = 0
        self._free.push(slot)
        if self.prefix_cache is not None:
            # this free may have turned shared pages into cache-only ones;
            # keep the cache inside its LRU pin budget
            self.prefix_cache.enforce_budget()

    def release_page(self, page: int) -> None:
        """Drop one reference on `page`; free it at refcount zero.  A
        cache-pinned page whose last request-reference just left becomes
        reclaimable (the cache's own reference keeps it resident)."""
        self.page_refs[page] -= 1
        if self.page_refs[page] == 0:
            self._free_pages.push(page)
        elif self.page_refs[page] < 0:
            raise ValueError(f"page {page} released below zero references")
        elif self.page_refs[page] == 1 and self.page_cached[page]:
            self._cache_only += 1

    def pin_page(self, page: int) -> None:
        """The prefix cache takes its reference on `page` (cell insert);
        the inserting request still holds it, so it is shared, not
        cache-only."""
        self.page_refs[page] += 1
        self.page_cached[page] = True

    def unpin_page(self, page: int) -> None:
        """The prefix cache drops its reference on `page` (cell evict)."""
        if self.page_refs[page] == 1:
            self._cache_only -= 1
        self.page_cached[page] = False
        self.release_page(page)

    def adopt_run(self, slot: int, pages) -> None:
        """Install a shared page run as the head of `slot`'s page table
        (prefix-cache hit), taking one reference per page.  The slot must
        hold nothing yet; ``reserve_prefix`` then extends it with the
        cold suffix's own pages."""
        if self._pages_held[slot]:
            raise ValueError(
                f"slot {slot} already holds {self._pages_held[slot]} pages; "
                f"a shared run must be adopted first")
        for i, page in enumerate(pages):
            if self.page_refs[page] == 1 and self.page_cached[page]:
                self._cache_only -= 1   # cache-only -> shared again
            self.page_refs[page] += 1
            self.page_table[slot, i] = page
        self._pages_held[slot] = len(pages)

    def _grow(self, slot: int) -> bool:
        """Append one page to `slot`; False when the pool is starved.
        A starved free list reclaims LRU cache-only prefix pages first —
        the cache layer gives way before any request is preempted."""
        held = int(self._pages_held[slot])
        if held >= self.max_pages:
            raise PoolExhausted(
                f"slot {slot} already holds max_pages={self.max_pages}")
        if not self._free_pages and self.prefix_cache is not None:
            self.prefix_cache.reclaim(1)
        if not self._free_pages:
            return False
        page = self._free_pages.pop()
        self.page_refs[page] = 1
        self.page_cached[page] = False
        self.page_table[slot, held] = page
        self._pages_held[slot] = held + 1
        return True

    # -- cache plumbing ----------------------------------------------------
    def insert(self, slot: int, prefill_cache: dict) -> None:
        """Allocate pages for a (batch=1) prefill cache and scatter it in.

        Legacy/test path — it costs one extra copy of the prompt's KV:
        the contiguous ``(1, s)`` cache is materialized by the prefill
        step and then re-scattered through the page table.  The serving
        engine now writes through ``reserve_prefix`` + the chunked
        prefill step, which scatters each chunk's KV to its final
        page/offset directly."""
        pk, pv = prefill_cache["k"], prefill_cache["v"]
        s = pk.shape[2]
        if s > self.max_len:
            raise ValueError(f"prefill length {s} > pool max_len {self.max_len}")
        self.reserve_prefix(slot, s)
        self.cache = _scatter_insert_paged(
            self.cache, jnp.int32(slot),
            jnp.asarray(self.page_table[slot]), pk, pv)
        self.lengths[slot] = s

    def reserve_prefix(self, slot: int, n_tokens: int) -> None:
        """Grow `slot` to hold an `n_tokens` prompt before chunked prefill
        writes into it (all pages up front — the same reservation point
        blocking admission used, so admission order is unchanged)."""
        if n_tokens > self.max_len:
            raise ValueError(
                f"prefix of {n_tokens} tokens > pool max_len {self.max_len}")
        need = self.pages_for(n_tokens)
        if need - int(self._pages_held[slot]) > \
                self.free_pages + self.reclaimable_pages:
            raise PoolExhausted(
                f"prefix of {n_tokens} tokens needs {need} pages, "
                f"{self.free_pages} free")
        for _ in range(need - int(self._pages_held[slot])):
            self._grow(slot)

    def chunk_extras(self, slot: int) -> tuple:
        """The slot's page-table row — the chunk step scatters through it."""
        return (jnp.asarray(self.page_table[slot]),)

    @property
    def kv_bound_cap(self) -> int:
        return self.max_pages * self.page_size

    def adopt(self, new_cache: dict) -> None:
        self.cache = new_cache

    def set_length(self, slot: int, n_tokens: int) -> None:
        self.lengths[slot] = n_tokens

    def prepare_decode(self, active_slots) -> list:
        """Grow every active slot whose next token crosses into a fresh
        page; returns the slots the pool could not serve (page-starved),
        in the deterministic order they were visited."""
        starved = []
        for slot in active_slots:
            if self.lengths[slot] >= self._pages_held[slot] * self.page_size:
                if not self._grow(slot):
                    starved.append(slot)
        return starved

    def decode_extras(self) -> tuple:
        return (jnp.asarray(self.page_table),)

    def grow_for_burst(self, slot: int, want_tokens: int) -> int:
        """Opportunistically back up to `want_tokens` KV positions past
        `slot`'s current length for a speculative verify burst, using ONLY
        genuinely free pages — never the prefix cache's reclaimable pages
        and never another request's (no preemption): a burst is a
        throughput bonus, not a reservation, so it must not change
        admission or eviction behaviour.  Returns how many positions are
        backed (>= 1 after ``prepare_decode`` granted the mandatory next
        token); verify writes beyond that divert to junk page 0 via the
        attention ok-guard and the scheduler caps acceptance to the
        backed count."""
        target = min(int(self.lengths[slot]) + want_tokens, self.max_len)
        while int(self._pages_held[slot]) * self.page_size < target:
            held = int(self._pages_held[slot])
            if held >= self.max_pages or not self._free_pages:
                break
            page = self._free_pages.pop()
            self.page_refs[page] = 1
            self.page_cached[page] = False
            self.page_table[slot, held] = page
            self._pages_held[slot] = held + 1
        backed = int(self._pages_held[slot]) * self.page_size \
            - int(self.lengths[slot])
        return max(min(backed, want_tokens,
                       self.max_len - int(self.lengths[slot])), 0)

    def sync_index(self) -> None:
        """Re-upload the host length mirror as the device index (see the
        contiguous pool's ``sync_index``)."""
        self.cache = dict(self.cache,
                          index=jnp.asarray(self.lengths, jnp.int32))

    def update(self, new_cache: dict, active_slots=()) -> None:
        self.cache = new_cache
        for slot in active_slots:
            self.lengths[slot] += 1
