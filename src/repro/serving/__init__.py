"""Continuous-batching serving subsystem (KV pool + scheduler + engine)."""

from repro.serving.engine import ServeEngine, SERVABLE_FAMILIES
from repro.serving.pool import KVCachePool, PoolExhausted
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     ServeStats)
from repro.serving.trace import uniform_trace, zipf_trace

__all__ = ["ServeEngine", "SERVABLE_FAMILIES", "KVCachePool", "PoolExhausted",
           "Request", "RequestResult", "Scheduler", "ServeStats",
           "uniform_trace", "zipf_trace"]
