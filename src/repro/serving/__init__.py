"""Continuous-batching serving subsystem.

Layered as: KV pool (contiguous ``KVCachePool`` or page-table
``PagedKVCachePool`` memory layouts, with refcounted pages) +
``PrefixCache`` (shared-prefix KV page-run reuse over a paged pool) +
``Scheduler`` (admission, in-flight batching, page-pressure preemption,
per-request sampling, draft-then-verify speculative decoding) +
``ServeEngine`` facade (tuner-sized pools, jitted steps, ``kv_layout``
selection, ``spec_k``) + ``ReplicaRouter`` (N engines behind one
admission queue with pluggable routing policies and overflow
re-routing).
"""

from repro.serving.engine import KV_LAYOUTS, SERVABLE_FAMILIES, ServeEngine
from repro.serving.pool import KVCachePool, PagedKVCachePool, PoolExhausted
from repro.serving.prefill import PrefillManager
from repro.serving.prefix_cache import PrefixCache, prefix_key
from repro.serving.router import (ROUTE_POLICIES, ReplicaRouter, RouterStats,
                                  prefix_replica)
from repro.serving.sampling import K_CAP, effective_top_k, make_sampler
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     ServeStats, VirtualClock)
from repro.serving.spec import Drafter, NGramDrafter
from repro.serving.trace import (longprompt_trace, repetitive_trace,
                                 sharedprefix_trace, trace_repetitiveness,
                                 uniform_trace, zipf_trace)

__all__ = ["ServeEngine", "SERVABLE_FAMILIES", "KV_LAYOUTS", "KVCachePool",
           "PagedKVCachePool", "PoolExhausted", "PrefillManager",
           "PrefixCache", "prefix_key", "ReplicaRouter", "RouterStats",
           "ROUTE_POLICIES", "prefix_replica", "Request", "RequestResult",
           "Scheduler", "ServeStats", "VirtualClock", "make_sampler",
           "K_CAP", "effective_top_k", "Drafter", "NGramDrafter",
           "longprompt_trace", "repetitive_trace", "sharedprefix_trace",
           "trace_repetitiveness", "uniform_trace", "zipf_trace"]
