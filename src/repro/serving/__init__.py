"""Continuous-batching serving subsystem.

Layered as: KV pool (contiguous ``KVCachePool`` or page-table
``PagedKVCachePool`` memory layouts, with refcounted pages) +
``PrefixCache`` (shared-prefix KV page-run reuse over a paged pool) +
``Scheduler`` (admission, in-flight batching, page-pressure preemption,
per-request sampling, draft-then-verify speculative decoding) +
``ServeEngine`` facade (tuner-sized pools, jitted steps, ``kv_layout``
selection, ``spec_k``) + ``ReplicaRouter`` (N engines behind one
admission queue with pluggable routing policies, overflow re-routing,
open-loop arrival release, SLO-aware admission, and ``AutoscalePolicy``
fleet autoscaling) + ``telemetry`` (vstep-clocked ``Tracer`` spans and
ring events, the ``MetricsRegistry`` schema both ``to_metrics`` views
are built on, and the Prometheus / Chrome-trace exporters).
"""

from repro.serving.engine import KV_LAYOUTS, SERVABLE_FAMILIES, ServeEngine
from repro.serving.pool import KVCachePool, PagedKVCachePool, PoolExhausted
from repro.serving.prefill import PrefillManager
from repro.serving.prefix_cache import PrefixCache, prefix_key
from repro.serving.router import (ADMISSION_MODES, ROUTE_POLICIES,
                                  AutoscaleEvent, AutoscalePolicy,
                                  RejectedRequest, ReplicaRouter,
                                  RouterStats, prefix_replica,
                                  replay_peak_replicas)
from repro.serving.sampling import K_CAP, effective_top_k, make_sampler
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     ServeStats, VirtualClock,
                                     percentile_steps)
from repro.serving.spec import Drafter, NGramDrafter
from repro.serving.telemetry import (EVENT_KINDS, PHASES, ROUTER_SCHEMA,
                                     SERVE_SCHEMA, MetricSpec,
                                     MetricsRegistry, Span, TraceEvent,
                                     Tracer, chrome_trace, json_sanitize,
                                     prometheus_text, write_chrome_trace)
from repro.serving.trace import (ARRIVAL_MODES, bursty_arrivals,
                                 longprompt_trace, poisson_arrivals,
                                 repetitive_trace, sharedprefix_trace,
                                 trace_repetitiveness, uniform_trace,
                                 with_arrivals, zipf_trace)

__all__ = ["ServeEngine", "SERVABLE_FAMILIES", "KV_LAYOUTS", "KVCachePool",
           "PagedKVCachePool", "PoolExhausted", "PrefillManager",
           "PrefixCache", "prefix_key", "ReplicaRouter", "RouterStats",
           "ROUTE_POLICIES", "ADMISSION_MODES", "AutoscalePolicy",
           "AutoscaleEvent", "RejectedRequest", "prefix_replica",
           "Request", "RequestResult", "Scheduler", "ServeStats",
           "VirtualClock", "percentile_steps", "make_sampler",
           "K_CAP", "effective_top_k", "Drafter", "NGramDrafter",
           "ARRIVAL_MODES", "poisson_arrivals", "bursty_arrivals",
           "with_arrivals", "longprompt_trace", "repetitive_trace",
           "sharedprefix_trace", "trace_repetitiveness", "uniform_trace",
           "zipf_trace", "Tracer", "Span", "TraceEvent", "MetricSpec",
           "MetricsRegistry", "SERVE_SCHEMA", "ROUTER_SCHEMA", "PHASES",
           "EVENT_KINDS", "prometheus_text", "chrome_trace",
           "write_chrome_trace", "json_sanitize", "replay_peak_replicas"]
