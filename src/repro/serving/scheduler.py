"""Request scheduler: admission, in-flight batching, eviction, preemption.

Two policies over the same KV pool (contiguous or paged) and jitted steps:

* ``continuous`` — between decode steps, every freed slot is immediately
  re-prefilled from the queue (continuous batching / in-flight batching).
* ``static`` — gang scheduling: admit a full batch, drain it until the
  *last* request finishes, then admit the next batch.  This is the old
  ``launch/serve.py`` behaviour, kept as the benchmark baseline.

The scheduler is layout-agnostic: it admits through ``pool.can_admit``
(contiguous pools count free *slots*; paged pools count free *pages*,
with headroom reserved for in-flight requests about to cross a page
boundary), grows paged slots before each decode step via
``pool.prepare_decode``, and — when the page pool is starved mid-decode —
**preempts** the youngest in-flight request: its slot and pages are
freed and it is re-queued at the front.  A preempted request is resumed
by re-prefilling its prompt plus everything it already generated, which
reproduces its KV state exactly, so preemption never changes the token
stream (greedy, and sampled too: the sampler keys on request id and
generation step, not on slot or time).

The preemption victim is the request with the **youngest admission step**;
two requests admitted in the same step (between the same pair of decode
iterations) tie-break on the **highest request id** — a property of the
request, not of queue insertion order, so the victim is deterministic
however the trace was assembled.

Sampling is per-request: ``Request.temperature`` / ``Request.top_k``
ride through per-slot vectors into one jitted sampler call per step
(``serving/sampling.py``); the default (temperature 0) is greedy argmax.
The loop is host-driven, one slot-wise decode over the whole pool per
iteration, one device->host sync per step for the sampled tokens.
Everything is deterministic for a fixed trace.

``run()`` drains a whole trace, but every phase is also exposed as a
step-wise API (``reset`` / ``try_admit`` / ``admit_from_queue`` / ``step``
/ ``stats``) so a ``ReplicaRouter`` can drive N schedulers in lockstep,
routing between them at admission time and catching solo page starvation
(``step(evict_on_starvation=True)`` hands the evicted entry back for
re-routing instead of raising).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.serving.pool import PoolExhausted
from repro.serving.sampling import K_CAP


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (s,) int32 token ids
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 = greedy
    top_k: int = 0                # 0 = no top-k filter


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    max_new_tokens: int
    slot: int = -1
    tokens: list = dataclasses.field(default_factory=list)
    preemptions: int = 0
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit


@dataclasses.dataclass
class ServeStats:
    results: list
    wall_s: float
    decode_steps: int
    generated_tokens: int
    occupancy: float              # mean active-slot fraction per decode step
    peak_active: int = 0          # max concurrent in-flight requests
    peak_resident_tokens: int = 0  # max KV tokens held across the pool
    preemptions: int = 0          # page-pressure evictions (paged pools)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)

    def summary(self) -> str:
        lat = [r.latency_s for r in self.results]
        pre = f", {self.preemptions} preemptions" if self.preemptions else ""
        return (f"{len(self.results)} requests, {self.generated_tokens} tokens "
                f"in {self.wall_s:.3f}s -> {self.tokens_per_s:.1f} tok/s | "
                f"{self.decode_steps} decode steps, "
                f"occupancy {self.occupancy:.0%}, "
                f"peak {self.peak_active} in flight{pre} | latency "
                f"mean {np.mean(lat):.3f}s p max {np.max(lat):.3f}s")


@dataclasses.dataclass
class _Entry:
    """A queued unit of work: a fresh request, or a preempted one carrying
    the result it must resume (tokens generated so far).  ``rerouted``
    marks a solo-starvation eviction a router handed back: its pool
    provably cannot finish the request, so re-dispatch must place it by
    the pessimistic residency bound even under an optimistic eos."""
    req: Request
    st: RequestResult | None = None
    rerouted: bool = False

    @property
    def pending_len(self) -> int:
        """Prompt length at (re-)admission: original prompt plus anything
        already generated before a preemption."""
        n = len(self.req.prompt)
        return n + len(self.st.tokens) if self.st is not None else n

    def remaining_new(self) -> int:
        """Generation budget left (fresh entries: the full request ask)."""
        if self.st is None:
            return self.req.max_new_tokens
        return self.st.max_new_tokens - len(self.st.tokens)


@dataclasses.dataclass
class _Active:
    req: Request
    st: RequestResult
    admit_step: int               # decode step at admission; youngest is
    #                               the preemption victim, ties by req.rid


class Scheduler:
    """Drains a request queue through repeated slot-wise decode calls."""

    def __init__(self, pool, prefill_fn, decode_fn,
                 eos_id: int | None = None, policy: str = "continuous",
                 sampler=None, clock=time.perf_counter):
        if policy not in ("continuous", "static"):
            raise ValueError(policy)
        self.pool = pool
        self.prefill_fn = prefill_fn        # (tokens (1,s)) -> logits, cache
        self.decode_fn = decode_fn          # (cache, tokens, active, *extras)
        self.eos_id = eos_id
        self.policy = policy
        self.sampler = sampler              # None -> greedy argmax
        self.clock = clock
        self.all_greedy = False
        self.reset()

    # -- step-wise state ----------------------------------------------------
    def reset(self, t0: float | None = None) -> None:
        """Fresh drain state (queue, active set, counters, host mirrors)."""
        S = self.pool.num_slots
        self.queue: deque = deque()
        self.active: dict[int, _Active] = {}
        self.done: list[RequestResult] = []
        self._last_tokens = np.zeros((S, 1), np.int32)
        self._active_mask = np.zeros((S,), np.int32)
        self._steps = 0
        self._busy = 0
        self._peak = 0
        self._peak_resident = 0
        self._preemptions = 0
        self._t0 = self.clock() if t0 is None else t0

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    def validate(self, requests) -> None:
        """Reject up front what this pool could never serve: a mid-run
        rejection would throw away the stats of every request already
        served in a drain.  Without an eos, generation is deterministic
        full-length, so a paged request whose worst-case residency
        outstrips the whole page pool is *guaranteed* to starve.  (With
        an eos the request might stop early; it is admitted optimistically
        and the mid-decode starvation path still raises.)"""
        for req in requests:
            if len(req.prompt) > self.pool.max_len:
                raise ValueError(
                    f"request {req.rid}: prompt ({len(req.prompt)}) does "
                    f"not fit pool max_len {self.pool.max_len}")
            if not 0 <= req.top_k <= K_CAP:
                raise ValueError(
                    f"request {req.rid}: top_k {req.top_k} not in "
                    f"[0, {K_CAP}]")
            worst = self.worst_resident(_Entry(req))
            if not self.pool.can_ever_serve(worst):
                raise PoolExhausted(
                    f"request {req.rid} needs {worst} resident KV tokens "
                    f"but the pool can never hold that many")

    def worst_resident(self, entry: _Entry) -> int:
        """Max KV tokens `entry` will hold if admitted here (eos: only the
        pending prefill is certain; otherwise full-length generation is)."""
        if self.eos_id is not None:
            return entry.pending_len
        return min(entry.pending_len + entry.remaining_new() - 1,
                   self.pool.max_len)

    # -- sampling ----------------------------------------------------------
    def _sample_rows(self, logits_last, entries):
        """One sampler call over rows; entries[i] styles row i (None rows
        sample greedily with a dead key)."""
        if self.sampler is None or self.all_greedy:
            return np.asarray(jnp.argmax(logits_last, axis=-1))
        n = logits_last.shape[0]
        temps = np.zeros((n,), np.float32)
        topks = np.zeros((n,), np.int32)
        rids = np.zeros((n,), np.int32)
        steps = np.zeros((n,), np.int32)
        for i, en in enumerate(entries):
            if en is None:
                continue
            temps[i] = en.req.temperature
            topks[i] = en.req.top_k
            rids[i] = en.req.rid
            steps[i] = len(en.st.tokens)
        return np.asarray(self.sampler(
            logits_last, jnp.asarray(temps), jnp.asarray(topks),
            jnp.asarray(rids), jnp.asarray(steps)))

    # -- admission ---------------------------------------------------------
    def can_admit(self, entry: _Entry) -> bool:
        return self.pool.can_admit(entry.pending_len, tuple(self.active))

    def try_admit(self, entry: _Entry) -> bool:
        """Router-facing single-entry admission; False when full."""
        if not self.can_admit(entry):
            return False
        self._admit(entry)
        return True

    def admit_from_queue(self) -> None:
        """Admit from the local queue head while the pool has room."""
        while self.queue and self.can_admit(self.queue[0]):
            self._admit(self.queue.popleft())

    def _admit(self, entry: _Entry) -> None:
        now = self.clock()
        req = entry.req
        if entry.st is None:
            s = len(req.prompt)
            budget = self.pool.max_len - s + 1   # writes stop at max_len - 1
            st = RequestResult(
                rid=req.rid, prompt_len=s,
                max_new_tokens=min(req.max_new_tokens, budget),
                t_submit=getattr(req, "_t_submit", now))
            st.t_admit = now
            prompt = np.asarray(req.prompt, np.int32)
        else:                                    # resume after preemption
            st = entry.st
            prompt = np.concatenate([np.asarray(req.prompt, np.int32),
                                     np.asarray(st.tokens, np.int32)])
        # prefill lengths are bucketed to powers of two so resumes (whose
        # lengths are arbitrary) reuse one compiled prefill per bucket:
        # the prompt is right-padded, logits are read at the true last
        # position, and the cache is sliced back before insertion (causal
        # attention keeps positions < n independent of the padding)
        n = len(prompt)
        pad = 1 << (n - 1).bit_length()
        if pad == n:
            logits, cache = self.prefill_fn(jnp.asarray(prompt)[None, :])
        else:
            padded = np.zeros((pad,), np.int32)
            padded[:n] = prompt
            logits, cache = self.prefill_fn(jnp.asarray(padded)[None, :],
                                            n - 1)
            cache = {"k": cache["k"][:, :, :n], "v": cache["v"][:, :, :n],
                     "index": jnp.asarray(n, jnp.int32)}
        tok = int(self._sample_rows(logits[:, -1], [_Active(req, st, 0)])[0])
        if entry.st is None:
            st.t_first = self.clock()
        st.tokens.append(tok)
        if len(st.tokens) >= st.max_new_tokens or tok == self.eos_id:
            st.t_done = self.clock()
            self.done.append(st)
            return
        slot = self.pool.alloc()
        st.slot = slot
        self.pool.insert(slot, cache)
        self.active[slot] = _Active(req, st, self._steps)
        self._last_tokens[slot, 0] = tok
        self._active_mask[slot] = 1

    # -- preemption --------------------------------------------------------
    def _evict(self, slot: int) -> _Entry:
        """Free `slot` and return its request as a resumable entry."""
        en = self.active.pop(slot)
        en.st.slot = -1
        en.st.preemptions += 1
        self._active_mask[slot] = 0
        self._last_tokens[slot, 0] = 0
        self.pool.free(slot)                 # returns its pages
        return _Entry(en.req, en.st)

    def _preempt(self, slot: int) -> None:
        self.queue.appendleft(self._evict(slot))
        self._preemptions += 1

    # -- one decode iteration ----------------------------------------------
    def step(self, evict_on_starvation: bool = False) -> list:
        """One slot-wise decode over the active set.

        Paged pools grow slots crossing a page boundary first; starvation
        preempts the youngest in-flight request (ties by request id) until
        the step fits.  When the *sole* active request starves the pool can
        never make progress alone: raise ``PoolExhausted``, or — under a
        router (``evict_on_starvation``) — hand the evicted entry back for
        re-routing to a replica that can hold it.  Returns the evicted
        entries (empty in the single-engine path).
        """
        evicted = []
        while True:
            starved = self.pool.prepare_decode(sorted(self.active))
            if not starved:
                break
            if len(self.active) == 1:
                (slot,) = self.active
                if not evict_on_starvation:
                    raise PoolExhausted(
                        f"page starvation mid-decode: request "
                        f"{self.active[slot].req.rid} holds every page and "
                        f"still needs another — the page pool is too small "
                        f"for it")
                evicted.append(self._evict(slot))
                self._preemptions += 1
                return evicted               # nothing left to decode
            victim = max(self.active,
                         key=lambda sl: (self.active[sl].admit_step,
                                         self.active[sl].req.rid))
            self._preempt(victim)
        self._peak = max(self._peak, len(self.active))
        self._peak_resident = max(self._peak_resident,
                                  int(self.pool.lengths.sum()))
        logits, new_cache = self.decode_fn(
            self.pool.cache, jnp.asarray(self._last_tokens),
            jnp.asarray(self._active_mask), *self.pool.decode_extras())
        self.pool.update(new_cache, tuple(self.active))
        self._steps += 1
        self._busy += len(self.active)
        S = self.pool.num_slots
        rows = [self.active.get(i) for i in range(S)]
        toks = self._sample_rows(logits[:, -1], rows)
        now = self.clock()
        for slot, en in list(self.active.items()):
            st = en.st
            tok = int(toks[slot])
            st.tokens.append(tok)
            self._last_tokens[slot, 0] = tok
            if len(st.tokens) >= st.max_new_tokens or tok == self.eos_id:
                st.t_done = now
                self.done.append(st)
                del self.active[slot]
                self._active_mask[slot] = 0
                self._last_tokens[slot, 0] = 0
                self.pool.free(slot)
        return evicted

    # -- results -----------------------------------------------------------
    def stats(self) -> ServeStats:
        wall = self.clock() - self._t0
        done = sorted(self.done, key=lambda r: r.rid)
        return ServeStats(
            results=done, wall_s=wall, decode_steps=self._steps,
            generated_tokens=sum(len(r.tokens) for r in done),
            occupancy=self._busy / max(self._steps * self.pool.num_slots, 1),
            peak_active=self._peak, peak_resident_tokens=self._peak_resident,
            preemptions=self._preemptions)

    # -- main loop ---------------------------------------------------------
    def run(self, requests) -> ServeStats:
        requests = list(requests)
        self.validate(requests)
        # all-greedy traces skip the sampler (argmax is its temperature-0 /
        # top_k-1 special case, so this is a pure fast path)
        self.all_greedy = all(r.temperature <= 0 or r.top_k == 1
                              for r in requests)
        self.reset()
        for r in requests:
            r._t_submit = self._t0
            self.queue.append(_Entry(r))
        while self.has_work:
            if self.policy == "continuous" or not self.active:
                self.admit_from_queue()
            if not self.active:
                if self.queue:
                    en = self.queue[0]
                    raise PoolExhausted(
                        f"request {en.req.rid} ({en.pending_len} tokens) "
                        f"cannot be admitted into an otherwise idle pool — "
                        f"the KV pool is too small for it")
                continue
            self.step()
        return self.stats()
