"""Request scheduler: admission, in-flight batching, eviction, preemption.

Two policies over the same KV pool (contiguous or paged) and jitted steps:

* ``continuous`` — between decode steps, every freed slot is immediately
  re-prefilled from the queue (continuous batching / in-flight batching).
* ``static`` — gang scheduling: admit a full batch, drain it until the
  *last* request finishes, then admit the next batch.  This is the old
  ``launch/serve.py`` behaviour, kept as the benchmark baseline.

The scheduler is layout-agnostic: it admits through ``pool.can_admit``
(contiguous pools count free *slots*; paged pools count free *pages*,
with headroom reserved for in-flight requests about to cross a page
boundary), grows paged slots before each decode step via
``pool.prepare_decode``, and — when the page pool is starved mid-decode —
**preempts** the youngest in-flight request: its slot and pages are
freed and it is re-queued at the front.  A preempted request is resumed
by re-prefilling its prompt plus everything it already generated, which
reproduces its KV state exactly, so preemption never changes the token
stream (greedy, and sampled too: the sampler keys on request id and
generation step, not on slot or time).

Sampling is per-request: ``Request.temperature`` / ``Request.top_k``
ride through per-slot vectors into one jitted sampler call per step
(``serving/sampling.py``); the default (temperature 0) is greedy argmax.
The loop is host-driven, one slot-wise decode over the whole pool per
iteration, one device->host sync per step for the sampled tokens.
Everything is deterministic for a fixed trace.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.serving.pool import PoolExhausted
from repro.serving.sampling import K_CAP


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (s,) int32 token ids
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 = greedy
    top_k: int = 0                # 0 = no top-k filter


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    max_new_tokens: int
    slot: int = -1
    tokens: list = dataclasses.field(default_factory=list)
    preemptions: int = 0
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit


@dataclasses.dataclass
class ServeStats:
    results: list
    wall_s: float
    decode_steps: int
    generated_tokens: int
    occupancy: float              # mean active-slot fraction per decode step
    peak_active: int = 0          # max concurrent in-flight requests
    peak_resident_tokens: int = 0  # max KV tokens held across the pool
    preemptions: int = 0          # page-pressure evictions (paged pools)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)

    def summary(self) -> str:
        lat = [r.latency_s for r in self.results]
        pre = f", {self.preemptions} preemptions" if self.preemptions else ""
        return (f"{len(self.results)} requests, {self.generated_tokens} tokens "
                f"in {self.wall_s:.3f}s -> {self.tokens_per_s:.1f} tok/s | "
                f"{self.decode_steps} decode steps, "
                f"occupancy {self.occupancy:.0%}, "
                f"peak {self.peak_active} in flight{pre} | latency "
                f"mean {np.mean(lat):.3f}s p max {np.max(lat):.3f}s")


@dataclasses.dataclass
class _Entry:
    """A queued unit of work: a fresh request, or a preempted one carrying
    the result it must resume (tokens generated so far)."""
    req: Request
    st: RequestResult | None = None

    @property
    def pending_len(self) -> int:
        """Prompt length at (re-)admission: original prompt plus anything
        already generated before a preemption."""
        n = len(self.req.prompt)
        return n + len(self.st.tokens) if self.st is not None else n


@dataclasses.dataclass
class _Active:
    req: Request
    st: RequestResult
    admit_seq: int                # monotone; youngest = preemption victim


class Scheduler:
    """Drains a request queue through repeated slot-wise decode calls."""

    def __init__(self, pool, prefill_fn, decode_fn,
                 eos_id: int | None = None, policy: str = "continuous",
                 sampler=None, clock=time.perf_counter):
        if policy not in ("continuous", "static"):
            raise ValueError(policy)
        self.pool = pool
        self.prefill_fn = prefill_fn        # (tokens (1,s)) -> logits, cache
        self.decode_fn = decode_fn          # (cache, tokens, active, *extras)
        self.eos_id = eos_id
        self.policy = policy
        self.sampler = sampler              # None -> greedy argmax
        self.clock = clock
        self._admit_seq = 0
        self._all_greedy = False

    # -- sampling ----------------------------------------------------------
    def _sample_rows(self, logits_last, entries):
        """One sampler call over rows; entries[i] styles row i (None rows
        sample greedily with a dead key)."""
        if self.sampler is None or self._all_greedy:
            return np.asarray(jnp.argmax(logits_last, axis=-1))
        n = logits_last.shape[0]
        temps = np.zeros((n,), np.float32)
        topks = np.zeros((n,), np.int32)
        rids = np.zeros((n,), np.int32)
        steps = np.zeros((n,), np.int32)
        for i, en in enumerate(entries):
            if en is None:
                continue
            temps[i] = en.req.temperature
            topks[i] = en.req.top_k
            rids[i] = en.req.rid
            steps[i] = len(en.st.tokens)
        return np.asarray(self.sampler(
            logits_last, jnp.asarray(temps), jnp.asarray(topks),
            jnp.asarray(rids), jnp.asarray(steps)))

    # -- admission ---------------------------------------------------------
    def _admit(self, entry: _Entry, active, last_tokens, active_mask, done):
        now = self.clock()
        req = entry.req
        if entry.st is None:
            s = len(req.prompt)
            budget = self.pool.max_len - s + 1   # writes stop at max_len - 1
            st = RequestResult(
                rid=req.rid, prompt_len=s,
                max_new_tokens=min(req.max_new_tokens, budget),
                t_submit=getattr(req, "_t_submit", now))
            st.t_admit = now
            prompt = np.asarray(req.prompt, np.int32)
        else:                                    # resume after preemption
            st = entry.st
            prompt = np.concatenate([np.asarray(req.prompt, np.int32),
                                     np.asarray(st.tokens, np.int32)])
        # prefill lengths are bucketed to powers of two so resumes (whose
        # lengths are arbitrary) reuse one compiled prefill per bucket:
        # the prompt is right-padded, logits are read at the true last
        # position, and the cache is sliced back before insertion (causal
        # attention keeps positions < n independent of the padding)
        n = len(prompt)
        pad = 1 << (n - 1).bit_length()
        if pad == n:
            logits, cache = self.prefill_fn(jnp.asarray(prompt)[None, :])
        else:
            padded = np.zeros((pad,), np.int32)
            padded[:n] = prompt
            logits, cache = self.prefill_fn(jnp.asarray(padded)[None, :],
                                            n - 1)
            cache = {"k": cache["k"][:, :, :n], "v": cache["v"][:, :, :n],
                     "index": jnp.asarray(n, jnp.int32)}
        tok = int(self._sample_rows(logits[:, -1], [_Active(req, st, 0)])[0])
        if entry.st is None:
            st.t_first = self.clock()
        st.tokens.append(tok)
        if len(st.tokens) >= st.max_new_tokens or tok == self.eos_id:
            st.t_done = self.clock()
            done.append(st)
            return
        slot = self.pool.alloc()
        st.slot = slot
        self.pool.insert(slot, cache)
        active[slot] = _Active(req, st, self._admit_seq)
        self._admit_seq += 1
        last_tokens[slot, 0] = tok
        active_mask[slot] = 1

    # -- preemption --------------------------------------------------------
    def _preempt(self, slot, active, last_tokens, active_mask, queue):
        en = active.pop(slot)
        en.st.slot = -1
        en.st.preemptions += 1
        active_mask[slot] = 0
        last_tokens[slot, 0] = 0
        self.pool.free(slot)                 # returns its pages
        queue.appendleft(_Entry(en.req, en.st))

    # -- main loop ---------------------------------------------------------
    def run(self, requests) -> ServeStats:
        # validate up front: a mid-run rejection would throw away the
        # stats of every request already served in this drain.  Without an
        # eos, generation is deterministic full-length, so a paged request
        # whose worst-case residency outstrips the whole page pool is
        # *guaranteed* to starve — reject it here instead of mid-decode.
        # (With an eos the request might stop early; it is admitted
        # optimistically and the mid-decode starvation path still raises.)
        for req in requests:
            if len(req.prompt) > self.pool.max_len:
                raise ValueError(
                    f"request {req.rid}: prompt ({len(req.prompt)}) does "
                    f"not fit pool max_len {self.pool.max_len}")
            if not 0 <= req.top_k <= K_CAP:
                raise ValueError(
                    f"request {req.rid}: top_k {req.top_k} not in "
                    f"[0, {K_CAP}]")
            worst = len(req.prompt) if self.eos_id is not None else \
                min(len(req.prompt) + req.max_new_tokens - 1,
                    self.pool.max_len)
            if not self.pool.can_ever_serve(worst):
                raise PoolExhausted(
                    f"request {req.rid} needs {worst} resident KV tokens "
                    f"but the pool can never hold that many")
        # all-greedy traces skip the sampler (argmax is its temperature-0 /
        # top_k-1 special case, so this is a pure fast path)
        self._all_greedy = all(r.temperature <= 0 or r.top_k == 1
                               for r in requests)
        queue = deque(_Entry(r) for r in requests)
        done: list[RequestResult] = []
        active: dict[int, _Active] = {}
        S = self.pool.num_slots
        last_tokens = np.zeros((S, 1), np.int32)
        active_mask = np.zeros((S,), np.int32)

        t0 = self.clock()
        for en in queue:
            en.req._t_submit = t0
        steps = 0
        busy = 0
        peak = 0
        peak_resident = 0
        preemptions = 0
        while queue or active:
            if self.policy == "continuous" or not active:
                while queue and self.pool.can_admit(queue[0].pending_len,
                                                    tuple(active)):
                    self._admit(queue.popleft(), active, last_tokens,
                                active_mask, done)
            if not active:
                if queue:
                    en = queue[0]
                    raise PoolExhausted(
                        f"request {en.req.rid} ({en.pending_len} tokens) "
                        f"cannot be admitted into an otherwise idle pool — "
                        f"the KV pool is too small for it")
                continue
            # paged pools grow slots crossing a page boundary; starvation
            # preempts the youngest in-flight request until the step fits
            while True:
                starved = self.pool.prepare_decode(sorted(active))
                if not starved:
                    break
                if len(active) == 1:
                    (slot,) = active
                    raise PoolExhausted(
                        f"page starvation mid-decode: request "
                        f"{active[slot].req.rid} holds every page and still "
                        f"needs another — the page pool is too small for it")
                victim = max(active, key=lambda sl: active[sl].admit_seq)
                self._preempt(victim, active, last_tokens, active_mask, queue)
                preemptions += 1
            peak = max(peak, len(active))
            peak_resident = max(peak_resident, int(self.pool.lengths.sum()))
            logits, new_cache = self.decode_fn(
                self.pool.cache, jnp.asarray(last_tokens),
                jnp.asarray(active_mask), *self.pool.decode_extras())
            self.pool.update(new_cache, tuple(active))
            steps += 1
            busy += len(active)
            rows = [active.get(i) for i in range(S)]
            toks = self._sample_rows(logits[:, -1], rows)
            now = self.clock()
            for slot, en in list(active.items()):
                st = en.st
                tok = int(toks[slot])
                st.tokens.append(tok)
                last_tokens[slot, 0] = tok
                if len(st.tokens) >= st.max_new_tokens or tok == self.eos_id:
                    st.t_done = now
                    done.append(st)
                    del active[slot]
                    active_mask[slot] = 0
                    last_tokens[slot, 0] = 0
                    self.pool.free(slot)

        wall = self.clock() - t0
        done.sort(key=lambda r: r.rid)
        return ServeStats(
            results=done, wall_s=wall, decode_steps=steps,
            generated_tokens=sum(len(r.tokens) for r in done),
            occupancy=busy / max(steps * S, 1),
            peak_active=peak, peak_resident_tokens=peak_resident,
            preemptions=preemptions)
