"""Request scheduler: admission, in-flight batching, eviction-on-completion.

Two policies over the same KVCachePool and jitted steps:

* ``continuous`` — between decode steps, every freed slot is immediately
  re-prefilled from the queue (continuous batching / in-flight batching).
* ``static`` — gang scheduling: admit a full batch, drain it until the
  *last* request finishes, then admit the next batch.  This is the old
  ``launch/serve.py`` behaviour, kept as the benchmark baseline.

The loop is host-driven: one slot-wise decode over the whole pool per
iteration, greedy (argmax) sampling, one device->host sync per step for
the sampled tokens.  Everything is deterministic for a fixed trace.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.serving.pool import KVCachePool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (s,) int32 token ids
    max_new_tokens: int = 16


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    max_new_tokens: int
    slot: int = -1
    tokens: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit


@dataclasses.dataclass
class ServeStats:
    results: list
    wall_s: float
    decode_steps: int
    generated_tokens: int
    occupancy: float              # mean active-slot fraction per decode step

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)

    def summary(self) -> str:
        lat = [r.latency_s for r in self.results]
        return (f"{len(self.results)} requests, {self.generated_tokens} tokens "
                f"in {self.wall_s:.3f}s -> {self.tokens_per_s:.1f} tok/s | "
                f"{self.decode_steps} decode steps, "
                f"occupancy {self.occupancy:.0%} | latency "
                f"mean {np.mean(lat):.3f}s p max {np.max(lat):.3f}s")


class Scheduler:
    """Drains a request queue through repeated slot-wise decode calls."""

    def __init__(self, pool: KVCachePool, prefill_fn, decode_fn,
                 eos_id: int | None = None, policy: str = "continuous",
                 clock=time.perf_counter):
        if policy not in ("continuous", "static"):
            raise ValueError(policy)
        self.pool = pool
        self.prefill_fn = prefill_fn        # (tokens (1,s)) -> logits, cache
        self.decode_fn = decode_fn          # (cache, tokens, active) -> ...
        self.eos_id = eos_id
        self.policy = policy
        self.clock = clock

    # -- admission ---------------------------------------------------------
    def _admit(self, req: Request, active, last_tokens, active_mask, done):
        now = self.clock()
        s = len(req.prompt)
        budget = self.pool.max_len - s + 1   # writes stop at max_len - 1
        max_new = min(req.max_new_tokens, budget)
        st = RequestResult(rid=req.rid, prompt_len=s, max_new_tokens=max_new,
                           t_submit=getattr(req, "_t_submit", now))
        st.t_admit = now
        tokens = jnp.asarray(np.asarray(req.prompt, np.int32))[None, :]
        logits, cache = self.prefill_fn(tokens)
        first = int(np.asarray(jnp.argmax(logits[0, -1], axis=-1)))
        st.t_first = self.clock()
        st.tokens.append(first)
        if max_new == 1 or first == self.eos_id:
            st.t_done = st.t_first
            done.append(st)
            return
        slot = self.pool.alloc()
        st.slot = slot
        self.pool.insert(slot, cache)
        active[slot] = st
        last_tokens[slot, 0] = first
        active_mask[slot] = 1

    # -- main loop ---------------------------------------------------------
    def run(self, requests) -> ServeStats:
        # validate up front: a mid-run rejection would throw away the
        # stats of every request already served in this drain
        for req in requests:
            if len(req.prompt) > self.pool.max_len:
                raise ValueError(
                    f"request {req.rid}: prompt ({len(req.prompt)}) does "
                    f"not fit pool max_len {self.pool.max_len}")
        queue = deque(requests)
        done: list[RequestResult] = []
        active: dict[int, RequestResult] = {}
        S = self.pool.num_slots
        last_tokens = np.zeros((S, 1), np.int32)
        active_mask = np.zeros((S,), np.int32)

        t0 = self.clock()
        for r in queue:
            r._t_submit = t0
        steps = 0
        busy = 0
        while queue or active:
            if self.policy == "continuous" or not active:
                while queue and self.pool.num_free:
                    self._admit(queue.popleft(), active, last_tokens,
                                active_mask, done)
            if not active:
                continue
            logits, new_cache = self.decode_fn(
                self.pool.cache, jnp.asarray(last_tokens),
                jnp.asarray(active_mask))
            self.pool.update(new_cache, tuple(active))
            steps += 1
            busy += len(active)
            toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            now = self.clock()
            for slot, st in list(active.items()):
                tok = int(toks[slot])
                st.tokens.append(tok)
                last_tokens[slot, 0] = tok
                if len(st.tokens) >= st.max_new_tokens or tok == self.eos_id:
                    st.t_done = now
                    done.append(st)
                    del active[slot]
                    active_mask[slot] = 0
                    last_tokens[slot, 0] = 0
                    self.pool.free(slot)

        wall = self.clock() - t0
        done.sort(key=lambda r: r.rid)
        return ServeStats(
            results=done, wall_s=wall, decode_steps=steps,
            generated_tokens=sum(len(r.tokens) for r in done),
            occupancy=busy / max(steps * S, 1))
