"""Request scheduler: admission, in-flight batching, eviction, preemption.

Two policies over the same KV pool (contiguous or paged) and jitted steps:

* ``continuous`` — between decode steps, every freed slot is immediately
  re-prefilled from the queue (continuous batching / in-flight batching).
* ``static`` — gang scheduling: admit a full batch, drain it until the
  *last* request finishes, then admit the next batch.  This is the old
  ``launch/serve.py`` behaviour, kept as the benchmark baseline.

The scheduler is layout-agnostic: it admits through ``pool.can_admit``
(contiguous pools count free *slots*; paged pools count free *pages*,
with headroom reserved for in-flight requests about to cross a page
boundary), grows paged slots before each decode step via
``pool.prepare_decode``, and — when the page pool is starved mid-decode —
**preempts** the youngest in-flight request: its slot and pages are
freed and it is re-queued at the front.  A preempted request is resumed
by re-prefilling its prompt plus everything it already generated, which
reproduces its KV state exactly, so preemption never changes the token
stream (greedy, and sampled too: the sampler keys on request id and
generation step, not on slot or time).

The preemption victim is the request with the **youngest admission step**;
two requests admitted in the same step (between the same pair of decode
iterations) tie-break on the **highest request id** — a property of the
request, not of queue insertion order, so the victim is deterministic
however the trace was assembled.

Sampling is per-request: ``Request.temperature`` / ``Request.top_k`` /
``Request.top_p`` ride through per-slot vectors into one jitted sampler
call per step (``serving/sampling.py``); the default (temperature 0) is
greedy argmax.  The loop is host-driven, one slot-wise decode over the
whole pool per iteration, one device->host sync per step for the sampled
tokens.  Everything is deterministic for a fixed trace.

With ``spec_k > 0`` (plus a ``verify_fn``) each decode tick becomes a
draft-then-verify tick (``_spec_step``): a drafter proposes k tokens per
slot from the slot's own history, one verify step scores all k+1
positions against pool KV, and the slot accepts the longest draft prefix
matching the sequential sampler's own ``(rid, step)`` draws — so
speculative streams are bit-identical to ``spec_k == 0`` and a tick can
emit up to k+1 tokens per slot for one jitted call.

``run()`` drains a whole trace, but every phase is also exposed as a
step-wise API (``reset`` / ``try_admit`` / ``admit_from_queue`` / ``step``
/ ``stats``) so a ``ReplicaRouter`` can drive N schedulers in lockstep,
routing between them at admission time and catching solo page starvation
(``step(evict_on_starvation=True)`` hands the evicted entry back for
re-routing instead of raising).

**Prefill** has two modes (``chunk_step_fn`` + ``prefill_chunk``):

* ``prefill_chunk == 0`` — *blocking*: the whole (bucketed) prompt runs
  as one chunk inline at admission, exactly the old cadence — but the
  chunk step scatters its KV straight into pool slots/pages, so even
  this path no longer materializes a contiguous ``(1, s)`` cache for
  ``insert`` to re-scatter.
* ``prefill_chunk > 0`` — *chunked*: admission reserves the slot and the
  prompt's pages, queues a ``PrefillJob``, and ``step`` interleaves at
  most ``prefill_chunk`` prompt tokens between decode ticks — in-flight
  requests keep streaming while a prompt is ingested.

With no ``chunk_step_fn`` the legacy path (``prefill_fn`` + pool
``insert``) is used unchanged.

TTFT is additionally tracked on a **virtual step clock** — a
deterministic wall-time proxy where every jitted model invocation
(decode tick, or one prefill chunk) costs one unit, and a blocking
prefill costs its chunk-equivalent ``ceil(n / chunk)`` *serially* (it
runs on the driver thread and stalls the loop — fleet-wide under the
lockstep router, which is exactly the stall chunking removes).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.serving.pool import PoolExhausted
from repro.serving.prefill import PrefillManager
from repro.serving.sampling import K_CAP, effective_top_k
from repro.serving.spec import NGramDrafter


def percentile_steps(values, q: float) -> float:
    """np.percentile over virtual-step samples; NaN for an idle fleet
    (no completed requests) — JSON writers map it to null."""
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values, np.float64), q))


class VirtualClock:
    """Deterministic step-count clock for the TTFT proxy: one unit per
    jitted model invocation.  ``advance_serial`` marks driver-thread work
    that stalls everyone (a blocking prefill at dispatch); on the plain
    clock it is the same as ``advance`` — the router's per-replica round
    view distinguishes the two."""

    def __init__(self):
        self._t = 0

    @property
    def t(self) -> int:
        return self._t

    def advance(self, n: int = 1) -> None:
        self._t += int(n)

    advance_serial = advance


class RoundClock:
    """A replica's view of a shared fleet clock during one lockstep round.

    Parallel-phase work (``advance``: decode ticks, prefill chunks)
    accumulates a local offset — at the end of the round the router
    advances the shared clock by the *max* offset across replicas, since
    real replicas work concurrently.  Serial-phase work
    (``advance_serial``: blocking prefill during dispatch) goes straight
    to the shared clock — the driver thread runs those one after another,
    stalling every replica's round."""

    def __init__(self, shared: VirtualClock):
        self.shared = shared
        self.offset = 0

    @property
    def t(self) -> int:
        return self.shared.t + self.offset

    def advance(self, n: int = 1) -> None:
        self.offset += int(n)

    def advance_serial(self, n: int = 1) -> None:
        self.shared.advance(n)

    def take(self) -> int:
        off, self.offset = self.offset, 0
        return off


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (s,) int32 token ids
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 = greedy
    top_k: int = 0                # 0 = no top-k filter
    top_p: float = 1.0            # 1 = no nucleus filter
    arrival_vstep: int = 0        # open-loop arrival on the virtual step
    #                               clock; 0 = available at t=0 (closed loop)


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    max_new_tokens: int
    slot: int = -1
    tokens: list = dataclasses.field(default_factory=list)
    preemptions: int = 0
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    # virtual-step stamps (deterministic TTFT proxy; -1 = never reached)
    v_submit: int = 0
    v_first: int = -1
    v_done: int = -1

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit

    @property
    def ttft_steps(self) -> int:
        """Time-to-first-token on the virtual step clock — deterministic
        for a fixed trace/fleet/policy, unlike wall-clock ttft_s."""
        return self.v_first - self.v_submit

    @property
    def e2e_steps(self) -> int:
        """Arrival-to-last-token latency on the virtual step clock."""
        return self.v_done - self.v_submit

    def meets_slo(self, slo_ttft_steps: int = 0,
                  slo_e2e_steps: int = 0) -> bool:
        """Did this request meet its deadlines?  Judged ONLY on virtual
        steps (never wall-clock); an unset deadline (<= 0) always passes."""
        if self.v_first < 0 or self.v_done < 0:
            return False
        if slo_ttft_steps > 0 and self.ttft_steps > slo_ttft_steps:
            return False
        if slo_e2e_steps > 0 and self.e2e_steps > slo_e2e_steps:
            return False
        return True


@dataclasses.dataclass
class ServeStats:
    results: list
    wall_s: float
    decode_steps: int
    generated_tokens: int
    occupancy: float              # mean active-slot fraction per decode step
    peak_active: int = 0          # max concurrent in-flight requests
    peak_resident_tokens: int = 0  # max KV tokens held across the pool
    preemptions: int = 0          # page-pressure evictions (paged pools)
    # chunked-prefill observability (zeros on the legacy prefill path)
    prefill_chunks: int = 0       # chunk-step invocations
    prefill_tokens: int = 0       # prompt tokens ingested through chunks
    prefill_compiles: int = 0     # distinct chunk buckets jitted
    prefill_queue_peak: int = 0   # max requests mid-prefill at once
    overlap_steps: int = 0        # steps that both chunked AND decoded
    mean_ttft_steps: float = 0.0  # mean virtual-clock time to first token
    # latency distribution + goodput, all on the virtual step clock (the
    # deterministic proxy) — never derived from wall_s.  Percentiles are
    # NaN when nothing completed (idle fleet); goodput counts the tokens
    # of requests that met the TTFT/e2e deadlines (deadline 0 = unset,
    # every completed request passes it)
    p50_ttft_steps: float = float("nan")
    p99_ttft_steps: float = float("nan")
    p50_e2e_steps: float = float("nan")
    p99_e2e_steps: float = float("nan")
    goodput_tokens: int = 0
    slo_ttft_steps: int = 0       # the deadlines goodput was judged by
    slo_e2e_steps: int = 0
    # shared-prefix KV cache observability (zeros with the cache off)
    prefix_hits: int = 0          # admissions that reused a cached run
    prefix_misses: int = 0        # admissions with no cached prefix
    prefill_tokens_saved: int = 0  # prompt tokens skipped via cache hits
    prefix_evictions: int = 0     # cache cells reclaimed under pressure
    # speculative decoding observability (zeros with spec_k == 0).
    # spec_verify_steps counts per-SLOT scoring events (one per active
    # slot per verify invocation), so accepted_per_verify is the clean
    # per-request speedup factor, not inflated by batch width
    spec_verify_steps: int = 0    # slot-verify scoring events
    spec_drafted_tokens: int = 0  # draft tokens proposed (k per slot-step)
    spec_accepted_tokens: int = 0  # draft tokens accepted (burst - 1 each)
    total_vsteps: int = 0         # virtual-clock span of the whole drain
    # effective per-request top-k after the vocab/K_CAP cap: {rid: k} for
    # every admitted request that asked for a top-k filter — surfaces what
    # the sampler actually applied instead of silently clamping
    effective_top_k: dict = dataclasses.field(default_factory=dict)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)

    def to_metrics(self) -> dict:
        """Flat ``{key: number}`` snapshot of the single-engine drain —
        the scrape a dashboard would ingest.  Keys and kinds come from
        ``telemetry.SERVE_SCHEMA`` (the registry raises on a missing or
        undeclared key, so this view cannot silently drift from the
        schema); ``RouterStats.to_metrics`` is the same pattern over
        ``ROUTER_SCHEMA`` with a shared key suffix vocabulary."""
        from repro.serving.telemetry import SERVE_SCHEMA, MetricsRegistry
        reg = MetricsRegistry(SERVE_SCHEMA)
        reg.set("serve_requests_completed", len(self.results))
        reg.set("serve_generated_tokens", self.generated_tokens)
        reg.set("serve_goodput_tokens", self.goodput_tokens)
        reg.set("serve_slo_ttft_steps", self.slo_ttft_steps)
        reg.set("serve_slo_e2e_steps", self.slo_e2e_steps)
        reg.set("serve_ttft_p50_steps", self.p50_ttft_steps)
        reg.set("serve_ttft_p99_steps", self.p99_ttft_steps)
        reg.set("serve_e2e_p50_steps", self.p50_e2e_steps)
        reg.set("serve_e2e_p99_steps", self.p99_e2e_steps)
        reg.set("serve_mean_ttft_steps", self.mean_ttft_steps)
        reg.set("serve_total_vsteps", self.total_vsteps)
        reg.set("serve_wall_s", self.wall_s)
        reg.set("serve_tokens_per_s", self.tokens_per_s)
        reg.set("serve_decode_steps", self.decode_steps)
        reg.set("serve_occupancy", self.occupancy)
        reg.set("serve_peak_active", self.peak_active)
        reg.set("serve_peak_resident_kv", self.peak_resident_tokens)
        reg.set("serve_preemptions", self.preemptions)
        reg.set("serve_prefill_chunks", self.prefill_chunks)
        reg.set("serve_prefill_tokens", self.prefill_tokens)
        reg.set("serve_prefix_hits", self.prefix_hits)
        reg.set("serve_prefix_misses", self.prefix_misses)
        reg.set("serve_prefill_tokens_saved", self.prefill_tokens_saved)
        reg.set("serve_prefix_evictions", self.prefix_evictions)
        reg.set("serve_spec_verify_steps", self.spec_verify_steps)
        reg.set("serve_spec_drafted_tokens", self.spec_drafted_tokens)
        reg.set("serve_spec_accepted_tokens", self.spec_accepted_tokens)
        return reg.snapshot()

    @property
    def accepted_per_verify(self) -> float:
        """Tokens emitted per verify step (accepted drafts + the bonus
        token each step always emits) — > 1 means speculation is paying."""
        if not self.spec_verify_steps:
            return 0.0
        return (self.spec_verify_steps + self.spec_accepted_tokens) \
            / self.spec_verify_steps

    def summary(self) -> str:
        lat = [r.latency_s for r in self.results]
        pre = f", {self.preemptions} preemptions" if self.preemptions else ""
        if self.prefix_hits:
            pre += (f", {self.prefix_hits} prefix hits "
                    f"({self.prefill_tokens_saved}t prefill saved)")
        if self.spec_verify_steps:
            pre += (f", spec {self.accepted_per_verify:.2f} tok/verify "
                    f"({self.spec_accepted_tokens}/{self.spec_drafted_tokens}"
                    f" drafts accepted)")
        return (f"{len(self.results)} requests, {self.generated_tokens} tokens "
                f"in {self.wall_s:.3f}s -> {self.tokens_per_s:.1f} tok/s | "
                f"{self.decode_steps} decode steps, "
                f"occupancy {self.occupancy:.0%}, "
                f"peak {self.peak_active} in flight{pre} | latency "
                f"mean {np.mean(lat):.3f}s p max {np.max(lat):.3f}s")


@dataclasses.dataclass
class _Entry:
    """A queued unit of work: a fresh request, or a preempted one carrying
    the result it must resume (tokens generated so far).  ``rerouted``
    marks a solo-starvation eviction a router handed back: its pool
    provably cannot finish the request, so re-dispatch must place it by
    the pessimistic residency bound even under an optimistic eos."""
    req: Request
    st: RequestResult | None = None
    rerouted: bool = False
    probe_hit: object = None      # prefix-cache probe from the can_admit
    #                               immediately preceding _admit — attach
    #                               reuses it instead of re-walking keys

    @property
    def pending_len(self) -> int:
        """Prompt length at (re-)admission: original prompt plus anything
        already generated before a preemption."""
        n = len(self.req.prompt)
        return n + len(self.st.tokens) if self.st is not None else n

    def pending_tokens(self) -> np.ndarray:
        """The token prefix a (re-)admission must ingest — the prompt,
        plus everything generated before a preemption (a resume
        re-prefills both; the prefix cache keys on exactly these)."""
        prompt = np.asarray(self.req.prompt, np.int32)
        if self.st is not None and self.st.tokens:
            return np.concatenate(
                [prompt, np.asarray(self.st.tokens, np.int32)])
        return prompt

    def remaining_new(self) -> int:
        """Generation budget left (fresh entries: the full request ask)."""
        if self.st is None:
            return self.req.max_new_tokens
        return self.st.max_new_tokens - len(self.st.tokens)


@dataclasses.dataclass
class _Active:
    req: Request
    st: RequestResult
    admit_step: int               # decode step at admission; youngest is
    #                               the preemption victim, ties by req.rid


class Scheduler:
    """Drains a request queue through repeated slot-wise decode calls."""

    def __init__(self, pool, prefill_fn, decode_fn,
                 eos_id: int | None = None, policy: str = "continuous",
                 # advisory wall_s only; gated metrics are vstep-clocked
                 sampler=None, clock=time.perf_counter,  # easeylint: allow[wall-clock]
                 chunk_step_fn=None, prefill_chunk: int = 0,
                 prefill_chunk_unit: int = 16, vclock=None,
                 verify_fn=None, spec_k: int = 0, drafter=None,
                 vocab_size: int | None = None,
                 slo_ttft_steps: int = 0, slo_e2e_steps: int = 0,
                 tracer=None, replica_id: int = 0):
        if policy not in ("continuous", "static"):
            raise ValueError(policy)
        if prefill_chunk < 0 or prefill_chunk_unit < 1:
            raise ValueError((prefill_chunk, prefill_chunk_unit))
        if spec_k < 0:
            raise ValueError(f"spec_k {spec_k} < 0")
        if spec_k and verify_fn is None:
            raise ValueError("spec_k > 0 needs a verify_fn "
                             "(training/steps.build_verify_step_slots*)")
        self.pool = pool
        self.prefill_fn = prefill_fn        # (tokens (1,s)) -> logits, cache
        self.decode_fn = decode_fn          # (cache, tokens, active, *extras)
        self.chunk_step_fn = chunk_step_fn  # (cache, toks, slot, off, n, *x)
        self.prefill_chunk = prefill_chunk  # 0 = blocking full-prompt
        # chunk_unit prices a blocking prefill on the virtual clock (its
        # ceil(n/unit) chunk-equivalents) so blocking-vs-chunked TTFT is
        # compared in the same work units
        self.chunk_unit = prefill_chunk_unit
        self.eos_id = eos_id
        self.policy = policy
        self.sampler = sampler              # None -> greedy argmax
        self.clock = clock
        self.vclock = vclock or VirtualClock()
        # draft-then-verify speculative decoding: k drafts per slot, one
        # verify step scoring all k+1 positions (verify_fn), acceptance on
        # the host against the same (rid, step) sampler draws
        self.verify_fn = verify_fn          # (cache, toks, active, *extras)
        self.spec_k = spec_k
        self.drafter = drafter if drafter is not None else \
            (NGramDrafter() if spec_k else None)
        self.vocab_size = vocab_size        # for effective-top-k reporting
        # deadlines (virtual steps) goodput is judged by; 0 = unset
        self.slo_ttft_steps = int(slo_ttft_steps)
        self.slo_e2e_steps = int(slo_e2e_steps)
        # telemetry hook: every call site is guarded by `is not None`, so
        # tracing off costs one attribute load per event site and traces
        # never reach jitted code — spans/events are pure host bookkeeping
        # on the virtual clock and cannot move token streams
        self.tracer = tracer
        self.replica_id = int(replica_id)
        self.all_greedy = False
        self.reset()

    # -- step-wise state ----------------------------------------------------
    def reset(self, t0: float | None = None) -> None:
        """Fresh drain state (queue, active set, counters, host mirrors)."""
        S = self.pool.num_slots
        self.queue: deque = deque()
        self.active: dict[int, _Active] = {}
        self.done: list[RequestResult] = []
        self._last_tokens = np.zeros((S, 1), np.int32)
        self._active_mask = np.zeros((S,), np.int32)
        self._steps = 0
        self._busy = 0
        self._peak = 0
        self._peak_resident = 0
        self._preemptions = 0
        self._overlap = 0
        self._spec_verifies = 0
        self._spec_drafted = 0
        self._spec_accepted = 0
        self._eff_topk: dict[int, int] = {}
        self._t0 = self.clock() if t0 is None else t0
        self._v0 = self.vclock.t           # virtual submission time
        self._mgr = None if self.chunk_step_fn is None else \
            PrefillManager(self.pool, self.chunk_step_fn, self.prefill_chunk,
                           tracer=self.tracer, vclock=self.vclock,
                           replica_id=self.replica_id)
        pc = getattr(self.pool, "prefix_cache", None)
        if pc is not None and hasattr(pc, "bind_tracer"):
            pc.bind_tracer(self.tracer, self.vclock, self.replica_id)

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active or self.prefill_backlog)

    @property
    def prefill_backlog(self) -> bool:
        """Whether requests are mid-prefill (chunks still queued)."""
        return self._mgr is not None and self._mgr.has_jobs

    @property
    def in_flight(self) -> int:
        """Requests holding pool resources: actively decoding ones plus
        those mid-prefill (slot and pages reserved, chunks queued)."""
        jobs = len(self._mgr.jobs) if self._mgr is not None else 0
        return len(self.active) + jobs

    @property
    def prefill_backlog_tokens(self) -> int:
        """Prompt tokens queued for ingestion but not yet chunked through
        — the backlog the router's TTFT napkin charges new arrivals."""
        return self._mgr.pending_tokens if self._mgr is not None else 0

    @property
    def free_tokens(self) -> int:
        """Router load signal: the pool's admittable tokens minus the
        prefill backlog still owed to it.  A replica mid-ingest has the
        HBM reserved but the compute pending — counting its queued
        chunks as free capacity would route new prompts straight into
        the stall chunking exists to hide."""
        backlog = self._mgr.pending_tokens if self._mgr is not None else 0
        return max(self.pool.free_tokens - backlog, 0)

    def validate(self, requests) -> None:
        """Reject up front what this pool could never serve: a mid-run
        rejection would throw away the stats of every request already
        served in a drain.  Without an eos, generation is deterministic
        full-length, so a paged request whose worst-case residency
        outstrips the whole page pool is *guaranteed* to starve.  (With
        an eos the request might stop early; it is admitted optimistically
        and the mid-decode starvation path still raises.)"""
        for req in requests:
            if len(req.prompt) > self.pool.max_len:
                raise ValueError(
                    f"request {req.rid}: prompt ({len(req.prompt)}) does "
                    f"not fit pool max_len {self.pool.max_len}")
            if not 0 <= req.top_k <= K_CAP:
                raise ValueError(
                    f"request {req.rid}: top_k {req.top_k} not in "
                    f"[0, {K_CAP}] — the sampler would silently clamp it")
            top_p = getattr(req, "top_p", 1.0)
            if not 0.0 < top_p <= 1.0:
                raise ValueError(
                    f"request {req.rid}: top_p {top_p} not in (0, 1]")
            if getattr(req, "arrival_vstep", 0) < 0:
                raise ValueError(
                    f"request {req.rid}: arrival_vstep "
                    f"{req.arrival_vstep} < 0")
            worst = self.worst_resident(_Entry(req))
            if not self.pool.can_ever_serve(worst):
                raise PoolExhausted(
                    f"request {req.rid} needs {worst} resident KV tokens "
                    f"but the pool can never hold that many")

    def worst_resident(self, entry: _Entry) -> int:
        """Max KV tokens `entry` will hold if admitted here (eos: only the
        pending prefill is certain; otherwise full-length generation is)."""
        if self.eos_id is not None:
            return entry.pending_len
        return min(entry.pending_len + entry.remaining_new() - 1,
                   self.pool.max_len)

    # -- sampling ----------------------------------------------------------
    def _sample_rows(self, logits_last, entries):
        """One sampler call over rows; entries[i] styles row i (None rows
        sample greedily with a dead key)."""
        if self.sampler is None or self.all_greedy:
            return np.asarray(jnp.argmax(logits_last, axis=-1))
        n = logits_last.shape[0]
        temps = np.zeros((n,), np.float32)
        topks = np.zeros((n,), np.int32)
        topps = np.ones((n,), np.float32)
        rids = np.zeros((n,), np.int32)
        steps = np.zeros((n,), np.int32)
        for i, en in enumerate(entries):
            if en is None:
                continue
            temps[i] = en.req.temperature
            topks[i] = en.req.top_k
            topps[i] = getattr(en.req, "top_p", 1.0)
            rids[i] = en.req.rid
            steps[i] = len(en.st.tokens)
        return np.asarray(self.sampler(
            logits_last, jnp.asarray(temps), jnp.asarray(topks),
            jnp.asarray(topps), jnp.asarray(rids), jnp.asarray(steps)))

    def _sample_rows_multi(self, logits, width):
        """Sample ALL `width` speculated positions of every slot in one
        sampler call: row (slot, j) draws with the slot's request styling
        at generation step ``len(st.tokens) + j`` — the very key the
        sequential sampler would use if the j-th draft is accepted, which
        is what makes accepted bursts bit-identical to one-at-a-time
        decoding.  logits: (S, width, vocab) -> (S, width) int32."""
        if self.sampler is None or self.all_greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        S = logits.shape[0]
        temps = np.zeros((S, width), np.float32)
        topks = np.zeros((S, width), np.int32)
        topps = np.ones((S, width), np.float32)
        rids = np.zeros((S, width), np.int32)
        steps = np.zeros((S, width), np.int32)
        for slot, en in self.active.items():
            temps[slot, :] = en.req.temperature
            topks[slot, :] = en.req.top_k
            topps[slot, :] = getattr(en.req, "top_p", 1.0)
            rids[slot, :] = en.req.rid
            steps[slot, :] = len(en.st.tokens) + np.arange(width)
        flat = self.sampler(
            logits.reshape(S * width, -1),
            jnp.asarray(temps.reshape(-1)), jnp.asarray(topks.reshape(-1)),
            jnp.asarray(topps.reshape(-1)), jnp.asarray(rids.reshape(-1)),
            jnp.asarray(steps.reshape(-1)))
        return np.asarray(flat).reshape(S, width)

    # -- admission ---------------------------------------------------------
    def _probe_prefix(self, entry: _Entry):
        """Read-only shared-prefix cache probe for `entry` (None when no
        cache is attached or prefill bypasses the chunk pipeline)."""
        cache = getattr(self.pool, "prefix_cache", None)
        if cache is None or self._mgr is None:
            return None
        return cache.probe(entry.pending_tokens())

    def can_admit(self, entry: _Entry) -> bool:
        """Admission asks the pool for the entry's *cold* footprint: with
        a prefix-cache hit only the un-cached suffix needs fresh pages.
        The probe rides on the entry so the ``_admit`` that immediately
        follows a True answer attaches it without re-walking the keys
        (a router's losing replicas overwrite it; the winner re-probes
        in ``try_admit`` right before admitting, so it is never stale)."""
        entry.probe_hit = self._probe_prefix(entry)
        return self.pool.can_admit(entry.pending_len, tuple(self.active),
                                   hit=entry.probe_hit)

    def try_admit(self, entry: _Entry) -> bool:
        """Router-facing single-entry admission; False when full."""
        if not self.can_admit(entry):
            return False
        self._admit(entry)
        return True

    def admit_from_queue(self) -> None:
        """Admit from the local queue head while the pool has room."""
        while self.queue and self.can_admit(self.queue[0]):
            self._admit(self.queue.popleft())

    def _admit(self, entry: _Entry) -> None:
        now = self.clock()
        req = entry.req
        if self.tracer is not None:
            # close whichever wait span this request was in — "queued"
            # (fresh, begun at release) or "resume" (begun at preemption;
            # matching is on (rid, phase) so a reroute's resume closes
            # even when re-admission lands on another replica)
            self.tracer.end_any(("resume", "queued"), req.rid, self.vclock.t,
                                pending_tokens=int(entry.pending_len))
        if req.top_k:
            # surface what the sampler will actually apply (vocab and
            # K_CAP caps) — validated <= K_CAP, but a small-vocab model
            # can still cap below the ask
            self._eff_topk[req.rid] = effective_top_k(
                req.top_k, self.vocab_size or req.top_k)
        if entry.st is None:
            s = len(req.prompt)
            budget = self.pool.max_len - s + 1   # writes stop at max_len - 1
            st = RequestResult(
                rid=req.rid, prompt_len=s,
                max_new_tokens=min(req.max_new_tokens, budget),
                t_submit=getattr(req, "_t_submit", now),
                # open loop: latency is measured from the request's
                # *arrival* on the virtual clock, so queue wait counts
                v_submit=self._v0 + getattr(req, "arrival_vstep", 0))
            st.t_admit = now
            prompt = entry.pending_tokens()
        else:                                    # resume after preemption
            st = entry.st
            prompt = entry.pending_tokens()
        if self._mgr is not None:
            # pool-direct prefill: the slot and the prompt's pages are
            # reserved NOW (the same decision point blocking admission
            # reserved at, so admission order and token streams match);
            # a prefix-cache hit inside submit leaves only the cold
            # suffix for the chunk pipeline
            job = self._mgr.submit(entry, st, prompt)
            job.admit_step = self._steps
            if self.prefill_chunk:
                return                           # chunks interleave in step()
            # blocking: the un-cached remainder as one chunk, inline —
            # priced on the virtual clock at its chunk-equivalent cost,
            # *serially* (it runs on the driver thread and stalls the
            # lockstep loop)
            self.vclock.advance_serial(-(-job.remaining // self.chunk_unit))
            self._finish_prefill(job, self._mgr.drain(job))
            return
        # legacy path (no chunk step): prefill to a contiguous (1, s)
        # cache, then scatter it into the pool.  Prefill lengths are
        # bucketed to powers of two so resumes (whose lengths are
        # arbitrary) reuse one compiled prefill per bucket: the prompt is
        # right-padded, logits are read at the true last position, and
        # the cache is sliced back before insertion (causal attention
        # keeps positions < n independent of the padding)
        n = len(prompt)
        pad = 1 << (n - 1).bit_length()
        if pad == n:
            logits, cache = self.prefill_fn(jnp.asarray(prompt)[None, :])
        else:
            padded = np.zeros((pad,), np.int32)
            padded[:n] = prompt
            logits, cache = self.prefill_fn(jnp.asarray(padded)[None, :],
                                            n - 1)
            cache = {"k": cache["k"][:, :, :n], "v": cache["v"][:, :, :n],
                     "index": jnp.asarray(n, jnp.int32)}
        self.vclock.advance_serial(-(-n // self.chunk_unit))
        tok = int(self._sample_rows(logits[:, -1], [_Active(req, st, 0)])[0])
        if entry.st is None:
            st.t_first = self.clock()
            st.v_first = self.vclock.t
        st.tokens.append(tok)
        if len(st.tokens) >= st.max_new_tokens or tok == self.eos_id:
            st.t_done = self.clock()
            st.v_done = self.vclock.t
            self.done.append(st)
            return
        slot = self.pool.alloc()
        st.slot = slot
        self.pool.insert(slot, cache)
        self.active[slot] = _Active(req, st, self._steps)
        self._last_tokens[slot, 0] = tok
        self._active_mask[slot] = 1
        if self.tracer is not None:
            self.tracer.begin("decode", req.rid, self.vclock.t,
                              replica=self.replica_id, slot=slot,
                              resident_tokens=int(self.pool.lengths[slot]))

    def _finish_prefill(self, job, logits) -> None:
        """A job's final chunk landed: sample the first token and either
        finish the request or activate its (already-populated) slot."""
        st, req = job.st, job.entry.req
        tok = int(self._sample_rows(logits[:, -1], [_Active(req, st, 0)])[0])
        if job.entry.st is None:
            st.t_first = self.clock()
            st.v_first = self.vclock.t
        st.tokens.append(tok)
        if len(st.tokens) >= st.max_new_tokens or tok == self.eos_id:
            st.t_done = self.clock()
            st.v_done = self.vclock.t
            self.done.append(st)
            self.pool.free(job.slot)
            return
        st.slot = job.slot
        self.active[job.slot] = _Active(req, st, job.admit_step)
        self._last_tokens[job.slot, 0] = tok
        self._active_mask[job.slot] = 1
        if self.tracer is not None:
            self.tracer.begin("decode", req.rid, self.vclock.t,
                              replica=self.replica_id, slot=job.slot,
                              resident_tokens=int(
                                  self.pool.lengths[job.slot]))

    # -- preemption --------------------------------------------------------
    def _evict(self, slot: int) -> _Entry:
        """Free `slot` and return its request as a resumable entry."""
        en = self.active.pop(slot)
        en.st.slot = -1
        en.st.preemptions += 1
        if self.tracer is not None:
            v = self.vclock.t
            self.tracer.end("decode", en.st.rid, v, preempted=True,
                            tokens=len(en.st.tokens))
            self.tracer.instant("preempt", v, replica=self.replica_id,
                                rid=en.st.rid, slot=slot,
                                tokens=len(en.st.tokens))
            self.tracer.begin("resume", en.st.rid, v,
                              replica=self.replica_id)
        self._active_mask[slot] = 0
        self._last_tokens[slot, 0] = 0
        self.pool.free(slot)                 # returns its pages
        return _Entry(en.req, en.st)

    def _preempt(self, slot: int) -> None:
        self.queue.appendleft(self._evict(slot))
        self._preemptions += 1

    # -- one decode iteration ----------------------------------------------
    def _requeue_job(self, job) -> None:
        """Re-queue an evicted mid-prefill job at the queue front.  A
        fresh job (no tokens yet) restarts from scratch; a resume job
        keeps its result so the already-emitted tokens survive."""
        st = job.st if job.st.tokens else None
        if st is not None:
            st.slot = -1
            st.preemptions += 1
        if self.tracer is not None:
            rid = job.entry.req.rid
            v = self.vclock.t
            self.tracer.instant("preempt", v, replica=self.replica_id,
                                rid=rid, mid_prefill=True,
                                ingested=int(job.done))
            self.tracer.begin("resume", rid, v, replica=self.replica_id)
        self.queue.appendleft(_Entry(job.entry.req, st))
        self._preemptions += 1

    def step(self, evict_on_starvation: bool = False) -> list:
        """One scheduler tick: ingest at most ``prefill_chunk`` queued
        prompt tokens, then one slot-wise decode over the active set.

        Paged pools grow slots crossing a page boundary first; starvation
        preempts mid-prefill jobs first (youngest — they have ingested
        the least), then the youngest in-flight request (ties by request
        id) until the step fits.  When the *sole* active request starves
        the pool can never make progress alone: raise ``PoolExhausted``,
        or — under a router (``evict_on_starvation``) — hand the evicted
        entry back for re-routing to a replica that can hold it.  Returns
        the evicted entries (empty in the single-engine path).
        """
        chunked = 0
        if self._mgr is not None and self._mgr.has_jobs:
            self._peak = max(self._peak, self.in_flight)
            finished, chunked = self._mgr.tick(self.vclock)
            for job, logits in finished:
                self._finish_prefill(job, logits)
        if not self.active:
            return []
        evicted = []
        while True:
            starved = self.pool.prepare_decode(sorted(self.active))
            if not starved:
                break
            if self._mgr is not None and self._mgr.has_jobs:
                self._requeue_job(self._mgr.evict_newest())
                continue
            if len(self.active) == 1:
                (slot,) = self.active
                if not evict_on_starvation:
                    raise PoolExhausted(
                        f"page starvation mid-decode: request "
                        f"{self.active[slot].req.rid} holds every page and "
                        f"still needs another — the page pool is too small "
                        f"for it")
                evicted.append(self._evict(slot))
                self._preemptions += 1
                return evicted               # nothing left to decode
            victim = max(self.active,
                         key=lambda sl: (self.active[sl].admit_step,
                                         self.active[sl].req.rid))
            self._preempt(victim)
        self._peak = max(self._peak, self.in_flight)
        self._peak_resident = max(self._peak_resident,
                                  int(self.pool.lengths.sum()))
        if self.spec_k and self.verify_fn is not None:
            self._spec_step(chunked)
            return evicted
        logits, new_cache = self.decode_fn(
            self.pool.cache, jnp.asarray(self._last_tokens),
            jnp.asarray(self._active_mask), *self.pool.decode_extras())
        self.pool.update(new_cache, tuple(self.active))
        self.vclock.advance(1)
        self._steps += 1
        self._busy += len(self.active)
        if chunked:
            self._overlap += 1       # ingested a chunk AND decoded a token
        S = self.pool.num_slots
        rows = [self.active.get(i) for i in range(S)]
        toks = self._sample_rows(logits[:, -1], rows)
        now = self.clock()
        vnow = self.vclock.t
        for slot, en in list(self.active.items()):
            st = en.st
            tok = int(toks[slot])
            st.tokens.append(tok)
            self._last_tokens[slot, 0] = tok
            if len(st.tokens) >= st.max_new_tokens or tok == self.eos_id:
                st.t_done = now
                st.v_done = vnow
                self.done.append(st)
                del self.active[slot]
                self._active_mask[slot] = 0
                self._last_tokens[slot, 0] = 0
                self.pool.free(slot)
                if self.tracer is not None:
                    self.tracer.end("decode", st.rid, vnow,
                                    tokens=len(st.tokens))
        return evicted

    # -- speculative decode -------------------------------------------------
    def _spec_step(self, chunked: int) -> None:
        """One draft-then-verify tick over the active set.

        Per slot: the drafter proposes k tokens from the slot's own
        history; the verify step scores all k+1 positions (pending token
        + drafts) against pool KV in one jitted call; every position is
        sampled with the sequential sampler's own ``(rid, step)`` key;
        the slot then accepts the longest prefix of draws that matches
        its drafts — exactly the tokens one-at-a-time decode would have
        produced, so speculative streams are bit-identical to spec_k=0.

        Page charging: ``prepare_decode`` already granted the mandatory
        next-token position (same starvation/preemption semantics as
        non-speculative decode); ``grow_for_burst`` then backs as much of
        the burst as genuinely free pages allow, acceptance is capped at
        the backed count, and any verify write past it lands in junk
        page 0 via the attention ok-guard — never in a live (possibly
        prefix-shared) page.  KV written for rejected drafts is
        overwritten by the next step before any causal mask admits it.
        The device index is not advanced by the verify step (acceptance
        is a host decision): ``pool.sync_index`` re-uploads the length
        mirror once per tick.
        """
        S = self.pool.num_slots
        k = self.spec_k
        tok_mat = np.zeros((S, k + 1), np.int32)
        caps = np.zeros((S,), np.int64)
        drafts: dict[int, list] = {}
        for slot, en in self.active.items():
            hist = np.asarray(en.req.prompt).tolist() + en.st.tokens
            d = self.drafter.draft(hist, k)
            drafts[slot] = d
            tok_mat[slot, 0] = self._last_tokens[slot, 0]
            tok_mat[slot, 1:] = d
            caps[slot] = self.pool.grow_for_burst(slot, k + 1)
        logits, new_cache = self.verify_fn(
            self.pool.cache, jnp.asarray(tok_mat),
            jnp.asarray(self._active_mask), *self.pool.decode_extras())
        self.pool.adopt(new_cache)
        self.vclock.advance(1)
        self._steps += 1
        self._busy += len(self.active)
        if chunked:
            self._overlap += 1
        toks = self._sample_rows_multi(logits, k + 1)
        now = self.clock()
        vnow = self.vclock.t
        for slot, en in list(self.active.items()):
            st = en.st
            d = drafts[slot]
            cap = int(caps[slot])        # >= 1: prepare_decode granted it
            emitted = 0
            j = 0
            finished = False
            while True:
                tok = int(toks[slot, j])
                st.tokens.append(tok)
                emitted += 1
                if len(st.tokens) >= st.max_new_tokens or \
                        tok == self.eos_id:
                    finished = True
                    break
                # j == k: no draft beyond position k to validate;
                # emitted == cap: sample j+1's query position is not
                # backed by a page; tok != d[j]: the draft fed at
                # position j+1 is not what sequential decode would see
                if j >= k or emitted >= cap or tok != d[j]:
                    break
                j += 1
            self._spec_verifies += 1
            self._spec_drafted += k
            self._spec_accepted += emitted - 1
            if self.tracer is not None:
                self.tracer.span("spec_verify", st.rid, vnow - 1, vnow,
                                 replica=self.replica_id, slot=slot,
                                 k=k, emitted=emitted,
                                 accepted=emitted - 1, backed=cap)
            self.pool.set_length(slot,
                                 int(self.pool.lengths[slot]) + emitted)
            if finished:
                st.t_done = now
                st.v_done = vnow
                self.done.append(st)
                del self.active[slot]
                self._active_mask[slot] = 0
                self._last_tokens[slot, 0] = 0
                self.pool.free(slot)
                if self.tracer is not None:
                    self.tracer.end("decode", st.rid, vnow,
                                    tokens=len(st.tokens))
            else:
                self._last_tokens[slot, 0] = int(toks[slot, emitted - 1])
        self.pool.sync_index()

    # -- results -----------------------------------------------------------
    def stats(self) -> ServeStats:
        wall = self.clock() - self._t0
        done = sorted(self.done, key=lambda r: r.rid)
        ttfts = [r.ttft_steps for r in done if r.v_first >= 0]
        e2es = [r.e2e_steps for r in done if r.v_done >= 0]
        goodput = sum(
            len(r.tokens) for r in done
            if r.meets_slo(self.slo_ttft_steps, self.slo_e2e_steps))
        mgr = self._mgr
        pc = getattr(self.pool, "prefix_cache", None)
        return ServeStats(
            results=done, wall_s=wall, decode_steps=self._steps,
            generated_tokens=sum(len(r.tokens) for r in done),
            occupancy=self._busy / max(self._steps * self.pool.num_slots, 1),
            peak_active=self._peak, peak_resident_tokens=self._peak_resident,
            preemptions=self._preemptions,
            prefill_chunks=mgr.chunks_run if mgr else 0,
            prefill_tokens=mgr.tokens_ingested if mgr else 0,
            prefill_compiles=len(mgr.compiled_buckets) if mgr else 0,
            prefill_queue_peak=mgr.queue_peak if mgr else 0,
            overlap_steps=self._overlap,
            mean_ttft_steps=float(np.mean(ttfts)) if ttfts else 0.0,
            p50_ttft_steps=percentile_steps(ttfts, 50),
            p99_ttft_steps=percentile_steps(ttfts, 99),
            p50_e2e_steps=percentile_steps(e2es, 50),
            p99_e2e_steps=percentile_steps(e2es, 99),
            goodput_tokens=goodput,
            slo_ttft_steps=self.slo_ttft_steps,
            slo_e2e_steps=self.slo_e2e_steps,
            prefix_hits=pc.hits if pc else 0,
            prefix_misses=pc.misses if pc else 0,
            prefill_tokens_saved=pc.tokens_saved if pc else 0,
            prefix_evictions=pc.evictions if pc else 0,
            spec_verify_steps=self._spec_verifies,
            spec_drafted_tokens=self._spec_drafted,
            spec_accepted_tokens=self._spec_accepted,
            total_vsteps=self.vclock.t - self._v0,
            effective_top_k=dict(self._eff_topk))

    # -- main loop ---------------------------------------------------------
    def run(self, requests) -> ServeStats:
        """Drain a trace.  Closed-loop traces (every ``arrival_vstep``
        0) queue everything up front, exactly the old behaviour.  Open-
        loop traces release each request only once the virtual clock
        reaches its arrival; an idle pool with only future arrivals
        fast-forwards the clock to the next one (real time passes while
        nothing computes), so the schedule stays deterministic."""
        requests = list(requests)
        self.validate(requests)
        # all-greedy traces skip the sampler (argmax is its temperature-0 /
        # top_k-1 special case, so this is a pure fast path)
        self.all_greedy = all(r.temperature <= 0 or r.top_k == 1
                              for r in requests)
        self.reset()
        # stable sort: ties (and the all-zero closed loop) keep trace order
        pending = deque(sorted((_Entry(r) for r in requests),
                        key=lambda en: getattr(en.req, "arrival_vstep", 0)))
        for r in requests:
            r._t_submit = self._t0
        while pending or self.has_work:
            while pending and self._v0 + \
                    getattr(pending[0].req, "arrival_vstep", 0) \
                    <= self.vclock.t:
                en = pending.popleft()
                if self.tracer is not None:
                    # the wait span starts at *arrival*, not release: a
                    # fast-forwarded idle gap still counts as queue time 0
                    self.tracer.begin(
                        "queued", en.req.rid,
                        self._v0 + getattr(en.req, "arrival_vstep", 0),
                        replica=self.replica_id,
                        prompt_len=len(en.req.prompt))
                self.queue.append(en)
            if self.policy == "continuous" or \
                    not (self.active or self.prefill_backlog):
                self.admit_from_queue()
            if not self.active and not self.prefill_backlog:
                if self.queue:
                    en = self.queue[0]
                    raise PoolExhausted(
                        f"request {en.req.rid} ({en.pending_len} tokens) "
                        f"cannot be admitted into an otherwise idle pool — "
                        f"the KV pool is too small for it")
                if pending:
                    nxt = self._v0 + pending[0].req.arrival_vstep
                    self.vclock.advance(nxt - self.vclock.t)
                continue
            self.step()
        if self.tracer is not None:
            self.tracer.close(self.vclock.t)
        return self.stats()
