"""ServeEngine — the EASEY serving facade (continuous batching).

Glues the existing layers together the same way the training driver does:

    AppSpec(arch, decode shape) + TargetSpec --BuildService--> DeploymentPlan
        (the tuner's serve-mode branch sizes the KV pool from the HBM
         budget and records it in the plan)
    model_for(cfg) + build_prefill_step / build_decode_step_slots
        --> jitted steps (decode donates the pool cache)
    KVCachePool + Scheduler --> continuous or gang-scheduled batching

``launch/serve.py`` is a thin CLI over this class; the serving benchmark
drives both policies through one engine so the comparison shares every
compiled function.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.appspec import AppSpec
from repro.core.build import BuildService
from repro.core.target import get_target
from repro.models.params import init_params
from repro.models.transformer import model_for
from repro.serving.pool import KVCachePool
from repro.serving.scheduler import Scheduler, ServeStats
from repro.training.steps import build_decode_step_slots, build_prefill_step

SERVABLE_FAMILIES = ("dense", "moe")


class ServeEngine:
    """One model + one KV pool + jitted steps; runs request traces."""

    def __init__(self, arch: str = "deepseek-7b-smoke",
                 target: str = "local:cpu", num_slots: int = 8,
                 max_len: int = 128, seed: int = 0,
                 eos_id: int | None = None, log=print):
        app = AppSpec(arch=arch, shape="decode_32k",
                      shape_overrides={"seq_len": max_len,
                                       "global_batch": num_slots},
                      run="serve --engine continuous")
        cfg = app.model_config
        if cfg.family not in SERVABLE_FAMILIES:
            raise NotImplementedError(
                f"ServeEngine needs a slot-indexable attention KV cache; "
                f"family {cfg.family!r} is served by the legacy static path")
        if cfg.window:
            raise NotImplementedError(
                "slot-wise decode does not support sliding-window attention "
                "yet (the pool would attend the full history)")
        tgt = get_target(target)
        result = BuildService().build(app, tgt, lower=False)
        self.plan = result.plan
        # the tuner may cap the pool below the requested batch (HBM budget)
        self.num_slots = self.plan.serve_slots or num_slots
        self.max_len = self.plan.serve_max_len or max_len
        if self.num_slots < num_slots:
            log(f"[serve] pool capped by HBM budget: "
                f"{num_slots} -> {self.num_slots} slots")
        self.cfg = cfg
        self.model = model_for(cfg, remat="none")
        self.mesh = None if tgt.num_chips == 1 else result.mesh
        self.eos_id = eos_id
        self.log = log
        self.params = init_params(self.model.param_table(),
                                  jax.random.PRNGKey(seed))
        prefill = build_prefill_step(self.model, self.mesh)
        decode = build_decode_step_slots(self.model, self.mesh)
        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    # -- step wrappers bound to the params ---------------------------------
    def prefill_fn(self, tokens: jax.Array):
        return self._prefill(self.params, {"tokens": tokens})

    def decode_fn(self, cache, tokens, active):
        return self._decode(self.params, cache, tokens, active)

    # -- driving -----------------------------------------------------------
    def make_pool(self) -> KVCachePool:
        return KVCachePool(self.model, self.num_slots, self.max_len)

    def run(self, requests, policy: str = "continuous") -> ServeStats:
        """Drain `requests` under `policy` ('continuous' | 'static').

        A fresh pool per run keeps back-to-back policy comparisons honest
        (same cold cache state; jitted steps stay warm across runs).
        """
        sched = Scheduler(self.make_pool(), self.prefill_fn, self.decode_fn,
                          eos_id=self.eos_id, policy=policy)
        stats = sched.run(list(requests))
        self.log(f"[serve:{policy}] {stats.summary()}")
        return stats
