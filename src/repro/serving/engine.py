"""ServeEngine — the EASEY serving facade (continuous batching).

Glues the existing layers together the same way the training driver does:

    AppSpec(arch, decode shape) + TargetSpec --BuildService--> DeploymentPlan
        (the tuner's serve-mode branch sizes BOTH KV layouts from the HBM
         budget: a contiguous slots x max_len pool and a paged
         num_pages x page_size pool, and records them in the plan/napkin)
    model_for(cfg) + build_prefill_step +
        build_decode_step_slots / build_decode_step_slots_paged
        --> jitted steps (decode donates the pool cache)
    KVCachePool | PagedKVCachePool + Scheduler
        --> continuous or gang-scheduled batching

``kv_layout`` selects the memory layer:

* ``"contiguous"`` — every slot pins max_len positions of HBM; the slot
  count is the tuner's worst-case cap (``plan.serve_slots``).
* ``"paged"`` — slots hold page lists over a budget-sized page pool
  (``plan.serve_num_pages`` x ``plan.serve_page_size``); concurrency is
  bounded by actual tokens, so heavy-tailed traces admit far more
  requests in the same budget (at the cost of page-pressure preemptions
  when the tail bites).

Prompt ingestion runs through the chunk-prefill step
(``build_prefill_chunk_step[_paged]``), which scatters each chunk's KV
straight into pool slots/pages — no intermediate contiguous ``(1, s)``
cache.  ``prefill_chunk`` picks the grain: the tuner's
``plan.serve_prefill_chunk`` by default (chunks interleave with decode
ticks inside ``Scheduler.step``), or 0 for blocking full-prompt prefill
at admission (the old cadence, kept as the TTFT baseline — both modes
are token-identical by construction).

``prefix_cache=True`` (paged layout only) attaches a shared-prefix KV
cache (``serving/prefix_cache.PrefixCache``) to every pool this engine
builds: admissions whose prompt prefix is already resident reuse the
cached page run by pointer copy and prefill only the cold suffix.  The
tuner budgets the cache's LRU pin cap (``plan.serve_prefix_cache_pages``)
out of the same page pool.  Cached and cache-off runs are token-
identical by construction — the cache only changes *where* prefix KV
comes from, never its bits.

``kv_kernel`` selects the paged decode attention implementation:

* ``"gather"`` — read K/V back *through* the page table into a
  materialized ``(slots, max_pages*page_size, K, dh)`` tensor, then
  attend (the reference path; only option for the contiguous layout).
* ``"pallas"`` — the fused Pallas paged-attention kernel
  (``kernels/paged_attention.py``): the page table is walked inside the
  kernel, K/V stream page-by-page from the pool with online softmax in
  VMEM scratch, and the materialized gather never hits HBM.
* ``"auto"`` (default) — follow the tuner (``plan.serve_kv_kernel``:
  pallas targets get the kernel, reference targets the gather).

Both implementations are token-identical (the equivalence sweep in
tests/test_kernels_paged.py and the engine-level stream check in
tests/test_serving_paged.py hold them to it).

``launch/serve.py`` is a thin CLI over this class; the serving benchmark
drives both layouts and both policies through engines that share the
request traces, so every comparison is apples-to-apples.

``replicas`` > 1 declares this engine one of N co-resident replicas
behind a ``ReplicaRouter``: the tuner splits the HBM budget N ways and
every pool size above becomes a per-replica figure (the plan's napkin
additionally quotes the fleet-aggregate ``serve_fleet_capacity``).

``spec_k`` turns on draft-then-verify speculative decoding: every decode
tick drafts k tokens per slot (``serving/spec.NGramDrafter`` by default —
longest-suffix n-gram over the slot's own prompt + generated history; any
object with ``draft(history, k)`` plugs in via ``drafter=``, the hook a
small ``configs/`` drafter model drops into), scores all k+1 positions in
ONE jitted verify step, and accepts the longest draft prefix matching the
sequential sampler's own ``(rid, step)`` draws — so speculative token
streams are **bit-identical** to ``spec_k=0`` while a tick can emit up to
k+1 tokens per slot.  Accepted bursts are charged against pages with the
junk-page-0 overwrite guard, so a burst can never scribble into a
prefix-shared page.  ``spec_k=None`` defers to the tuner
(``plan.serve_spec_k``, picked from the trace's repetitiveness — see
``repetitiveness=``); 0 disables.  Typical usage::

    eng = ServeEngine(arch="picolm-4-smoke", kv_layout="paged", spec_k=4)
    stats = eng.run(repetitive_trace(32, eng.cfg.vocab_size))
    stats.accepted_per_verify     # tokens emitted per verify step (> 1
    stats.spec_accepted_tokens    #  when drafts are being accepted)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.appspec import AppSpec
from repro.core.build import BuildService
from repro.core.target import get_target
from repro.models.params import init_params
from repro.models.transformer import model_for
from repro.serving.pool import KVCachePool, PagedKVCachePool
from repro.serving.sampling import make_sampler
from repro.serving.scheduler import Scheduler, ServeStats
from repro.training.steps import (build_decode_step_slots,
                                  build_decode_step_slots_paged,
                                  build_prefill_chunk_step,
                                  build_prefill_chunk_step_paged,
                                  build_prefill_step,
                                  build_verify_step_slots,
                                  build_verify_step_slots_paged)

SERVABLE_FAMILIES = ("dense", "moe")
KV_LAYOUTS = ("contiguous", "paged")
KV_KERNELS = ("auto", "gather", "pallas")


class ServeEngine:
    """One model + one KV pool + jitted steps; runs request traces."""

    def __init__(self, arch: str = "deepseek-7b-smoke",
                 target: str = "local:cpu", num_slots: int = 8,
                 max_len: int = 128, seed: int = 0,
                 eos_id: int | None = None, kv_layout: str = "contiguous",
                 page_size: int = 0, num_pages: int = 0,
                 replicas: int = 1, prefill_chunk: int | None = None,
                 prefix_cache: bool = False, kv_kernel: str = "auto",
                 spec_k: int | None = 0, drafter=None,
                 repetitiveness: float = 0.0, log=print):
        if kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout {kv_layout!r} not in {KV_LAYOUTS}")
        if kv_kernel not in KV_KERNELS:
            raise ValueError(f"kv_kernel {kv_kernel!r} not in {KV_KERNELS}")
        if kv_kernel == "pallas" and kv_layout != "paged":
            raise ValueError(
                "kv_kernel='pallas' is the fused *paged* decode kernel — "
                f"it needs kv_layout='paged', not {kv_layout!r}")
        if replicas < 1:
            raise ValueError(f"replicas {replicas} < 1")
        if prefix_cache and kv_layout != "paged":
            raise ValueError(
                "prefix_cache reuses page runs by pointer copy — it needs "
                f"kv_layout='paged', not {kv_layout!r}")
        # `replicas` tells the tuner how many co-resident engines split the
        # HBM budget (ReplicaRouter fleets); num_slots stays the *per
        # replica* ask, so the fleet-wide batch is num_slots x replicas
        if spec_k is not None and spec_k < 0:
            raise ValueError(f"spec_k {spec_k} < 0")
        if not 0.0 <= repetitiveness <= 1.0:
            raise ValueError(f"repetitiveness {repetitiveness} not in [0, 1]")
        app = AppSpec(arch=arch, shape="decode_32k",
                      shape_overrides={"seq_len": max_len,
                                       "global_batch": num_slots * replicas,
                                       "serve_replicas": replicas,
                                       "serve_repetitiveness": repetitiveness},
                      run=f"serve --engine continuous --kv-layout {kv_layout}")
        cfg = app.model_config
        if cfg.family not in SERVABLE_FAMILIES:
            raise NotImplementedError(
                f"ServeEngine needs a slot-indexable attention KV cache; "
                f"family {cfg.family!r} is served by the legacy static path")
        if cfg.window:
            raise NotImplementedError(
                "slot-wise decode does not support sliding-window attention "
                "yet (the pool would attend the full history)")
        tgt = get_target(target)
        result = BuildService().build(app, tgt, lower=False)
        self.plan = result.plan
        self.kv_layout = kv_layout
        self.replicas = replicas
        self.max_len = self.plan.serve_max_len or max_len
        if kv_layout == "paged":
            # the page pool, not the slot count, is the HBM reservation:
            # slots are page-table rows, so the engine keeps the requested
            # concurrency (capped only by one-page-per-active-request)
            self.page_size = page_size or self.plan.serve_page_size or 16
            if num_pages:
                self.num_pages = num_pages
            elif self.plan.serve_num_pages and \
                    self.page_size == self.plan.serve_page_size:
                self.num_pages = self.plan.serve_num_pages
            elif self.plan.serve_num_pages:
                # tuner sized the pool for its own page size — carry the
                # *token* budget over to the requested page size
                tokens = (self.plan.serve_num_pages - 1) * \
                    self.plan.serve_page_size
                self.num_pages = max(tokens // self.page_size, 1) + 1
            else:
                self.num_pages = 0
            usable = (self.num_pages - 1) if self.num_pages else num_slots
            self.num_slots = max(1, min(num_slots, usable))
            if self.num_slots < num_slots:
                log(f"[serve] pool capped by page budget: {num_slots} -> "
                    f"{self.num_slots} slots (1 page per active request)")
        else:
            self.page_size = 0
            self.num_pages = 0
            # the tuner may cap the pool below the requested batch (HBM
            # budget): a contiguous slot is a worst-case reservation
            self.num_slots = self.plan.serve_slots or num_slots
            if self.num_slots < num_slots:
                log(f"[serve] pool capped by HBM budget: "
                    f"{num_slots} -> {self.num_slots} slots")
        self.cfg = cfg
        self.model = model_for(cfg, remat="none")
        self.mesh = None if tgt.num_chips == 1 else result.mesh
        self.eos_id = eos_id
        self.seed = seed
        self.log = log
        # shared-prefix KV cache (paged only): the tuner carves an LRU
        # pin budget out of the same page pool; default off so cache-off
        # baselines (and every pre-cache benchmark cell) are untouched.
        # The plan's quota is a page count for the PLAN's pool — make_pool
        # re-caps it against the pool actually built, so an explicit
        # --num-pages/--page-size override can never void the ~1/4 bound.
        self.prefix_cache = prefix_cache
        self.prefix_cache_pages = self.plan.serve_prefix_cache_pages
        # prompt-ingestion grain: None -> the tuner's chunk size; 0 ->
        # blocking full-prompt prefill; >0 -> explicit chunk tokens.
        # chunk_unit prices blocking prefills on the virtual TTFT clock
        # in the SAME chunk-equivalents, whatever mode runs.
        self.chunk_unit = self.plan.serve_prefill_chunk or 16
        self.prefill_chunk = self.chunk_unit if prefill_chunk is None \
            else prefill_chunk
        self.params = init_params(self.model.param_table(),
                                  jax.random.PRNGKey(seed))
        self.sampler = make_sampler(seed)
        prefill = build_prefill_step(self.model, self.mesh)
        self._prefill = jax.jit(prefill)
        if kv_layout == "paged":
            # "auto" follows the tuner's call for this target; the plan
            # field is only "" for non-serve shapes, so default to gather
            self.kv_kernel = kv_kernel if kv_kernel != "auto" \
                else (self.plan.serve_kv_kernel or "gather")
            decode = build_decode_step_slots_paged(
                self.model, self.mesh,
                use_kernel=(self.kv_kernel == "pallas"))
            chunk = build_prefill_chunk_step_paged(self.model, self.mesh)
            verify = build_verify_step_slots_paged(self.model, self.mesh)
        else:
            self.kv_kernel = "gather"
            decode = build_decode_step_slots(self.model, self.mesh)
            chunk = build_prefill_chunk_step(self.model, self.mesh)
            verify = build_verify_step_slots(self.model, self.mesh)
        self._decode = jax.jit(decode, donate_argnums=(1,))
        # kv_bound (arg 6) is static: it sizes the chunk's KV read-back,
        # so the chunk jit cache is (chunk buckets) x (bound buckets)
        self._chunk = jax.jit(chunk, donate_argnums=(1,),
                              static_argnums=(6,))
        # speculative verify step: jit is lazy, so building it costs
        # nothing until spec_k > 0 actually drives a verify tick
        self._verify = jax.jit(verify, donate_argnums=(1,))
        # spec_k=None defers to the tuner's pick for this trace shape
        # (plan.serve_spec_k, from the serve_repetitiveness hint); the
        # Pallas kernel still serves the s=1 ticks — verify bursts read
        # through the (token-identical) gather path inside the step
        self.spec_k = self.plan.serve_spec_k if spec_k is None else spec_k
        self.drafter = drafter

    # -- step wrappers bound to the params ---------------------------------
    def prefill_fn(self, tokens: jax.Array, last: int | None = None):
        batch = {"tokens": tokens}
        if last is not None:
            batch["last"] = jnp.int32(last)
        return self._prefill(self.params, batch)

    def decode_fn(self, cache, tokens, active, *extras):
        return self._decode(self.params, cache, tokens, active, *extras)

    def chunk_fn(self, cache, tokens, slot, offset, n_valid, *extras):
        """Prefill one prompt chunk straight into the pool cache (donated)."""
        return self._chunk(self.params, cache, tokens, slot, offset,
                           n_valid, *extras)

    def verify_fn(self, cache, tokens, active, *extras):
        """Score a (num_slots, k+1) speculative batch; logits at every
        position (cache donated; index stays host-authoritative)."""
        return self._verify(self.params, cache, tokens, active, *extras)

    # -- driving -----------------------------------------------------------
    def make_pool(self, prefix_cache: bool | None = None):
        """A fresh pool (and, when enabled, a fresh shared-prefix cache
        attached to it — per pool, so per replica under a router).
        ``prefix_cache`` overrides the engine default for this pool."""
        use_cache = self.prefix_cache if prefix_cache is None \
            else prefix_cache
        if self.kv_layout == "paged":
            pool = PagedKVCachePool(self.model, self.num_slots, self.max_len,
                                    page_size=self.page_size,
                                    num_pages=self.num_pages)
            if use_cache:
                from repro.core.tuning import prefix_cache_quota
                from repro.serving.prefix_cache import PrefixCache
                # the tuner's quota, but never more than ~1/4 of the pool
                # that actually got built (it may be smaller than the
                # plan's when --num-pages/--page-size override the tuner)
                cap = prefix_cache_quota(pool.num_pages)
                budget = min(self.prefix_cache_pages or cap, cap)
                PrefixCache(pool, max_pages=max(budget, 1))
            return pool
        if use_cache:
            raise ValueError("prefix_cache needs the paged KV layout")
        return KVCachePool(self.model, self.num_slots, self.max_len)

    def run(self, requests, policy: str = "continuous",
            prefill_chunk: int | None = None,
            prefix_cache: bool | None = None,
            spec_k: int | None = None,
            slo_ttft_steps: int = 0,
            slo_e2e_steps: int = 0,
            tracer=None) -> ServeStats:
        """Drain `requests` under `policy` ('continuous' | 'static').

        A fresh pool per run keeps back-to-back policy comparisons honest
        (same cold cache state; jitted steps stay warm across runs).
        ``prefill_chunk`` overrides the engine's ingestion grain for this
        run (0 = blocking full-prompt prefill); ``prefix_cache`` toggles
        the shared-prefix KV cache for this run — cached and cache-off
        runs share every jitted step, so either comparison is free.
        ``spec_k`` overrides the engine's speculative draft length for
        this run (0 = plain one-token decode) — spec-on and spec-off runs
        also share every jitted step, and their token streams are
        bit-identical by construction.
        ``slo_ttft_steps`` / ``slo_e2e_steps`` set the virtual-step
        deadlines ``ServeStats.goodput_tokens`` is judged by (0 = unset;
        the tuner's suggestions live in ``plan.serve_slo_ttft_steps`` /
        ``plan.serve_slo_e2e_steps``).  Requests whose ``arrival_vstep``
        is set are admitted open-loop: only once the virtual clock
        reaches their arrival.
        ``tracer`` (a ``serving.telemetry.Tracer``) records per-request
        spans and ring events on the virtual clock — pure host-side
        bookkeeping behind None-guards, so tracing on/off cannot change
        a single token.
        """
        chunk = self.prefill_chunk if prefill_chunk is None else prefill_chunk
        k = self.spec_k if spec_k is None else spec_k
        sched = Scheduler(self.make_pool(prefix_cache=prefix_cache),
                          self.prefill_fn, self.decode_fn,
                          eos_id=self.eos_id, policy=policy,
                          sampler=self.sampler, chunk_step_fn=self.chunk_fn,
                          prefill_chunk=chunk,
                          prefill_chunk_unit=self.chunk_unit,
                          verify_fn=self.verify_fn if k else None,
                          spec_k=k, drafter=self.drafter,
                          vocab_size=self.cfg.vocab_size,
                          slo_ttft_steps=slo_ttft_steps,
                          slo_e2e_steps=slo_e2e_steps,
                          tracer=tracer)
        stats = sched.run(list(requests))
        self.log(f"[serve:{self.kv_layout}:{policy}] {stats.summary()}")
        return stats
