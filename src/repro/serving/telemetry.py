"""Vstep-clocked request tracing + the unified serving metrics registry.

EASEY's middle layers exist so the *framework* observes the deployment
and feeds what it sees back into configuration — the scientist never
instruments anything by hand.  Until now the serving stack only reported
end-of-run aggregates (``ServeStats`` / ``RouterStats``): when a bench
cell regresses or the autoscaler thrashes there is no per-request
timeline explaining *why*.  This module is that timeline layer, and the
single source of truth for every flat metric key the stack exports.

Three pieces:

* ``Tracer`` — per-request **spans** on the deterministic virtual-step
  clock (queued -> prefill_chunk[i] -> cache_attach -> decode ->
  spec_verify -> resume -> ...), plus a bounded structured **event
  ring** (preemptions, reroutes, SLO rejections, prefix-cache reclaims,
  autoscale transitions).  Every timestamp is a vstep — never wall
  clock — so two identical runs produce byte-identical traces and a
  test can assert on them.  The tracer is pure host-side bookkeeping:
  instrumentation sites are guarded by ``if tracer is not None`` and no
  trace state ever enters jitted code, so telemetry-on streams are
  bit-identical to telemetry-off by construction.

* ``MetricsRegistry`` — counters / gauges / histograms behind a declared
  schema (``SERVE_SCHEMA`` / ``ROUTER_SCHEMA``).  ``ServeStats
  .to_metrics()`` and ``RouterStats.to_metrics()`` are *views over this
  registry*: they set exactly the schema's keys and ``snapshot()``
  refuses extras or omissions, so the exported key set can never drift
  from the declared one (the schema table in ``router.py``'s docstring
  is unit-tested against it).

* Exporters — ``prometheus_text`` (Prometheus text exposition format,
  ``# HELP`` / ``# TYPE`` per family) and ``chrome_trace`` /
  ``write_chrome_trace`` (Chrome-trace / Perfetto JSON: one *process*
  per replica, one *thread* per slot plus a queue lane, complete-event
  spans with vstep timestamps, instant events for the ring).  Load a
  ``--trace-out`` file at https://ui.perfetto.dev to read one request's
  queued -> prefill -> decode life as a timeline.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from collections import deque

# ---------------------------------------------------------------------------
# Metric schema: the single source every flat metrics export goes through


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One declared metric: exact key, or a template containing ``{i}``
    (expanded per replica by the router view)."""
    key: str
    kind: str                     # "counter" | "gauge" | "histogram"
    help: str

    def __post_init__(self):
        if self.kind not in ("counter", "gauge", "histogram"):
            raise ValueError(f"metric kind {self.kind!r}")


def _c(key, help):
    return MetricSpec(key, "counter", help)


def _g(key, help):
    return MetricSpec(key, "gauge", help)


# Suffixes shared by the single-engine and router views: same meaning,
# same kind, one definition — prefixed "serve_" / "router_" below.
_COMMON = (
    _c("requests_completed", "requests fully served"),
    _c("generated_tokens", "tokens emitted"),
    _c("goodput_tokens", "tokens from requests meeting the SLO"),
    _g("slo_ttft_steps", "TTFT deadline judged by (0=unset)"),
    _g("slo_e2e_steps", "e2e deadline judged by (0=unset)"),
    _g("ttft_p50_steps", "median TTFT, virtual steps"),
    _g("ttft_p99_steps", "p99 TTFT, virtual steps"),
    _g("e2e_p50_steps", "median e2e latency, virtual steps"),
    _g("e2e_p99_steps", "p99 e2e latency, virtual steps"),
    _g("mean_ttft_steps", "mean TTFT, virtual steps"),
    _c("total_vsteps", "virtual step clock at drain end"),
    _g("wall_s", "wall time (ADVISORY only)"),
    _g("tokens_per_s", "wall throughput (ADVISORY only)"),
)


def _prefixed(prefix, specs):
    return tuple(dataclasses.replace(s, key=prefix + s.key) for s in specs)


#: Flat key schema behind ``ServeStats.to_metrics()`` (single engine).
SERVE_SCHEMA = _prefixed("serve_", _COMMON) + (
    _c("serve_decode_steps", "scheduler decode/verify ticks"),
    _g("serve_occupancy", "mean active-slot fraction per decode step"),
    _g("serve_peak_active", "max concurrent in-flight requests"),
    _g("serve_peak_resident_kv", "max KV tokens resident in the pool"),
    _c("serve_preemptions", "page-pressure evictions"),
    _c("serve_prefill_chunks", "prefill chunk-step invocations"),
    _c("serve_prefill_tokens", "prompt tokens ingested through chunks"),
    _c("serve_prefix_hits", "admissions that reused a cached prefix run"),
    _c("serve_prefix_misses", "admissions with no cached prefix"),
    _c("serve_prefill_tokens_saved", "prompt tokens skipped via cache hits"),
    _c("serve_prefix_evictions", "prefix-cache cells reclaimed"),
    _c("serve_spec_verify_steps", "speculative slot-verify scoring events"),
    _c("serve_spec_drafted_tokens", "draft tokens proposed"),
    _c("serve_spec_accepted_tokens", "draft tokens accepted"),
)

#: Flat key schema behind ``RouterStats.to_metrics()`` — the table in
#: ``router.py``'s module docstring renders exactly these.
ROUTER_SCHEMA = _prefixed("router_", _COMMON) + (
    _c("router_requests_rejected", "SLO admission rejections"),
    _g("router_peak_in_flight", "max concurrent requests, fleet-wide"),
    _g("router_peak_replicas", "max replicas serving or draining"),
    _c("router_reroutes", "starvation re-dispatches"),
    _c("router_autoscale_grows", "replicas activated"),
    _c("router_autoscale_drains", "drains initiated"),
    _g("router_load_imbalance", "max/mean peak resident KV tokens"),
    _c("replica{i}_generated_tokens", "per-replica tokens"),
    _c("replica{i}_decode_steps", "per-replica scheduler ticks"),
    _g("replica{i}_peak_resident_kv", "per-replica peak resident tokens"),
    _c("replica{i}_preemptions", "per-replica page-pressure evicts"),
    _g("replica{i}_occupancy", "per-replica mean slot occupancy"),
)


@dataclasses.dataclass
class Histogram:
    """Fixed-bucket histogram (Prometheus-style cumulative on export)."""
    bounds: tuple                  # ascending upper bounds; +inf implicit
    counts: list = None
    total: int = 0
    sum: float = 0.0

    def __post_init__(self):
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram bounds not ascending {self.bounds}")
        if self.counts is None:
            self.counts = [0] * (len(self.bounds) + 1)

    def observe(self, value: float) -> None:
        for i, b in enumerate(self.bounds):
            if value <= b:
                self.counts[i] += 1
                break
        else:
            self.counts[-1] += 1
        self.total += 1
        self.sum += float(value)


class MetricsRegistry:
    """Schema-validated counters/gauges/histograms behind one flat
    namespace.

    Two modes of use, one instrument set:

    * **view building** — construct from a declared schema
      (``SERVE_SCHEMA`` / ``ROUTER_SCHEMA``), ``set`` every key, then
      ``snapshot()``; a key outside the schema, or a declared exact key
      never set, raises — the drift ``to_metrics()`` used to allow.
    * **live accumulation** — ``declare`` metrics on the fly (the
      ``Tracer`` does this for its span/event counters and duration
      histogram), ``inc`` / ``observe`` as events happen.
    """

    def __init__(self, schema=()):
        self._specs: dict[str, MetricSpec] = {}
        self._templates: list[MetricSpec] = []
        self._values: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}
        for spec in schema:
            self.declare(spec)

    def declare(self, spec: MetricSpec, buckets=None) -> MetricSpec:
        if "{i}" in spec.key:
            self._templates.append(spec)
            return spec
        if spec.key in self._specs:
            raise ValueError(f"metric {spec.key!r} already declared")
        if not re.fullmatch(r"[a-zA-Z_][a-zA-Z0-9_]*", spec.key):
            raise ValueError(f"metric key {spec.key!r} is not a valid "
                             f"Prometheus metric name")
        self._specs[spec.key] = spec
        if spec.kind == "histogram":
            self._hists[spec.key] = Histogram(tuple(buckets or (1, 10, 100)))
        return spec

    def spec_for(self, key: str) -> MetricSpec:
        """Resolve ``key`` to its spec — exact match first, then the
        ``{i}`` templates (``replica3_...`` matches ``replica{i}_...``)."""
        spec = self._specs.get(key)
        if spec is not None:
            return spec
        for t in self._templates:
            if re.fullmatch(re.escape(t.key).replace(r"\{i\}", r"\d+"), key):
                return t
        raise KeyError(f"metric {key!r} is not in the declared schema")

    def set(self, key: str, value) -> None:
        """Record a snapshot value for a declared (or template) key."""
        spec = self.spec_for(key)
        if spec.kind == "histogram":
            raise ValueError(f"{key!r} is a histogram — use observe()")
        self._values[key] = value

    def inc(self, key: str, n: float = 1) -> None:
        if self.spec_for(key).kind != "counter":
            raise ValueError(f"{key!r} is not a counter")
        self._values[key] = self._values.get(key, 0) + n

    def observe(self, key: str, value: float) -> None:
        if self.spec_for(key).kind != "histogram":
            raise ValueError(f"{key!r} is not a histogram")
        self._hists[key].observe(value)

    def snapshot(self, require_complete: bool = True) -> dict:
        """Flat ``{key: number}`` dict in schema declaration order
        (template instances in set order).  ``require_complete`` makes an
        unset exact scalar key an error — a view that forgot a schema key
        must fail loudly, not export a truncated scrape.  Histograms
        flatten to ``{key}_count`` / ``{key}_sum`` / ``{key}_le_{b}``."""
        if require_complete:
            missing = [k for k, s in self._specs.items()
                       if s.kind != "histogram" and k not in self._values]
            if missing:
                raise ValueError(
                    f"metrics view did not set declared keys: {missing}")
        out = {}
        for key, spec in self._specs.items():
            if spec.kind == "histogram":
                h = self._hists[key]
                out[f"{key}_count"] = h.total
                out[f"{key}_sum"] = h.sum
                for b, c in zip(h.bounds, h.counts):
                    out[f"{key}_le_{b}"] = c
            elif key in self._values:
                out[key] = self._values[key]
        for key in self._values:
            if key not in self._specs:
                out[key] = self._values[key]
        return out

    def to_prometheus(self) -> str:
        return prometheus_text(self.snapshot(require_complete=False), self)


def _prom_value(v) -> str:
    if v is None or (isinstance(v, float) and math.isnan(v)):
        return "NaN"
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int):
        return str(v)
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def prometheus_text(metrics: dict, schema) -> str:
    """Render a flat metrics dict in the Prometheus text exposition
    format.  ``schema`` is a ``MetricsRegistry`` or a spec iterable —
    it supplies each family's ``# HELP`` / ``# TYPE`` lines; NaN (an
    idle fleet's percentile) renders as Prometheus's literal ``NaN``.
    Deterministic: the line order is the dict's insertion order."""
    reg = schema if isinstance(schema, MetricsRegistry) \
        else MetricsRegistry(schema)
    lines = []
    seen_families = set()
    for key, value in metrics.items():
        try:
            spec = reg.spec_for(key)
        except KeyError:
            spec = MetricSpec(key, "gauge", "")
        family = spec.key
        if family not in seen_families:
            seen_families.add(family)
            if spec.help:
                lines.append(f"# HELP {key} {spec.help}")
            lines.append(f"# TYPE {key} {spec.kind}")
        lines.append(f"{key} {_prom_value(value)}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Vstep-clocked request tracing


#: Request lifecycle phases a full serving run can emit spans for.
PHASES = ("queued", "prefill_chunk", "cache_attach", "decode",
          "spec_verify", "resume")

#: Structured event kinds the bounded ring can carry.
EVENT_KINDS = ("preempt", "reroute", "reject", "prefix_reclaim",
               "autoscale_grow", "autoscale_drain", "autoscale_stop")


@dataclasses.dataclass
class Span:
    """One request-lifecycle interval on the virtual step clock."""
    phase: str
    rid: int
    v_start: int
    v_end: int = -1               # -1 = still open
    replica: int = 0
    slot: int = -1                # -1 = not bound to a pool slot (queued)
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def steps(self) -> int:
        return max(self.v_end - self.v_start, 0) if self.v_end >= 0 else 0


@dataclasses.dataclass
class TraceEvent:
    """One structured instant in the bounded event ring."""
    kind: str
    vstep: int
    replica: int = 0
    rid: int = -1
    attrs: dict = dataclasses.field(default_factory=dict)


class Tracer:
    """Deterministic span/event recorder for one serving drain.

    Everything is keyed to the virtual step clock the scheduler already
    runs on, so traces are bit-reproducible: two identical runs emit
    identical span lists, identical rings, and (through
    ``write_chrome_trace``) byte-identical files.  The tracer is
    host-side only and opt-in — every instrumentation site is guarded by
    ``if tracer is not None`` and none touches jitted code, so enabling
    it cannot move a single token.

    Spans are ``begin``/``end`` bracketed and matched on ``(rid,
    phase)`` — deliberately not on replica, so a reroute's ``resume``
    span opened on the starved replica closes cleanly when another
    replica re-admits the request.  ``end`` on a phase that was never
    opened is counted (``unmatched_ends``) but ignored, so partially
    instrumented paths degrade to missing spans, never to crashes.
    """

    def __init__(self, ring_capacity: int = 1024):
        if ring_capacity < 1:
            raise ValueError(f"ring_capacity {ring_capacity} < 1")
        self.ring_capacity = ring_capacity
        self.spans: list[Span] = []
        self.events: deque[TraceEvent] = deque(maxlen=ring_capacity)
        self.total_events = 0
        self.unmatched_ends = 0
        self._open: dict[tuple, Span] = {}     # (rid, phase) -> span

    # -- spans ---------------------------------------------------------------
    def begin(self, phase: str, rid: int, vstep: int, replica: int = 0,
              slot: int = -1, **attrs) -> Span:
        """Open a span; appended to ``spans`` now so file order is the
        deterministic host-loop begin order.  Re-beginning an open
        ``(rid, phase)`` closes the old span at the new start first."""
        old = self._open.pop((rid, phase), None)
        if old is not None:
            old.v_end = int(vstep)
        span = Span(phase=phase, rid=int(rid), v_start=int(vstep),
                    replica=int(replica), slot=int(slot), attrs=dict(attrs))
        self.spans.append(span)
        self._open[(rid, phase)] = span
        return span

    def end(self, phase: str, rid: int, vstep: int, **attrs) -> bool:
        """Close the open ``(rid, phase)`` span; False when none open."""
        span = self._open.pop((rid, phase), None)
        if span is None:
            self.unmatched_ends += 1
            return False
        span.v_end = int(vstep)
        span.attrs.update(attrs)
        return True

    def end_any(self, phases, rid: int, vstep: int, **attrs) -> bool:
        """Close whichever of ``phases`` is open for ``rid`` (first
        match) — admission doesn't care whether the wait it terminates
        was a fresh ``queued`` or a preemption ``resume``."""
        for phase in phases:
            if (rid, phase) in self._open:
                return self.end(phase, rid, vstep, **attrs)
        self.unmatched_ends += 1
        return False

    def span(self, phase: str, rid: int, v_start: int, v_end: int,
             replica: int = 0, slot: int = -1, **attrs) -> Span:
        """Record an already-complete span (e.g. one spec-verify tick)."""
        s = Span(phase=phase, rid=int(rid), v_start=int(v_start),
                 v_end=int(v_end), replica=int(replica), slot=int(slot),
                 attrs=dict(attrs))
        self.spans.append(s)
        return s

    def close(self, vstep: int) -> int:
        """End-of-run flush: close every still-open span at ``vstep``
        (a request shed mid-wait, a drain cut short).  Returns the count."""
        n = 0
        for span in list(self._open.values()):
            span.v_end = int(vstep)
            n += 1
        self._open.clear()
        return n

    # -- events --------------------------------------------------------------
    def instant(self, kind: str, vstep: int, replica: int = 0,
                rid: int = -1, **attrs) -> TraceEvent:
        """Append a structured event to the bounded ring (oldest events
        fall off once ``ring_capacity`` is exceeded — ``dropped_events``
        says how many)."""
        ev = TraceEvent(kind=kind, vstep=int(vstep), replica=int(replica),
                        rid=int(rid), attrs=dict(attrs))
        self.events.append(ev)
        self.total_events += 1
        return ev

    @property
    def dropped_events(self) -> int:
        return self.total_events - len(self.events)

    def events_of(self, kind: str) -> list:
        return [e for e in self.events if e.kind == kind]

    def spans_of(self, phase: str) -> list:
        return [s for s in self.spans if s.phase == phase]

    # -- derived metrics ------------------------------------------------------
    def metrics(self) -> MetricsRegistry:
        """A live registry over the trace itself: span counts per phase,
        ring totals/drops, and a histogram of span durations (vsteps) —
        the histogram leg of the registry, fed from real trace data."""
        reg = MetricsRegistry()
        reg.declare(_c("trace_spans_total", "spans recorded"))
        reg.declare(_c("trace_events_total", "ring events recorded"))
        reg.declare(_c("trace_events_dropped",
                       "ring events lost to the capacity bound"))
        reg.declare(MetricSpec("trace_span_vsteps", "histogram",
                               "span durations, virtual steps"),
                    buckets=(1, 2, 4, 8, 16, 32, 64, 128))
        reg.inc("trace_spans_total", len(self.spans))
        reg.inc("trace_events_total", self.total_events)
        reg.inc("trace_events_dropped", self.dropped_events)
        for phase in PHASES:
            key = f"trace_{phase}_spans"
            reg.declare(_c(key, f"{phase} spans recorded"))
            reg.inc(key, len(self.spans_of(phase)))
        for span in self.spans:
            if span.v_end >= 0:
                reg.observe("trace_span_vsteps", span.steps)
        return reg


# ---------------------------------------------------------------------------
# Chrome-trace / Perfetto export


def _tid(span_slot: int) -> int:
    """Thread id inside a replica 'process': tid 0 is the queue/scheduler
    lane (spans not bound to a slot), pool slot s is tid s + 1."""
    return 0 if span_slot < 0 else span_slot + 1


def chrome_trace(tracer: Tracer) -> dict:
    """The tracer's spans + ring as a Chrome-trace (Perfetto-loadable)
    JSON object: one *process* per replica, one *thread* per pool slot
    (plus a tid-0 queue lane), complete events (``ph: "X"``) for spans
    and instant events (``ph: "i"``) for the ring.  All ``ts``/``dur``
    values are **virtual steps** — no wall clock anywhere, so identical
    runs serialize byte-identically."""
    events = []
    replicas = sorted({s.replica for s in tracer.spans} |
                      {e.replica for e in tracer.events})
    threads = sorted({(s.replica, _tid(s.slot)) for s in tracer.spans} |
                     {(r, 0) for r in replicas})
    for r in replicas:
        events.append({"name": "process_name", "ph": "M", "pid": r,
                       "args": {"name": f"replica {r}"}})
    for r, tid in threads:
        name = "queue" if tid == 0 else f"slot {tid - 1}"
        events.append({"name": "thread_name", "ph": "M", "pid": r,
                       "tid": tid, "args": {"name": name}})
    for s in tracer.spans:
        end = s.v_end if s.v_end >= 0 else s.v_start
        events.append({
            "name": s.phase, "cat": "request", "ph": "X",
            "pid": s.replica, "tid": _tid(s.slot),
            "ts": s.v_start, "dur": max(end - s.v_start, 0),
            "args": {"rid": s.rid, **s.attrs},
        })
    for e in tracer.events:
        args = {"rid": e.rid, **e.attrs} if e.rid >= 0 else dict(e.attrs)
        events.append({
            "name": e.kind, "cat": "fleet", "ph": "i", "s": "p",
            "pid": e.replica, "tid": 0, "ts": e.vstep, "args": args,
        })
    # stable sort by (ts, pid, tid): deterministic input stays
    # deterministic, and Perfetto gets monotone timestamps
    events.sort(key=lambda ev: (ev.get("ts", -1), ev["pid"],
                                ev.get("tid", 0)))
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"clock": "virtual steps (1 ts = 1 jitted invocation)",
                      "dropped_ring_events": tracer.dropped_events},
    }


def write_chrome_trace(tracer: Tracer, path) -> dict:
    """Serialize ``chrome_trace(tracer)`` to ``path``.  ``sort_keys`` +
    fixed indent make the bytes a pure function of the span/event data,
    which is itself a pure function of the (deterministic) run."""
    trace = chrome_trace(tracer)
    from pathlib import Path
    Path(path).write_text(json.dumps(trace, indent=1, sort_keys=True))
    return trace


def json_sanitize(obj):
    """Recursively map NaN/inf floats to None so ``json.dumps`` emits
    strict JSON (``null``), matching the bench's NaN->null convention."""
    if isinstance(obj, dict):
        return {k: json_sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_sanitize(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    return obj
