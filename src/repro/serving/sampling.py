"""Per-request sampling for the slot-wise decode loop.

Each pool slot samples with its *own* temperature / top-k / top-p / PRNG
stream: the key for a draw is ``fold_in(fold_in(base, rid), step)`` where
``step`` is how many tokens the request has generated so far.  Keying on
the request id and the generation step (rather than the slot or the wall
clock) makes sampling deterministic across admission order, slot
assignment, *and* preemption — a request that is preempted and later
resumed re-draws exactly the token stream it would have produced
uninterrupted, which is what keeps the paged-vs-contiguous equivalence
tests honest under page pressure.  It is also what makes speculative
decoding bit-identical: the verify step samples positions
``step .. step+k`` with the very same per-row math, so an accepted burst
reproduces the sequential draws token for token.

Greedy decoding is the ``temperature == 0`` row-wise special case, so a
trace of default requests reproduces the old argmax scheduler bit-for-bit.
Top-k is capped at ``effective_top_k`` (one static ``lax.top_k``; per-row
k masks below the row's k-th value); ``top_k == 0`` disables the filter.
Requests asking for ``top_k > K_CAP`` are rejected at submission
(``Scheduler.validate``) instead of being silently clamped here, and the
effective per-request k (after the vocab cap) is surfaced in
``ServeStats.effective_top_k``.  Top-p (nucleus) keeps the smallest
probability-sorted set whose mass reaches p; ``top_p >= 1`` leaves the
logits bit-untouched, so default requests are unaffected.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

K_CAP = 64


def effective_top_k(top_k: int, vocab_size: int, k_cap: int = K_CAP) -> int:
    """The k the sampler actually applies for a request's ``top_k``:
    0 (filter off) or min(top_k, K_CAP, vocab)."""
    if top_k <= 0:
        return 0
    return min(top_k, k_cap, vocab_size)


def make_sampler(seed: int, k_cap: int = K_CAP):
    """Jitted (logits, temperature, top_k, top_p, rids, steps) -> int32.

    logits: (rows, vocab); temperature/top_p float32 (rows,);
    top_k/rids/steps int32 (rows,).  Works for the full pool
    (rows = num_slots), the single-row prefill first-token draw, and the
    flattened (num_slots * (k+1)) speculative verify batch alike.
    """
    base = jax.random.PRNGKey(seed)

    def _row(lg, temp, k, p, rid, step):
        lg = lg.astype(jnp.float32)
        greedy = jnp.argmax(lg).astype(jnp.int32)
        key = jax.random.fold_in(jax.random.fold_in(base, rid), step)
        cap = min(k_cap, lg.shape[-1])   # static: top_k(v, 64) on vocab 4
        kk = jnp.clip(k, 0, cap)
        vals, _ = jax.lax.top_k(lg, cap)
        kth = vals[jnp.maximum(kk - 1, 0)]
        masked = jnp.where((kk > 0) & (lg < kth), -jnp.inf, lg)
        # nucleus (top-p) on the already-k-filtered logits: keep the
        # smallest probability-sorted set whose mass reaches p (ties at
        # the cutoff all kept — deterministic).  Gated with a select so
        # p >= 1 (the default) passes `masked` through bit-identically.
        probs = jax.nn.softmax(masked)
        sp = jnp.sort(probs)[::-1]
        prior = jnp.cumsum(sp) - sp          # mass strictly above each tok
        cut = jnp.min(jnp.where(prior < p, sp, jnp.inf))
        nucleus = jnp.where(probs >= cut, masked, -jnp.inf)
        masked = jnp.where((p > 0) & (p < 1), nucleus, masked)
        drawn = jax.random.categorical(
            key, masked / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
        # top_k == 1 IS argmax; routing it through categorical would break
        # logit ties randomly where argmax breaks them by index
        return jnp.where((temp > 0) & (kk != 1), drawn, greedy)

    @jax.jit
    def sample(logits, temperature, top_k, top_p, rids, steps):
        return jax.vmap(_row)(logits, temperature, top_k, top_p, rids, steps)

    return sample
