"""Per-request sampling for the slot-wise decode loop.

Each pool slot samples with its *own* temperature / top-k / PRNG stream:
the key for a draw is ``fold_in(fold_in(base, rid), step)`` where ``step``
is how many tokens the request has generated so far.  Keying on the
request id and the generation step (rather than the slot or the wall
clock) makes sampling deterministic across admission order, slot
assignment, *and* preemption — a request that is preempted and later
resumed re-draws exactly the token stream it would have produced
uninterrupted, which is what keeps the paged-vs-contiguous equivalence
tests honest under page pressure.

Greedy decoding is the ``temperature == 0`` row-wise special case, so a
trace of default requests reproduces the old argmax scheduler bit-for-bit.
Top-k is capped at ``K_CAP`` (one static ``lax.top_k``; per-row k masks
below the row's k-th value); ``top_k == 0`` disables the filter.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

K_CAP = 64


def make_sampler(seed: int, k_cap: int = K_CAP):
    """Jitted (logits, temperature, top_k, rids, steps) -> (rows,) int32.

    logits: (rows, vocab); temperature float32 (rows,); top_k/rids/steps
    int32 (rows,).  Works for the full pool (rows = num_slots) and for
    the single-row prefill first-token draw alike.
    """
    base = jax.random.PRNGKey(seed)

    def _row(lg, temp, k, rid, step):
        lg = lg.astype(jnp.float32)
        greedy = jnp.argmax(lg).astype(jnp.int32)
        key = jax.random.fold_in(jax.random.fold_in(base, rid), step)
        kk = jnp.clip(k, 0, k_cap)
        vals, _ = jax.lax.top_k(lg, k_cap)
        kth = vals[jnp.maximum(kk - 1, 0)]
        masked = jnp.where((kk > 0) & (lg < kth), -jnp.inf, lg)
        drawn = jax.random.categorical(
            key, masked / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
        # top_k == 1 IS argmax; routing it through categorical would break
        # logit ties randomly where argmax breaks them by index
        return jnp.where((temp > 0) & (kk != 1), drawn, greedy)

    @jax.jit
    def sample(logits, temperature, top_k, rids, steps):
        return jax.vmap(_row)(logits, temperature, top_k, rids, steps)

    return sample
