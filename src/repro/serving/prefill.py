"""PrefillManager — chunked prompt ingestion as its own schedulable stage.

Prompt ingestion used to be an inline side effect of admission: the
scheduler ran one blocking full-prompt prefill, materialized a contiguous
``(1, s)`` cache, and ``insert`` re-scattered it into the pool — stalling
the decode loop for the whole prompt and (under the router) stalling the
whole lockstep fleet, since admissions run serially on the driver thread.
This module splits prefill out, the way EASEY's middleware layer splits a
tunable stage out of a monolithic deployment step:

* a prompt is cut into fixed-size **chunks** (the tuner's
  ``plan.serve_prefill_chunk``); each chunk is padded to a power-of-two
  bucket so the jit cache stays at ~log2(chunk) entries;
* each chunk runs through the **chunk-prefill step**
  (``training/steps.build_prefill_chunk_step[_paged]``), which computes
  the chunk's KV and scatters it **directly into pool slots/pages** —
  its final resting place, one write, no contiguous intermediate — while
  attending causally over every prior chunk through the pool's own
  indirection (page table or slot row);
* the scheduler interleaves at most one chunk budget's worth of prefill
  tokens between decode ticks (``Scheduler.step``), so in-flight requests
  keep streaming while a new prompt is ingested, and a router overlaps
  replica A's ingestion with B/C's decode ticks.

The pool reservation (slot + all prompt pages) happens at **submit** —
the same decision point blocking admission reserved at — so admission
order, preemption behaviour, and therefore every token stream are
identical to the blocking path.  Blocking mode itself is just the
degenerate manager: one chunk covering the whole (bucketed) prompt,
drained inline at admission.

When the pool carries a shared-prefix cache
(``serving/prefix_cache.PrefixCache``), ``submit`` probes it first: a hit
installs the cached page run into the slot by pointer copy and starts the
ingest cursor *past* the shared prefix, so only the cold suffix is ever
cut into chunks — zero chunk steps and zero KV writes for the reused
part.  The final chunk of every prompt registers its fully-covered pages
back into the cache, so the first request over a prefix pays for all its
successors.

Counters (chunks run, tokens ingested, distinct compiled buckets, queue
peak, cache hits/misses/saved tokens) feed ``Scheduler.stats`` — the
observability the tuner's chunk-size and cache-budget choices are judged
against.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax.numpy as jnp
import numpy as np


def bucket_len(n: int) -> int:
    """Power-of-two jit bucket for an `n`-token chunk."""
    return 1 << (max(n, 1) - 1).bit_length()


@dataclasses.dataclass
class PrefillJob:
    """One request's prompt mid-ingestion: the scheduler entry it will
    activate, the full pending token prefix (prompt plus anything already
    generated before a preemption), and the ingest cursor."""
    entry: object                  # scheduler _Entry
    st: object                     # RequestResult being (re)built
    prompt: np.ndarray             # (n,) int32 pending prefix
    slot: int
    done: int = 0                  # tokens already scattered into the pool
    chunks: int = 0                # chunk steps run so far (trace span index)
    admit_step: int = 0            # scheduler step at SUBMISSION — the
    #                                preemption-age stamp, so the victim
    #                                choice matches blocking admission
    #                                however ingestion was interleaved

    @property
    def remaining(self) -> int:
        return len(self.prompt) - self.done


class PrefillManager:
    """Chunk queue + chunk-step driver over one KV pool.

    ``chunk_tokens`` is the interleave grain: ``tick`` ingests at most
    that many prompt tokens per call (0 means whole-prompt chunks — the
    blocking degenerate, driven via ``drain``).
    """

    def __init__(self, pool, chunk_step, chunk_tokens: int = 0,
                 tracer=None, vclock=None, replica_id: int = 0):
        if chunk_tokens < 0:
            raise ValueError(f"chunk_tokens {chunk_tokens} < 0")
        self.pool = pool
        self.chunk_step = chunk_step   # (cache, toks, slot, off, n, *extras)
        self.chunk_tokens = chunk_tokens
        # telemetry hook (None = off): host-side span bookkeeping only,
        # recorded after each chunk lands — never inside the jitted step
        self.tracer = tracer
        self.vclock = vclock
        self.replica_id = int(replica_id)
        self.jobs: deque[PrefillJob] = deque()
        # observability: the tuner's chunk-size choice is judged on these
        self.chunks_run = 0
        self.tokens_ingested = 0
        self.compiled_buckets: set[int] = set()
        self.queue_peak = 0

    # -- state ---------------------------------------------------------------
    @property
    def has_jobs(self) -> bool:
        return bool(self.jobs)

    @property
    def pending_tokens(self) -> int:
        """Prompt tokens still owed to the pool — the ingest backlog a
        router's least-loaded policy charges against free capacity."""
        return sum(j.remaining for j in self.jobs)

    @property
    def prefix_cache(self):
        """The pool's attached shared-prefix cache (None when disabled)."""
        return getattr(self.pool, "prefix_cache", None)

    # -- lifecycle -----------------------------------------------------------
    def submit(self, entry, st, prompt: np.ndarray) -> PrefillJob:
        """Reserve the slot and the prompt's pages, queue the job.

        A prefix-cache hit adopts the shared page run first (pointer
        copies + a reference per page) and reserves pages only for the
        cold suffix; the job's cursor starts past the cached tokens, so
        its chunks cover the suffix alone."""
        prompt = np.asarray(prompt, np.int32)
        slot = self.pool.alloc()
        cached = 0
        if self.prefix_cache is not None:
            cached = self.prefix_cache.attach(
                slot, prompt, getattr(entry, "probe_hit", None))
        try:
            self.pool.reserve_prefix(slot, len(prompt))
        except Exception:
            self.pool.free(slot)   # also drops the shared run's references
            raise
        if cached:
            self.pool.set_length(slot, cached)
        job = PrefillJob(entry=entry, st=st, prompt=prompt, slot=slot,
                         done=cached)
        if self.tracer is not None and self.prefix_cache is not None:
            # zero-width span: the probe + pointer-copy adoption happens
            # at a single vstep, but hit/miss and tokens reused matter
            t = self.vclock.t if self.vclock is not None else 0
            self.tracer.span("cache_attach", st.rid, t, t,
                             replica=self.replica_id, slot=slot,
                             hit=bool(cached), tokens_cached=int(cached))
        self.jobs.append(job)
        self.queue_peak = max(self.queue_peak, len(self.jobs))
        return job

    def evict_newest(self):
        """Drop the youngest queued job (deterministic page-pressure
        relief: it has ingested the least), free its slot and pages, and
        return the job for the scheduler to re-queue."""
        job = self.jobs.pop()
        self.pool.free(job.slot)
        return job

    # -- chunk execution -----------------------------------------------------
    def _run_chunk(self, job: PrefillJob):
        """Ingest one chunk of `job`; returns the chunk's last-position
        logits when it was the final chunk, else None."""
        c = min(self.chunk_tokens or job.remaining, job.remaining)
        bucket = bucket_len(c)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :c] = job.prompt[job.done:job.done + c]
        # static KV read-back bound: the chunk attends its own bucketed
        # prefix, not the pool's max_len (bound buckets x chunk buckets
        # is the whole chunk jit cache)
        bound = min(bucket_len(job.done + c), self.pool.kv_bound_cap)
        extras = self.pool.chunk_extras(job.slot)
        logits, new_cache = self.chunk_step(
            self.pool.cache, jnp.asarray(toks), jnp.int32(job.slot),
            jnp.int32(job.done), jnp.int32(c), bound, *extras)
        self.pool.adopt(new_cache)
        if self.tracer is not None:
            # each chunk is one vclock unit; tick()/drain() advance the
            # clock right after this returns, so the span is (t, t+1)
            t = self.vclock.t if self.vclock is not None else 0
            self.tracer.span("prefill_chunk", job.st.rid, t, t + 1,
                             replica=self.replica_id, slot=job.slot,
                             index=job.chunks, tokens=c, bucket=bucket,
                             offset=job.done)
        job.chunks += 1
        job.done += c
        self.chunks_run += 1
        self.tokens_ingested += c
        # the jit cache key is the (chunk bucket, kv bound) PAIR — bound
        # is a static argument, so each pair is its own compile
        self.compiled_buckets.add((bucket, bound))
        # keep the host length mirror current per chunk: mid-ingest KV is
        # resident HBM and must show up in peak_resident_tokens (lengths
        # of non-active slots are never consulted for decode growth)
        self.pool.set_length(job.slot, job.done)
        if job.done == len(job.prompt):
            if self.prefix_cache is not None:
                # the run is fully written and read-only from here on:
                # register its prompt-covered pages for future sharers
                self.prefix_cache.insert(job.prompt, job.slot)
            return logits
        return None

    def tick(self, vclock=None):
        """Ingest up to ``chunk_tokens`` prompt tokens (head-of-line).

        Returns ``(finished, invocations)`` where finished is a list of
        ``(job, logits)`` for jobs whose final chunk just landed.  Each
        chunk is one jitted invocation and advances ``vclock`` by one —
        the deterministic unit the TTFT proxy is measured in.
        """
        budget = self.chunk_tokens or (self.jobs[0].remaining
                                       if self.jobs else 0)
        finished, invocations = [], 0
        while self.jobs and budget >= min(
                self.chunk_tokens or self.jobs[0].remaining,
                self.jobs[0].remaining):
            job = self.jobs[0]
            take = min(self.chunk_tokens or job.remaining, job.remaining)
            logits = self._run_chunk(job)
            invocations += 1
            budget -= take
            if vclock is not None:
                vclock.advance(1)
            if logits is not None:
                self.jobs.popleft()
                finished.append((job, logits))
        return finished, invocations

    def drain(self, job: PrefillJob):
        """Blocking path: run every remaining chunk of `job` now (it must
        be the queue tail just submitted); returns the final logits."""
        assert self.jobs and self.jobs[-1] is job
        self.jobs.pop()
        logits = None
        while logits is None:
            logits = self._run_chunk(job)
        return logits
