"""Draft-then-verify speculative decoding: the drafter side.

The serving decode loop emits one token per jitted step per slot, so
tokens/step — the bench's gated metric — is hard-capped by batch
occupancy.  Speculative decoding breaks the cap: a cheap **drafter**
proposes k tokens per slot from the slot's own history, one VERIFY step
(``training/steps.build_verify_step_slots[_paged]``) scores all k+1
positions against pool KV at once, and the scheduler accepts the longest
prefix of drafts that matches what the per-``(rid, step)`` sampler would
have drawn sequentially — so speculative streams are **bit-identical** to
non-speculative ones, and a verify step that accepts a tokens advances
the request by a+1 for the price of one jitted call.

The drafter here is deliberately model-free: ``NGramDrafter`` predicts by
longest-suffix n-gram lookup over the request's prompt + generated tokens
(prompt-copy falls out of the same rule, since the prompt is part of the
history).  Any object with ``draft(history, k) -> list[int]`` plugs into
``Scheduler(drafter=...)`` / ``ServeEngine(drafter=...)`` — the hook a
small ``configs/`` model drops into later (its drafter would run its own
tiny decode loop over ``history`` and return k greedy tokens; everything
downstream — verify, acceptance, page charging — is drafter-agnostic,
because a *wrong* draft costs only its rejected KV write, which the next
step overwrites before any causal mask admits it).
"""

from __future__ import annotations


class Drafter:
    """Protocol: propose k tokens likely to follow ``history``.

    ``history`` is the request's full token prefix — prompt plus every
    emitted token, including the pending one not yet in KV — and the
    return value is exactly ``k`` proposed continuation tokens.  Drafts
    never affect correctness (a mismatch just ends the accepted burst),
    only the accepted-tokens/verify-step ratio.
    """

    def draft(self, history: list[int], k: int) -> list[int]:
        raise NotImplementedError


class NGramDrafter(Drafter):
    """Longest-suffix n-gram drafter over the request's own history.

    For each proposed token: take the history's last-n suffix for
    n = max_n..1, find that n-gram's most recent earlier occurrence, and
    propose the token that followed it; if no suffix recurs, repeat the
    last token.  The proposal is appended to a working copy of the
    history, so one call drafts a k-token continuation, not k independent
    guesses.  On repetitive streams (the bench's small-vocab trace, or
    any prompt-echoing workload) the longest-suffix rule locks onto the
    cycle and whole bursts verify.
    """

    def __init__(self, max_n: int = 3):
        if max_n < 1:
            raise ValueError(f"max_n {max_n} < 1")
        self.max_n = max_n
        # observability: how often the suffix rule actually fires vs the
        # repeat-last fallback — a drafter whose fallback dominates is
        # wasting verify steps, which is the tuner's cue to turn spec off
        self.calls = 0                # draft() invocations
        self.drafted_tokens = 0       # k summed over calls
        self.ngram_hits = 0           # proposals from a recurring suffix
        self.fallbacks = 0            # proposals from repeat-last

    def _next(self, hist: list[int]) -> int:
        L = len(hist)
        for n in range(min(self.max_n, L - 1), 0, -1):
            suffix = hist[L - n:]
            # most recent earlier occurrence of the suffix n-gram
            for p in range(L - n - 1, -1, -1):
                if hist[p:p + n] == suffix:
                    self.ngram_hits += 1
                    return hist[p + n]
        self.fallbacks += 1
        return hist[-1]

    def draft(self, history: list[int], k: int) -> list[int]:
        self.calls += 1
        self.drafted_tokens += k
        hist = [int(t) for t in history]
        if not hist:
            return [0] * k
        out = []
        for _ in range(k):
            t = self._next(hist)
            out.append(t)
            hist.append(t)
        return out
