"""Three-term roofline from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)        [s]
    memory term     = HLO_bytes / (chips * HBM_bw)             [s]
    collective term = wire_bytes / (links_used * link_bw)      [s]

HLO_FLOPs / HLO_bytes / wire_bytes come from analysis/hlo.py and are
per-device (SPMD module), so the chip division is already implicit —
we use them directly against per-chip peak numbers.

links_used: a ring reduction over one mesh axis of the 2-D ICI torus
drives 2 links (both ring directions) concurrently; we model
collective_time = wire_bytes_per_device / (2 x 50 GB/s) and flag the
assumption in the report.
"""

from __future__ import annotations

import dataclasses

from repro.analysis.hlo import HloCost

# TPU v5e (assignment constants)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_LINK_BW = 50e9           # bytes/s / link
LINKS_USED = 2               # bidirectional ring over one torus axis


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hbm_bytes: float
    wire_bytes: float
    model_flops: float
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0     # MODEL_FLOPS / (HLO_FLOPs * chips)
    roofline_fraction: float = 0.0  # t_compute / t_dominant
    mfu_bound: float = 0.0        # model_flops/chips/peak / t_dominant
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    memory_per_chip: dict = dataclasses.field(default_factory=dict)
    notes: list = dataclasses.field(default_factory=list)

    def finalize(self):
        self.t_compute = self.hlo_flops / PEAK_FLOPS
        self.t_memory = self.hbm_bytes / HBM_BW
        self.t_collective = self.wire_bytes / (LINKS_USED * ICI_LINK_BW)
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        t_dom = max(terms.values())
        self.roofline_fraction = self.t_compute / t_dom if t_dom else 0.0
        total_hlo = self.hlo_flops * self.chips
        self.useful_ratio = self.model_flops / total_hlo if total_hlo else 0.0
        self.mfu_bound = (self.model_flops / self.chips / PEAK_FLOPS) / t_dom \
            if t_dom else 0.0
        return self

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
            "model_flops": self.model_flops,
            "hlo_flops_per_chip": self.hlo_flops,
            "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu_bound,
        }


def from_cost(cost: HloCost, *, arch: str, shape: str, mesh: str,
              chips: int, model_flops: float,
              memory_per_chip: dict | None = None) -> Roofline:
    r = Roofline(arch=arch, shape=shape, mesh=mesh, chips=chips,
                 hlo_flops=cost.flops, hbm_bytes=cost.hbm_bytes,
                 wire_bytes=cost.wire_bytes, model_flops=model_flops,
                 collective_breakdown=cost.collective_breakdown,
                 memory_per_chip=memory_per_chip or {})
    return r.finalize()
