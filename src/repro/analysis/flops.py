"""Analytic MODEL_FLOPS (the roofline's 'useful compute' reference).

Conventions (documented in EXPERIMENTS.md):
  train   : 6 * N_active * tokens   (fwd 2x + bwd 4x)  + attention term
  prefill : 2 * N_active * tokens                      + attention term
  decode  : 2 * N_active * new_tokens                  + attention term
Attention (per layer, fwd): 4*b*s*ctx*H*dh (qk + av); causal halves the
train/prefill term; decode uses ctx = cache length.  Train multiplies the
fwd attention term by 3 (bwd is 2x fwd).  SSM terms are linear in s and
derived from the chunkwise algorithm's einsums.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.tuning import active_param_count


def _attn_flops(cfg: ModelConfig, b: int, s: int, ctx: int,
                causal: bool) -> float:
    layers = cfg.num_layers + cfg.num_encoder_layers
    if cfg.family == "ssm_xlstm":
        return 0.0  # handled by _ssm_flops
    if cfg.family == "hybrid_mamba":
        layers = max(cfg.num_layers // max(cfg.shared_attn_period, 1), 1)
        if cfg.window and s > cfg.window:
            ctx = cfg.window
    f = 4.0 * b * s * ctx * cfg.num_heads * cfg.head_dim * layers
    if causal and s == ctx:
        f *= 0.5
    if cfg.family == "encdec":  # + cross attention in the decoder
        f += 4.0 * b * s * ctx * cfg.num_heads * cfg.head_dim * cfg.num_layers
    return f


def _ssm_flops(cfg: ModelConfig, b: int, s: int) -> float:
    """Linear-scan terms (mLSTM / mamba2), fwd, per the chunkwise einsums."""
    if cfg.family == "ssm_xlstm":
        di = cfg.ssm_expand * cfg.d_model
        h = cfg.ssm_heads
        dk, dv = cfg.ssm_head_dim, di // h
        Q = cfg.ssm_chunk
        # intra-chunk: qk (Q*dk) + weighted-v (Q*dv); inter: state read/write dk*dv
        per_tok = 2 * h * (Q * dk + Q * dv + 2 * dk * dv)
        return b * s * per_tok * cfg.num_layers
    if cfg.family == "hybrid_mamba":
        di = cfg.ssm_expand * cfg.d_model
        h, n = cfg.ssm_heads, cfg.ssm_state
        p = di // h
        Q = cfg.ssm_chunk
        per_tok = 2 * (Q * n + Q * h * p + 2 * h * p * n)  # CB^T, L*x, state io
        return b * s * per_tok * cfg.num_layers
    return 0.0


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = b * s
        dense = 6.0 * n_active * tokens
        attn = 3.0 * _attn_flops(cfg, b, s, s, causal=True)
        ssm = 3.0 * _ssm_flops(cfg, b, s)
    elif shape.kind == "prefill":
        tokens = b * s
        dense = 2.0 * n_active * tokens
        attn = _attn_flops(cfg, b, s, s, causal=True)
        ssm = _ssm_flops(cfg, b, s)
    else:  # decode: one token against ctx = s
        dense = 2.0 * n_active * b
        attn = _attn_flops(cfg, b, 1, s, causal=False)
        ssm = _ssm_flops(cfg, b, 1)
    return {"dense": dense, "attention": attn, "ssm": ssm,
            "total": dense + attn + ssm, "n_active": n_active}
