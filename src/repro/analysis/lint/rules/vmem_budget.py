"""Rule ``vmem-budget``: Pallas kernels must fit the target's VMEM.

For every module-level function containing a ``pl.pallas_call``, the
rule statically sums the VMEM-resident bytes its block shapes imply:

* each lexical ``pl.BlockSpec((dims...), ...)`` site contributes
  ``prod(dims) * 4`` bytes (input dtypes are unknown statically — f32 is
  the conservative assumption), doubled for the pipeline's
  double-buffering; a ``[BlockSpec(...)] * N`` list-multiply counts N
  copies;
* each ``pltpu.VMEM((dims...), dtype)`` scratch shape contributes
  ``prod(dims) * sizeof(dtype)`` once (scratch is not double-buffered).

Dimensions resolve through, in order: constant-propagated local
assignments (``bx = min(block_x, n)`` resolves because ``min`` of the
resolvable subset is a sound upper bound), the function's own integer
keyword defaults (``block_q: int = 128``), and the declared bounds table
(``[vmem.bounds]`` in ``allow.toml``) for free model dimensions like
``dh`` or ``page_size``.  A dimension that resolves through none of
them is a *dynamically-shaped block* — an error, because an unbounded
block is exactly how a kernel silently outgrows VMEM when a config
scales.

The budget comes from ``core/tuning.vmem_budget_bytes`` over the
``[vmem] target`` in ``allow.toml`` (falling back to the same fraction
of ``TargetSpec.vmem_bytes`` when JAX is unavailable — kept in sync by
test).  Every kernel gets an ``info`` finding reporting its estimate;
crossing the budget is an ``error``.

The estimate is lexical: a BlockSpec built in a helper and passed N
times through runtime list construction counts once.  It is a floor,
not an exact occupancy — the point is catching order-of-magnitude
inflation at review time, not replacing the compiler.
"""

from __future__ import annotations

import ast
import math

from repro.analysis.lint.core import Finding, Source, dotted

# fallback when core/tuning is unimportable (no JAX in the venv);
# test_lint asserts this equals tuning.VMEM_BUDGET_FRACTION
VMEM_BUDGET_FRACTION = 0.9

DTYPE_BYTES = {"float32": 4, "f32": 4, "int32": 4, "uint32": 4,
               "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
               "int8": 1, "uint8": 1, "bool_": 1, "float64": 8,
               "int64": 8}

HINT = ("shrink the block shape, add the free dimension to "
        "[vmem.bounds] in allow.toml, or raise the target budget "
        "knowingly — VMEM overflows surface as compile failures on "
        "real TPUs only")


def _budget_bytes(target_name: str) -> float:
    from repro.core.target import get_target
    t = get_target(target_name)
    try:
        from repro.core.tuning import vmem_budget_bytes
        return vmem_budget_bytes(t)
    except Exception:
        return VMEM_BUDGET_FRACTION * t.vmem_bytes


class _Unresolved(Exception):
    def __init__(self, why: str):
        super().__init__(why)
        self.why = why


def _eval_dim(node: ast.AST, env: dict) -> int:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in env:
            return env[node.id]
        raise _Unresolved(f"unbounded dimension `{node.id}`")
    if isinstance(node, ast.BinOp):
        ops = {ast.Add: lambda a, b: a + b, ast.Sub: lambda a, b: a - b,
               ast.Mult: lambda a, b: a * b,
               ast.FloorDiv: lambda a, b: a // max(b, 1),
               ast.Pow: lambda a, b: a ** b}
        fn = ops.get(type(node.op))
        if fn is not None:
            return fn(_eval_dim(node.left, env), _eval_dim(node.right, env))
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) and \
            node.func.id in ("min", "max") and node.args:
        vals, missing = [], 0
        for a in node.args:
            try:
                vals.append(_eval_dim(a, env))
            except _Unresolved:
                missing += 1
        if node.func.id == "min" and vals:
            return min(vals)       # min over a subset is an upper bound
        if node.func.id == "max" and vals and not missing:
            return max(vals)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_eval_dim(node.operand, env)
    raise _Unresolved(f"dimension `{ast.unparse(node)}` is not statically "
                      f"evaluable")


def _fn_env(fn, bounds: dict) -> dict:
    env = dict(bounds)
    a = fn.args
    pos = a.posonlyargs + a.args
    for arg, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if isinstance(default, ast.Constant) and \
                isinstance(default.value, int):
            env[arg.arg] = default.value
    for arg, default in zip(a.kwonlyargs, a.kw_defaults):
        if isinstance(default, ast.Constant) and \
                isinstance(default.value, int):
            env[arg.arg] = default.value
    # one forward constant-propagation pass over simple top-level
    # assigns.  Because the estimate only needs an *upper bound*, a name
    # is propagatable when it has exactly one plain assignment and every
    # other store is a shrinking AugAssign (`br -= 1`, `bk //= 2`):
    # `rows = 1` followed by `rows *= s` in a loop must not freeze rows
    # at 1, but `br = min(block_rows, rows)` stays a bound through the
    # `while rows % br: br -= 1` alignment loop.
    SHRINKING = (ast.Sub, ast.FloorDiv, ast.RShift)
    plain: dict[str, int] = {}        # Name stores outside AugAssign
    growing: set[str] = set()
    aug_targets: set[ast.Name] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.AugAssign) and \
                isinstance(node.target, ast.Name):
            aug_targets.add(node.target)
            if not isinstance(node.op, SHRINKING):
                growing.add(node.target.id)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store) \
                and node not in aug_targets:
            plain[node.id] = plain.get(node.id, 0) + 1
    for st in fn.body:
        if isinstance(st, ast.Assign) and len(st.targets) == 1 and \
                isinstance(st.targets[0], ast.Name) and \
                plain.get(st.targets[0].id) == 1 and \
                st.targets[0].id not in growing:
            try:
                env[st.targets[0].id] = _eval_dim(st.value, env)
            except _Unresolved:
                pass
    return env


def _dtype_bytes(node: ast.AST | None) -> int:
    if node is None:
        return 4
    d = dotted(node)
    if d:
        leaf = d.split(".")[-1]
        return DTYPE_BYTES.get(leaf, 4)
    return 4


class VmemBudgetRule:
    id = "vmem-budget"

    def check(self, src: Source, cfg) -> list[Finding]:
        has_pallas = any(
            isinstance(n, ast.Call) and
            (dotted(n.func) or "").split(".")[-1] == "pallas_call"
            for n in ast.walk(src.tree))
        if not has_pallas:
            return []
        try:
            budget = _budget_bytes(cfg.vmem_target)
        except KeyError as e:
            return [Finding(self.id, src.rel, 1, 0,
                            f"cannot resolve VMEM budget: {e}", hint=HINT)]
        findings: list[Finding] = []
        for node in src.tree.body:
            if isinstance(node, ast.FunctionDef) and any(
                    isinstance(c, ast.Call) and
                    (dotted(c.func) or "").split(".")[-1] == "pallas_call"
                    for c in ast.walk(node)):
                self._check_kernel_fn(node, src, cfg, budget, findings)
        return findings

    def _check_kernel_fn(self, fn, src: Source, cfg, budget: float,
                         findings: list[Finding]) -> None:
        env = _fn_env(fn, cfg.vmem_bounds)
        blockspec_bytes = 0.0
        scratch_bytes = 0.0
        resolved = True

        def site_bytes(call: ast.Call, shape_node, dtype_node, mult: int,
                       kind: str):
            nonlocal blockspec_bytes, scratch_bytes, resolved
            if not isinstance(shape_node, (ast.Tuple, ast.List)):
                resolved = False
                findings.append(Finding(
                    self.id, src.rel, call.lineno, call.col_offset,
                    f"`{fn.name}`: {kind} shape is not a literal tuple — "
                    f"dynamically-shaped blocks defeat the static VMEM "
                    f"check", hint=HINT))
                return
            elems = 1
            for dim in shape_node.elts:
                try:
                    elems *= max(_eval_dim(dim, env), 1)
                except _Unresolved as e:
                    resolved = False
                    findings.append(Finding(
                        self.id, src.rel, dim.lineno, dim.col_offset,
                        f"`{fn.name}`: {kind} has a dynamic block "
                        f"dimension — {e.why}", hint=HINT))
                    return
            nbytes = elems * _dtype_bytes(dtype_node) * mult
            if kind == "scratch":
                scratch_bytes += nbytes
            else:
                blockspec_bytes += nbytes

        def visit(node, mult: int):
            if isinstance(node, ast.BinOp) and \
                    isinstance(node.op, ast.Mult):
                # [BlockSpec(...)] * N — count N copies of each site
                for seq, count in ((node.left, node.right),
                                   (node.right, node.left)):
                    if isinstance(seq, (ast.List, ast.Tuple)) and \
                            isinstance(count, ast.Constant) and \
                            isinstance(count.value, int):
                        visit(seq, mult * count.value)
                        visit(count, mult)
                        return
            if isinstance(node, ast.Call):
                leaf = (dotted(node.func) or "").split(".")[-1]
                if leaf == "BlockSpec":
                    shape = node.args[0] if node.args else None
                    for kw in node.keywords:
                        if kw.arg == "block_shape":
                            shape = kw.value
                    if shape is not None:
                        site_bytes(node, shape, None, mult, "BlockSpec")
                elif leaf == "VMEM":
                    shape = node.args[0] if node.args else None
                    dtype = node.args[1] if len(node.args) > 1 else None
                    site_bytes(node, shape, dtype, mult, "scratch")
            for child in ast.iter_child_nodes(node):
                visit(child, mult)

        visit(fn, 1)
        # in/out blocks are double-buffered by the pallas pipeline
        estimate = 2 * blockspec_bytes + scratch_bytes
        kib = estimate / 1024
        findings.append(Finding(
            self.id, src.rel, fn.lineno, fn.col_offset,
            f"`{fn.name}`: estimated VMEM ~{kib:,.0f} KiB "
            f"(2x{blockspec_bytes / 1024:,.0f} KiB blocks + "
            f"{scratch_bytes / 1024:,.0f} KiB scratch) of "
            f"{budget / 2**20:,.0f} MiB budget on {cfg.vmem_target}"
            + ("" if resolved else " — LOWER BOUND, dynamic dims above"),
            severity="info"))
        if estimate > budget:
            over = estimate / max(budget, 1)
            findings.append(Finding(
                self.id, src.rel, fn.lineno, fn.col_offset,
                f"`{fn.name}`: estimated VMEM {estimate / 2**20:,.1f} MiB "
                f"exceeds the {budget / 2**20:,.0f} MiB budget on "
                f"{cfg.vmem_target} ({over:.1f}x)", hint=HINT))
        if math.isnan(estimate):   # defensive; never expected
            findings.append(Finding(
                self.id, src.rel, fn.lineno, fn.col_offset,
                f"`{fn.name}`: VMEM estimate is NaN", hint=HINT))
