"""Rule ``keyed-rng``: serving-side RNG must be (rid, step)-keyed.

Sampling determinism across admission order, slot assignment, preemption
and speculation all rest on one discipline (serving/sampling.py): the
key for any draw is ``fold_in(fold_in(base, rid), step)``.  A literal
``PRNGKey(0)``, a base key drawn from directly, or one key reused for
two draws silently breaks stream identity in ways the equivalence tests
only catch when the colliding schedule happens to be exercised.

Scope: files under ``serving/``.  Flags, per function:

* ``jax.random.PRNGKey(<literal>)`` — a hard-coded seed;
* a draw (``categorical``, ``uniform``, ...) whose key argument is
  neither a ``fold_in``-derived expression/name nor a function
  parameter (a parameter is the *caller's* obligation — the helper
  pattern make_sampler uses);
* a name assigned from ``PRNGKey(...)`` passed to a draw directly
  (base keys exist to be folded, not drawn from);
* the same key name feeding two or more draws (unkeyed reuse).
"""

from __future__ import annotations

import ast

from repro.analysis.lint.core import Finding, Source, dotted

DRAWS = {"categorical", "uniform", "normal", "bernoulli", "gumbel",
         "choice", "randint", "permutation", "exponential",
         "truncated_normal", "dirichlet", "beta", "gamma", "poisson",
         "laplace", "shuffle"}

HINT = ("derive keys as fold_in(fold_in(base, rid), step) — see "
        "serving/sampling.py; a fresh fold per draw keeps streams "
        "deterministic under preemption and speculation")


def _is_random_fn(call: ast.Call, name: str) -> bool:
    d = dotted(call.func)
    return bool(d) and (d == f"jax.random.{name}" or
                        d == f"random.{name}" or d == name)


def _contains_fold_in(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            d = dotted(sub.func)
            if d and d.split(".")[-1] == "fold_in":
                return True
    return False


class KeyedRngRule:
    id = "keyed-rng"

    def check(self, src: Source, cfg) -> list[Finding]:
        if "/serving/" not in "/" + src.rel.replace("\\", "/"):
            return []
        findings: list[Finding] = []
        for node in ast.walk(src.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_fn(node, src, findings)
            elif isinstance(node, ast.Call) and \
                    _is_random_fn(node, "PRNGKey") and node.args and \
                    isinstance(node.args[0], ast.Constant):
                findings.append(Finding(
                    self.id, src.rel, node.lineno, node.col_offset,
                    f"literal PRNGKey({node.args[0].value!r}) — seeds must "
                    f"be injected, never hard-coded", hint=HINT))
        return findings

    def _check_fn(self, fn, src: Source, findings: list[Finding]) -> None:
        a = fn.args
        params = {arg.arg for arg in a.posonlyargs + a.args + a.kwonlyargs}
        folded: set[str] = set()
        base_keys: set[str] = set()
        for st in ast.walk(fn):
            if isinstance(st, ast.Assign) and len(st.targets) == 1 and \
                    isinstance(st.targets[0], ast.Name):
                name = st.targets[0].id
                if _contains_fold_in(st.value):
                    folded.add(name)
                elif isinstance(st.value, ast.Call) and \
                        _is_random_fn(st.value, "PRNGKey"):
                    base_keys.add(name)
        draws_per_key: dict[str, int] = {}
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            d = dotted(call.func)
            if not d or d.split(".")[-1] not in DRAWS or \
                    not _is_random_fn(call, d.split(".")[-1]):
                continue
            if not call.args:
                continue
            key = call.args[0]
            kname = key.id if isinstance(key, ast.Name) else None
            if kname is not None:
                draws_per_key[kname] = draws_per_key.get(kname, 0) + 1
            if kname in base_keys:
                findings.append(Finding(
                    self.id, src.rel, call.lineno, call.col_offset,
                    f"base key `{kname}` drawn from directly — fold "
                    f"(rid, step) in first", hint=HINT))
            elif not (_contains_fold_in(key) or kname in folded or
                      kname in params):
                findings.append(Finding(
                    self.id, src.rel, call.lineno, call.col_offset,
                    f"draw `{d}` keyed by an expression that is not "
                    f"fold_in-derived", hint=HINT))
            elif kname is not None and draws_per_key[kname] > 1:
                findings.append(Finding(
                    self.id, src.rel, call.lineno, call.col_offset,
                    f"key `{kname}` reused for a second draw in the same "
                    f"function — every draw needs its own fold",
                    hint=HINT))
        return None
