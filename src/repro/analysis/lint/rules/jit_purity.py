"""Rule ``jit-purity``: traced functions stay pure host-side.

Functions that enter a trace — passed to ``jax.jit``, ``jax.lax.scan``
or ``pl.pallas_call``, or decorated with ``@jax.jit`` /
``@functools.partial(jax.jit, ...)`` — execute at trace time, once, not
at call time.  Host-side effects inside them (mutating captured state,
appending to lists, telemetry calls, branching on ``tracer``) silently
freeze into the jitted program or vanish after the first call; both are
bugs the equivalence tests only see when re-tracing happens to change.

Resolution walks each module's own call graph: jit/scan/pallas entry
points are found syntactically (including ``functools.partial(kernel,
...)`` operands), then same-module functions they call by name join the
traced set transitively.  Inside a traced function the rule flags:

* mutating method calls (``append``/``update``/``add``/...) whose
  receiver is a *captured* name — bound outside the traced function and
  not a module import alias;
* assignments (plain, augmented, or subscript/attribute stores) whose
  target's root name is captured — a pallas ``o_ref[...] = ...`` is
  fine because the ref is a parameter;
* ``global`` / ``nonlocal`` declarations;
* any reference to a name or attribute containing ``tracer`` —
  telemetry must never enter traced code (ROADMAP §Observability).
"""

from __future__ import annotations

import ast

from repro.analysis.lint.core import (Finding, Source, bound_names, dotted,
                                      func_defs, import_aliases, root_name)

JIT_WRAPPERS = {"jax.jit", "jit"}
SCAN_FNS = {"jax.lax.scan", "lax.scan"}
PALLAS_FNS = {"pl.pallas_call", "pallas_call", "pltpu.pallas_call"}
PARTIAL_FNS = {"functools.partial", "partial"}

MUTATORS = {"append", "extend", "insert", "add", "update", "pop",
            "popleft", "appendleft", "remove", "discard", "clear",
            "setdefault", "write"}

HINT = ("traced functions run at trace time: keep host state, tracers "
        "and python-side accumulation outside jit/scan/pallas bodies")


def _callee_name(node: ast.AST) -> str | None:
    """Function name referenced by a jit/scan/pallas operand."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        d = dotted(node.func)
        if d in PARTIAL_FNS and node.args:
            return _callee_name(node.args[0])
    return None


def _traced_roots(tree: ast.AST) -> set[str]:
    roots: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = dotted(dec)
                if d in JIT_WRAPPERS:
                    roots.add(node.name)
                elif isinstance(dec, ast.Call):
                    dd = dotted(dec.func)
                    if dd in JIT_WRAPPERS:
                        roots.add(node.name)
                    elif dd in PARTIAL_FNS and dec.args and \
                            dotted(dec.args[0]) in JIT_WRAPPERS:
                        roots.add(node.name)
        elif isinstance(node, ast.Call):
            d = dotted(node.func)
            if d in (JIT_WRAPPERS | SCAN_FNS | PALLAS_FNS) and node.args:
                name = _callee_name(node.args[0])
                if name:
                    roots.add(name)
            elif d in PALLAS_FNS:
                # pallas_call(kernel, ...) with the kernel as a keyword
                for kw in node.keywords:
                    name = _callee_name(kw.value)
                    if name:
                        roots.add(name)
    return roots


class JitPurityRule:
    id = "jit-purity"

    def check(self, src: Source, cfg) -> list[Finding]:
        defs = func_defs(src.tree)
        roots = _traced_roots(src.tree) & defs.keys()
        if not roots:
            return []
        module_aliases = import_aliases(src.tree)
        module_defs = {n.name for n in src.tree.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}
        # transitive closure over same-module calls by name
        traced, frontier = set(), list(roots)
        while frontier:
            name = frontier.pop()
            if name in traced:
                continue
            traced.add(name)
            for node in ast.walk(defs[name]):
                if isinstance(node, ast.Call):
                    callee = dotted(node.func)
                    if callee in defs and callee not in traced:
                        frontier.append(callee)
        findings: list[Finding] = []
        for name in sorted(traced):
            self._check_traced(defs[name], src, module_aliases,
                               module_defs, findings)
        return findings

    def _check_traced(self, fn, src: Source, module_aliases: set[str],
                      module_defs: set[str], findings: list[Finding]):
        local = bound_names(fn)
        ok_roots = local | module_aliases | module_defs

        def flag(node, msg):
            findings.append(Finding(
                self.id, src.rel, node.lineno, node.col_offset,
                f"traced function `{fn.name}` {msg}", hint=HINT))

        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                flag(node, f"declares {type(node).__name__.lower()} "
                           f"{', '.join(node.names)} — host-state "
                           f"mutation inside a trace")
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in MUTATORS:
                root = root_name(node.func.value)
                if root is not None and root not in ok_roots:
                    flag(node, f"mutates captured `{root}."
                               f"{node.func.attr}(...)` — the effect "
                               f"runs at trace time, not per call")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    if isinstance(t, (ast.Attribute, ast.Subscript)):
                        root = root_name(t)
                        if root is not None and root not in ok_roots:
                            flag(node, f"stores into captured `{root}` — "
                                       f"host-state mutation inside a "
                                       f"trace")
            if isinstance(node, ast.Name) and "tracer" in node.id:
                flag(node, f"references `{node.id}` — telemetry must "
                           f"stay host-side, outside traced code")
            elif isinstance(node, ast.Attribute) and "tracer" in node.attr:
                flag(node, f"references `.{node.attr}` — telemetry must "
                           f"stay host-side, outside traced code")
