"""Rule ``wall-clock``: no wall-clock escape into gated code.

Every deterministic invariant in the serving stack hangs off the
virtual-step clock; a stray ``time.time()`` / ``perf_counter`` /
``datetime.now()`` is how wall time leaks into gated metrics, checkpoint
bytes, or scheduling decisions.  The rule flags every *reference* to a
wall-clock source — calls and bare references alike, so an advisory
``clock=time.perf_counter`` default argument or a
``default_factory=time.time`` field is caught too.

Known-advisory escapes are expressed, never silent:

* code inside a function literally named ``_timed`` (the one shared
  benchmark timing idiom) is exempt;
* a ``# easeylint: allow[wall-clock]`` pragma on the line (or the line
  above) marks a single advisory site, with the justification in the
  comment;
* whole advisory files (wall-clock FOM benchmarks, build timings) live
  in ``allow.toml`` with a reason each.
"""

from __future__ import annotations

import ast

from repro.analysis.lint.core import Finding, Source, dotted

WALL_CLOCK = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}
# functions imported bare (`from time import perf_counter`)
_WALL_FROM = {("time", "time"), ("time", "time_ns"),
              ("time", "perf_counter"), ("time", "perf_counter_ns"),
              ("time", "monotonic"), ("time", "monotonic_ns")}

HINT = ("route timing through an injected clock/now= parameter (vstep "
        "clocks for anything gated); mark a genuinely advisory site with "
        "`# easeylint: allow[wall-clock]` or an allow.toml entry")

ALLOWED_FUNCS = {"_timed"}


class WallClockRule:
    id = "wall-clock"

    def check(self, src: Source, cfg) -> list[Finding]:
        findings: list[Finding] = []
        # names bound straight to wall-clock functions by `from X import Y`
        bare: set[str] = set()
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    if (node.module, alias.name) in _WALL_FROM:
                        bare.add(alias.asname or alias.name)

        def visit(node, in_allowed: bool):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                in_allowed = in_allowed or node.name in ALLOWED_FUNCS
            if not in_allowed:
                name = None
                if isinstance(node, ast.Attribute):
                    d = dotted(node)
                    if d in WALL_CLOCK:
                        name = d
                elif isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Load) and node.id in bare:
                    name = node.id
                if name is not None:
                    findings.append(Finding(
                        self.id, src.rel, node.lineno, node.col_offset,
                        f"wall-clock source `{name}` referenced — gated "
                        f"metrics and serialized artifacts must be "
                        f"wall-clock-blind", hint=HINT))
                    return  # don't re-report `time.time` inside itself
            for child in ast.iter_child_nodes(node):
                visit(child, in_allowed)

        visit(src.tree, False)
        return findings
