"""Rule registry: rule id -> visitor class.

Each rule exposes ``id`` and ``check(src, cfg) -> list[Finding]``; the
runner owns parsing, pragma and allowlist suppression, exit status.
"""

from __future__ import annotations

from repro.analysis.lint.rules.wall_clock import WallClockRule
from repro.analysis.lint.rules.jit_purity import JitPurityRule
from repro.analysis.lint.rules.telemetry_guard import TelemetryGuardRule
from repro.analysis.lint.rules.keyed_rng import KeyedRngRule
from repro.analysis.lint.rules.refcount import RefcountPairingRule
from repro.analysis.lint.rules.vmem_budget import VmemBudgetRule

ALL_RULES = {cls.id: cls for cls in (
    WallClockRule, JitPurityRule, TelemetryGuardRule, KeyedRngRule,
    RefcountPairingRule, VmemBudgetRule)}
