"""Rule ``refcount-pairing``: page acquisitions must not leak locally.

The paged pool's refcount discipline (PRs 2/5) pairs every acquisition
— ``attach`` (+1 per shared page), ``adopt_run`` (ownership move),
``reserve_prefix`` (fresh pages) — with a ``free``/``release_page`` by
the time the holding request retires.  The pairing usually spans
functions (submit acquires, the scheduler releases at finish/preempt),
so the rule checks the *local* obligation: a function that acquires
pages for a slot and lets that slot neither escape nor be released on
some exit path is leaking pages that nothing can ever free.

Dataflow, per function (linear walk with branch-copies):

* ``<pool-ish>.attach(slot, ...)`` / ``.adopt_run(slot, ...)`` /
  ``.reserve_prefix(slot, ...)`` — receiver chain mentioning ``pool``,
  ``cache`` or ``prefix`` — marks ``slot`` as *holding*;
* ``.free(slot)`` clears it; ``.release_page(...)`` clears everything
  (page-granular releases are below slot-level tracking);
* any *escape* — the slot passed to another call, returned, yielded, or
  stored into an attribute/subscript/container — transfers ownership to
  whoever sees it and clears the obligation;
* a ``return`` (or falling off the end) while a slot is still held and
  unescaped is a finding on that exit; ``raise`` paths are exempt
  (exception cleanup is the caller's preemption/evict machinery).
"""

from __future__ import annotations

import ast

from repro.analysis.lint.core import Finding, Source, dotted

ACQUIRE = {"attach", "adopt_run", "reserve_prefix"}
RELEASE_ONE = {"free"}
RELEASE_ALL = {"release_page"}

HINT = ("pair the acquisition with pool.free(slot)/release_page on "
        "this path, or hand the slot off (store/return it) so the "
        "scheduler's finish/preempt path owns the release")


def _pool_like(recv: str | None) -> bool:
    if not recv:
        return False
    low = recv.lower()
    return "pool" in low or "cache" in low or "prefix" in low


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


class RefcountPairingRule:
    id = "refcount-pairing"

    def check(self, src: Source, cfg) -> list[Finding]:
        if "/serving/" not in "/" + src.rel.replace("\\", "/"):
            return []
        findings: list[Finding] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.FunctionDef):
                self._check_fn(node, src, findings)
        return findings

    def _check_fn(self, fn, src: Source, findings: list[Finding]) -> None:
        # held: slot name -> (line, col, "pool.method") of the acquisition
        def process_stmt(st, held: dict) -> None:
            """Mutate *held* for one statement's acquire/release/escape."""
            acquire_nodes: list[ast.AST] = []
            release_names: set[str] = set()
            release_all = False
            acquired_here: list[tuple[str, ast.Call, str]] = []
            for call in ast.walk(st):
                if not isinstance(call, ast.Call) or \
                        not isinstance(call.func, ast.Attribute):
                    continue
                recv = dotted(call.func.value)
                attr = call.func.attr
                if attr in ACQUIRE and _pool_like(recv) and call.args and \
                        isinstance(call.args[0], ast.Name):
                    acquire_nodes.append(call)
                    acquired_here.append(
                        (call.args[0].id, call, f"{recv}.{attr}"))
                elif attr in RELEASE_ONE and call.args and \
                        isinstance(call.args[0], ast.Name):
                    release_names.add(call.args[0].id)
                    acquire_nodes.append(call)
                elif attr in RELEASE_ALL:
                    release_all = True
                    acquire_nodes.append(call)
            # escapes: held names loaded anywhere in the statement outside
            # the acquire/release calls themselves
            consumed: set[int] = set()
            for c in acquire_nodes:
                consumed.update(id(n) for n in ast.walk(c))
            escaped = {n.id for n in ast.walk(st)
                       if isinstance(n, ast.Name) and
                       isinstance(n.ctx, ast.Load) and
                       n.id in held and id(n) not in consumed}
            for name in escaped:
                held.pop(name, None)
            if release_all:
                held.clear()
            for name in release_names:
                held.pop(name, None)
            for name, call, via in acquired_here:
                held[name] = (call.lineno, call.col_offset, via)

        def leak(held: dict, line: int) -> None:
            for name, (ln, col, via) in sorted(held.items()):
                findings.append(Finding(
                    self.id, src.rel, ln, col,
                    f"`{fn.name}` acquires pages for `{name}` via "
                    f"`{via}` but the exit at line {line} neither "
                    f"releases nor hands it off", hint=HINT))

        def walk(stmts, held: dict):
            """Returns the fall-through state, or None if the block
            exits on every path."""
            for st in stmts:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                    continue                 # analyzed on their own
                if isinstance(st, ast.Return):
                    process_stmt(st, held)   # returning the slot = escape
                    leak(held, st.lineno)
                    return None
                if isinstance(st, ast.Raise):
                    return None              # exception paths exempt
                if isinstance(st, (ast.Break, ast.Continue)):
                    return held
                if isinstance(st, ast.If):
                    process_stmt(st.test, held)
                    h1 = walk(st.body, dict(held))
                    h2 = walk(st.orelse, dict(held))
                    if h1 is None and h2 is None:
                        return None
                    merged: dict = {}
                    for h in (h1, h2):
                        if h is not None:
                            merged.update(h)
                    held.clear()
                    held.update(merged)
                    continue
                if isinstance(st, (ast.For, ast.While, ast.AsyncFor)):
                    cond = getattr(st, "iter", None) or \
                        getattr(st, "test", None)
                    if cond is not None:
                        process_stmt(cond, held)
                    h1 = walk(st.body, dict(held))
                    if h1 is not None:
                        held.update(h1)
                    h2 = walk(st.orelse, dict(held))
                    if h2 is not None:
                        held.update(h2)
                    continue
                if isinstance(st, (ast.With, ast.AsyncWith)):
                    for item in st.items:
                        process_stmt(item.context_expr, held)
                    h = walk(st.body, held)
                    if h is None:
                        return None
                    continue
                if isinstance(st, ast.Try):
                    h = walk(st.body, held)
                    for hd in st.handlers:
                        walk(hd.body, dict(held))
                    if h is not None and st.orelse:
                        h = walk(st.orelse, h)
                    if st.finalbody:
                        h = walk(st.finalbody, h if h is not None else held)
                    if h is None:
                        return None
                    held.clear()
                    held.update(h)
                    continue
                process_stmt(st, held)
            return held

        final = walk(fn.body, {})
        if final:
            last = fn.body[-1]
            leak(final, getattr(last, "end_lineno", last.lineno))
