"""Rule ``telemetry-guard``: tracer call sites must be None-guarded.

The telemetry contract (ROADMAP §Observability) is that every
instrumentation site is an ``if tracer is not None`` guard — pure
host-side bookkeeping that cannot move token streams and costs one
attribute load when tracing is off.  A bare ``tracer.begin(...)`` (or
``.emit(...)``) crashes every telemetry-off run the moment the code path
executes, which is exactly the drift this rule catches at review time.

A call fires when the receiver chain ends in ``tracer`` (``tracer.x()``,
``self.tracer.x()``) or the method is ``emit`` and the call is not
dominated by a None-check of the *same* receiver expression.  Recognized
guards, per function:

* ``if X is not None: ...`` (the repo idiom) — body is guarded;
* ``if X is None: return/raise/continue/break`` — the rest of the block;
* ``assert X is not None`` — the rest of the block;
* ``X is not None and X.begin(...)`` / plain-truthiness ``if X:`` —
  expression-level conjunction and truthiness both count.

``serving/telemetry.py`` itself (where the tracer is the required
subject, not an optional hook) is allowlisted in ``allow.toml``.
"""

from __future__ import annotations

import ast

from repro.analysis.lint.core import Finding, Source, dotted

HINT = ("wrap the site in `if <tracer> is not None:` — instrumentation "
        "must be skippable so telemetry-off runs never touch it")


def _guard_terms(test: ast.AST) -> tuple[set[str], set[str]]:
    """(proven-not-None when true, proven-not-None when false)."""
    if isinstance(test, ast.Compare) and len(test.ops) == 1 and \
            isinstance(test.comparators[0], ast.Constant) and \
            test.comparators[0].value is None:
        d = dotted(test.left)
        if d:
            if isinstance(test.ops[0], ast.IsNot):
                return {d}, set()
            if isinstance(test.ops[0], ast.Is):
                return set(), {d}
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        pos: set[str] = set()
        for v in test.values:
            p, _ = _guard_terms(v)
            pos |= p
        return pos, set()
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        p, n = _guard_terms(test.operand)
        return n, p
    d = dotted(test)
    if d:                       # plain truthiness: `if self.tracer:`
        return {d}, set()
    return set(), set()


def _block_exits(stmts) -> bool:
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


class TelemetryGuardRule:
    id = "telemetry-guard"

    def check(self, src: Source, cfg) -> list[Finding]:
        findings: list[Finding] = []

        def is_tracer_call(call: ast.Call) -> str | None:
            """Receiver dotted string when the call needs a guard."""
            if not isinstance(call.func, ast.Attribute):
                return None
            recv = dotted(call.func.value)
            if recv and (recv == "tracer" or recv.endswith(".tracer")):
                return recv
            if call.func.attr == "emit" and recv:
                return recv
            return None

        def scan_expr(node: ast.AST, guarded: frozenset):
            if isinstance(node, ast.BoolOp) and isinstance(node.op, ast.And):
                g = set(guarded)
                for v in node.values:
                    scan_expr(v, frozenset(g))
                    p, _ = _guard_terms(v)
                    g |= p
                return
            if isinstance(node, ast.IfExp):
                scan_expr(node.test, guarded)
                p, n = _guard_terms(node.test)
                scan_expr(node.body, guarded | p)
                scan_expr(node.orelse, guarded | n)
                return
            if isinstance(node, ast.Call):
                recv = is_tracer_call(node)
                if recv is not None and recv not in guarded:
                    findings.append(Finding(
                        self.id, src.rel, node.lineno, node.col_offset,
                        f"`{recv}.{node.func.attr}(...)` is not dominated "
                        f"by an `is not None` check of `{recv}`",
                        hint=HINT))
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                    continue
                scan_expr(child, guarded)

        def walk_block(stmts, guarded: frozenset):
            for st in stmts:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # a nested def runs later — its body cannot inherit
                    # the lexical guard (the closure may outlive it)
                    walk_block(st.body, frozenset())
                elif isinstance(st, ast.ClassDef):
                    walk_block(st.body, guarded)
                elif isinstance(st, ast.If):
                    scan_expr(st.test, guarded)
                    pos, neg = _guard_terms(st.test)
                    walk_block(st.body, guarded | pos)
                    walk_block(st.orelse, guarded | neg)
                    if not st.orelse and neg and _block_exits(st.body):
                        guarded = guarded | neg
                elif isinstance(st, ast.Assert):
                    scan_expr(st.test, guarded)
                    pos, _ = _guard_terms(st.test)
                    guarded = guarded | pos
                elif isinstance(st, (ast.While, ast.For, ast.AsyncFor)):
                    for field in ("test", "iter", "target"):
                        sub = getattr(st, field, None)
                        if sub is not None:
                            scan_expr(sub, guarded)
                    walk_block(st.body, guarded)
                    walk_block(st.orelse, guarded)
                elif isinstance(st, (ast.With, ast.AsyncWith)):
                    for item in st.items:
                        scan_expr(item.context_expr, guarded)
                    walk_block(st.body, guarded)
                elif isinstance(st, ast.Try):
                    walk_block(st.body, guarded)
                    for h in st.handlers:
                        walk_block(h.body, guarded)
                    walk_block(st.orelse, guarded)
                    walk_block(st.finalbody, guarded)
                else:
                    scan_expr(st, guarded)

        walk_block(src.tree.body, frozenset())
        return findings
