"""CLI: ``python -m repro.analysis.lint [paths...] --format {text,json}``.

Exit status 1 when any error-severity finding survives pragma/allowlist
suppression; info findings (the VMEM estimates) never fail the run.
JSON output is a stable schema (``version`` bumps on breaking change)::

    {"version": 1, "files": N, "rules": [...],
     "errors": E, "infos": I, "findings": [{rule, path, line, col,
                                            severity, message, hint}]}
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.lint.core import LintConfig, lint_paths

JSON_VERSION = 1


def main(argv=None) -> int:
    from repro.analysis.lint.rules import ALL_RULES
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="easeylint: AST invariant checker (determinism, jit "
                    "purity, telemetry guards, keyed RNG, refcount "
                    "pairing, Pallas VMEM budgets)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--allowlist", default=None,
                    help="allow.toml path (default: the bundled one)")
    ap.add_argument("--rules", default=None,
                    help=f"comma-separated subset of {sorted(ALL_RULES)}")
    args = ap.parse_args(argv)

    cfg = LintConfig.from_file(args.allowlist) if args.allowlist else None
    rule_ids = [r.strip() for r in args.rules.split(",")] \
        if args.rules else None
    roots = args.paths or ["src"]
    missing = [p for p in roots if not Path(p).exists()]
    if missing:
        print(f"easeylint: no such path(s): {missing}", file=sys.stderr)
        return 2
    findings, nfiles = lint_paths(roots, cfg=cfg, rule_ids=rule_ids)
    errors = [f for f in findings if f.severity == "error"]
    infos = [f for f in findings if f.severity == "info"]

    if args.format == "json":
        out = {"version": JSON_VERSION, "files": nfiles,
               "rules": sorted(rule_ids or ALL_RULES),
               "errors": len(errors), "infos": len(infos),
               "findings": [f.to_dict() for f in findings]}
        print(json.dumps(out, indent=2, sort_keys=False))
    else:
        for f in findings:
            print(f.render())
        tail = (f"easeylint: {nfiles} files, {len(errors)} error(s), "
                f"{len(infos)} advisory note(s)")
        print(tail if not errors else f"{tail} — FAIL")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
