"""Minimal TOML-subset parser for ``allow.toml``.

CI pins Python 3.10, which has no ``tomllib``, and the linter must not
grow a third-party dependency — so the allowlist file sticks to the
subset this parser understands and nothing more:

* ``[section]`` and dotted ``[section.sub]`` tables,
* ``[[array_of_tables]]`` entries,
* ``key = value`` pairs where value is a double-quoted string (no escape
  sequences beyond ``\\"`` and ``\\\\``), an integer, a float, or a
  boolean,
* ``#`` comments and blank lines.

Anything outside the subset raises ``TomlLiteError`` with a line number,
so a typo in the allowlist fails the lint run loudly instead of silently
allowing nothing.
"""

from __future__ import annotations

import re

_SECTION_RE = re.compile(r"^\[(\[)?\s*([A-Za-z0-9_.\-]+)\s*\]?\]\s*$")
_KV_RE = re.compile(r"^([A-Za-z0-9_\-]+)\s*=\s*(.+?)\s*$")


class TomlLiteError(ValueError):
    pass


def _parse_value(raw: str, lineno: int):
    if raw.startswith('"'):
        if not raw.endswith('"') or len(raw) < 2:
            raise TomlLiteError(f"line {lineno}: unterminated string {raw!r}")
        body = raw[1:-1]
        return body.replace('\\"', '"').replace("\\\\", "\\")
    if raw in ("true", "false"):
        return raw == "true"
    try:
        return int(raw)
    except ValueError:
        pass
    try:
        return float(raw)
    except ValueError:
        raise TomlLiteError(
            f"line {lineno}: unsupported value {raw!r} (toml_lite accepts "
            f"strings, ints, floats, booleans)") from None


def _strip_comment(line: str) -> str:
    """Drop a trailing comment, respecting double-quoted strings."""
    out, in_str = [], False
    i = 0
    while i < len(line):
        ch = line[i]
        if ch == '"' and (i == 0 or line[i - 1] != "\\"):
            in_str = not in_str
        elif ch == "#" and not in_str:
            break
        out.append(ch)
        i += 1
    return "".join(out).strip()


def loads(text: str) -> dict:
    """Parse the TOML subset into nested dicts; ``[[name]]`` becomes a
    list of dicts under ``name``."""
    root: dict = {}
    current: dict = root
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = _strip_comment(raw)
        if not line:
            continue
        m = _SECTION_RE.match(line)
        if m:
            is_array = bool(m.group(1)) and line.startswith("[[")
            parts = m.group(2).split(".")
            node = root
            for part in parts[:-1]:
                node = node.setdefault(part, {})
                if not isinstance(node, dict):
                    raise TomlLiteError(
                        f"line {lineno}: {part!r} is not a table")
            leaf = parts[-1]
            if is_array:
                arr = node.setdefault(leaf, [])
                if not isinstance(arr, list):
                    raise TomlLiteError(
                        f"line {lineno}: {leaf!r} is not an array of tables")
                current = {}
                arr.append(current)
            else:
                current = node.setdefault(leaf, {})
                if not isinstance(current, dict):
                    raise TomlLiteError(
                        f"line {lineno}: {leaf!r} redefined as a table")
            continue
        m = _KV_RE.match(line)
        if m:
            current[m.group(1)] = _parse_value(m.group(2), lineno)
            continue
        raise TomlLiteError(f"line {lineno}: cannot parse {raw!r}")
    return root
