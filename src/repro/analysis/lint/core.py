"""easeylint core: findings, pragma/allowlist suppression, the runner.

The linter enforces the repo's hand-maintained invariants statically
(vstep-only clocks, guarded telemetry, (rid, step)-keyed sampling,
refcount pairing, jit purity, Pallas VMEM budgets).  Rules are AST
visitors producing a shared :class:`Finding` type; the runner parses
each file once, fans it out to every rule, then strips findings that a
``# easeylint: allow[rule-id]`` pragma (same line or the line above) or
an ``allow.toml`` entry covers.

Severity is two-level: ``error`` findings fail the run (CI gates on
them); ``info`` findings are advisory reports (the VMEM rule's
per-kernel byte estimates) and never affect the exit status.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
import re
from pathlib import Path

from repro.analysis.lint import toml_lite

PRAGMA_RE = re.compile(r"#\s*easeylint:\s*allow\[([^\]]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str                # repo-relative, posix separators
    line: int
    col: int
    message: str
    hint: str = ""
    severity: str = "error"  # "error" | "info"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "severity": self.severity,
                "message": self.message, "hint": self.hint}

    def render(self) -> str:
        out = (f"{self.path}:{self.line}:{self.col}: "
               f"[{self.severity}] {self.rule}: {self.message}")
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


@dataclasses.dataclass
class Source:
    """One parsed file, shared by every rule."""
    rel: str                 # path as reported in findings
    text: str
    tree: ast.Module
    lines: list[str]


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    rule: str
    path: str
    reason: str


@dataclasses.dataclass
class LintConfig:
    """Parsed ``allow.toml``: site allowlist + VMEM-rule parameters."""
    allow: tuple[AllowEntry, ...] = ()
    vmem_target: str = "lrz:tpu-v5e-pod"
    vmem_bounds: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_file(cls, path: str | Path) -> "LintConfig":
        return cls.from_text(Path(path).read_text())

    @classmethod
    def from_text(cls, text: str) -> "LintConfig":
        data = toml_lite.loads(text)
        entries = []
        for i, raw in enumerate(data.get("allow", []), 1):
            rule, apath = raw.get("rule"), raw.get("path")
            reason = raw.get("reason", "")
            if not rule or not apath:
                raise ValueError(f"allow entry #{i} needs rule= and path=")
            if not reason.strip():
                # the allowlist is documentation as much as suppression —
                # an entry without a why is a finding waiting to rot
                raise ValueError(
                    f"allow entry #{i} ({rule} @ {apath}) needs a reason=")
            entries.append(AllowEntry(rule, apath, reason))
        vmem = data.get("vmem", {})
        bounds = {k: int(v) for k, v in vmem.get("bounds", {}).items()}
        return cls(allow=tuple(entries),
                   vmem_target=vmem.get("target", "lrz:tpu-v5e-pod"),
                   vmem_bounds=bounds)


def default_config() -> LintConfig:
    return LintConfig.from_file(Path(__file__).parent / "allow.toml")


# ---------------------------------------------------------------------------
# suppression

def _path_match(finding_path: str, pattern: str) -> bool:
    fp = finding_path.replace(os.sep, "/")
    pat = pattern.replace(os.sep, "/")
    if pat.endswith("/"):                       # directory prefix
        return fp.startswith(pat) or ("/" + pat) in ("/" + fp)
    if fnmatch.fnmatch(fp, pat):
        return True
    return fp == pat or fp.endswith("/" + pat)


def pragma_rules(line: str) -> set[str]:
    m = PRAGMA_RE.search(line)
    if not m:
        return set()
    return {tok.strip() for tok in m.group(1).split(",") if tok.strip()}


def suppressed(finding: Finding, src: Source, cfg: LintConfig) -> bool:
    for ln in (finding.line, finding.line - 1):
        if 1 <= ln <= len(src.lines):
            ids = pragma_rules(src.lines[ln - 1])
            if finding.rule in ids or "*" in ids:
                return True
    return any(e.rule in (finding.rule, "*") and
               _path_match(finding.path, e.path) for e in cfg.allow)


# ---------------------------------------------------------------------------
# small AST helpers shared by the rules

def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def root_name(node: ast.AST) -> str | None:
    """Leftmost Name of an attribute/subscript chain."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def import_aliases(tree: ast.AST) -> set[str]:
    """Every local name bound by an import (module aliases and members)."""
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
    return names


def func_defs(tree: ast.AST) -> dict:
    """name -> def node for every (possibly nested) function in the tree.
    On name collisions the first definition wins — good enough for the
    call-graph walk, which only needs *a* body to inspect."""
    out = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, node)
    return out


def bound_names(fn: ast.AST) -> set[str]:
    """Names bound anywhere inside *fn* (args of it and nested defs,
    assignment/for/with/comprehension targets, local defs, imports)."""
    names = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
            a = node.args
            for arg in (a.posonlyargs + a.args + a.kwonlyargs):
                names.add(arg.arg)
            if a.vararg:
                names.add(a.vararg.arg)
            if a.kwarg:
                names.add(a.kwarg.arg)
        elif isinstance(node, ast.Lambda):
            a = node.args
            for arg in (a.posonlyargs + a.args + a.kwonlyargs):
                names.add(arg.arg)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
    return names


# ---------------------------------------------------------------------------
# runner

def _rule_instances(rule_ids=None):
    from repro.analysis.lint.rules import ALL_RULES
    ids = list(ALL_RULES) if rule_ids is None else list(rule_ids)
    unknown = [r for r in ids if r not in ALL_RULES]
    if unknown:
        raise ValueError(f"unknown rule(s) {unknown}; known: "
                         f"{sorted(ALL_RULES)}")
    return [ALL_RULES[r]() for r in ids]


def lint_source(text: str, rel: str, cfg: LintConfig | None = None,
                rule_ids=None) -> list[Finding]:
    """Lint one in-memory source blob (the test fixtures' entry point)."""
    cfg = cfg if cfg is not None else LintConfig()
    rel = rel.replace(os.sep, "/")
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding("parse", rel, e.lineno or 1, e.offset or 0,
                        f"syntax error: {e.msg}")]
    src = Source(rel=rel, text=text, tree=tree, lines=text.splitlines())
    findings: list[Finding] = []
    for rule in _rule_instances(rule_ids):
        findings.extend(rule.check(src, cfg))
    # dedupe: rules that walk nested defs can visit a site twice
    findings = list(dict.fromkeys(
        f for f in findings if not suppressed(f, src, cfg)))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def iter_py_files(roots) -> list[Path]:
    files: list[Path] = []
    for root in roots:
        p = Path(root)
        if p.is_file():
            files.append(p)
            continue
        for f in sorted(p.rglob("*.py")):
            if "__pycache__" in f.parts or \
                    any(part.startswith(".") for part in f.parts[1:]):
                continue
            files.append(f)
    return files


def lint_paths(roots, cfg: LintConfig | None = None,
               rule_ids=None) -> tuple[list[Finding], int]:
    """Lint every ``.py`` under *roots*; returns (findings, files seen).
    Finding paths are relative to the current directory when possible so
    they match the repo-root-relative allowlist entries."""
    cfg = cfg if cfg is not None else default_config()
    cwd = Path.cwd()
    findings: list[Finding] = []
    files = iter_py_files(roots)
    for f in files:
        try:
            rel = str(f.resolve().relative_to(cwd))
        except ValueError:
            rel = str(f)
        findings.extend(lint_source(f.read_text(), rel, cfg, rule_ids))
    findings.sort(key=lambda x: (x.path, x.line, x.col, x.rule))
    return findings, len(files)
