"""easeylint — AST-level invariant checker for the repro codebase.

Six repo-specific rules enforce the invariants nine PRs of serving work
established by hand (see each rule module's docstring for the full
contract):

==================  =====================================================
rule id             invariant
==================  =====================================================
wall-clock          no wall-clock escape into gated metrics/artifacts
jit-purity          jit/scan/pallas bodies never touch host state
telemetry-guard     every tracer call dominated by `is not None`
keyed-rng           serving RNG keys are (rid, step) fold_in chains
refcount-pairing    page acquisitions release or hand off on all exits
vmem-budget         Pallas block+scratch bytes fit the target's VMEM
==================  =====================================================

Run ``python -m repro.analysis.lint src/ benchmarks/`` (CI does, before
pytest).  Suppress a single advisory site with a justified
``# easeylint: allow[rule-id]`` pragma; whole advisory files live in
``allow.toml`` next to this package, each entry with a ``reason``.
Rules 1-5 need no JAX import; the VMEM rule imports ``core/tuning`` for
the per-target budget and falls back to the same fraction of
``TargetSpec.vmem_bytes`` when JAX is absent.
"""

from repro.analysis.lint.core import (AllowEntry, Finding, LintConfig,
                                      default_config, lint_paths,
                                      lint_source)

__all__ = ["AllowEntry", "Finding", "LintConfig", "default_config",
           "lint_paths", "lint_source"]
