"""While-aware static cost model over compiled HLO text.

``compiled.cost_analysis()`` counts ``lax.scan`` bodies ONCE (verified in
the probe, ratio exactly 1/L), and all deep models here scan their layers,
so we parse ``compiled.as_text()`` ourselves:

* build the computation graph (entry, while bodies/conds, fusions, ...);
* extract while trip counts from the condition computation's ROOT compare
  constant;
* propagate execution multipliers (nested scans multiply);
* FLOPs   : dot ops (2 x out_elems x contracted_elems) x multiplier,
            counted in ALL computations (dots may hide inside fusions);
* HBM     : per-instruction (output + unique operand bytes) x multiplier,
            counted only in materializing computations (entry, while
            bodies, calls) — post-fusion HLO materializes each top-level
            instruction's output buffer;
* wire    : ring-algorithm wire bytes per collective op x multiplier
            (all-reduce 2(g-1)/g, all-gather/reduce-scatter/all-to-all
            (g-1)/g, collective-permute 1x), group size g parsed from
            replica_groups.

Everything is per-DEVICE: the program is the SPMD per-device module.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def xla_cost_analysis(compiled) -> dict:
    """Version-proof ``compiled.cost_analysis()``: jax <= 0.4.x returns a
    one-element list of dicts (per program), newer jax returns the dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total += elems * _DTYPE_BYTES[dt]
    return total


def type_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    elems = 1
    if dims:
        for d in dims.split(","):
            elems *= int(d)
    return elems


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list = dataclasses.field(default_factory=list)
    params: dict = dataclasses.field(default_factory=dict)  # name -> type


def _split_operands(rest: str) -> tuple[list[str], str]:
    """Split 'a, %b, f32[2]{0} %c), attr=...' into operand refs + attrs."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inside, attrs = rest[:i], rest[i + 1:]
                ops = re.findall(r"%([\w.\-]+)", inside)
                return ops, attrs
    return re.findall(r"%([\w.\-]+)", rest), ""


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    entry_name = None
    for line in text.splitlines():
        if not line.strip():
            continue
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            current = Computation(m.group(1))
            comps[current.name] = current
            if line.strip().startswith("ENTRY"):
                entry_name = current.name
            # parameter types from the signature
            sig = line[line.index("("):line.rindex("->")]
            for pm in re.finditer(r"([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)", sig):
                current.params[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, type_str, op, rest = im.groups()
        operands, attrs = _split_operands(rest)
        current.instrs.append(Instr(name, type_str, op, rest, operands, attrs))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _symbols(comp: Computation) -> dict[str, str]:
    table = dict(comp.params)
    for ins in comp.instrs:
        table[ins.name] = ins.type_str
    return table


def _trip_count(cond: Computation) -> int:
    """Trip count from the ROOT compare's constant operand."""
    consts = {}
    root = None
    for ins in cond.instrs:
        m = _CONST_RE.search(ins.type_str + " " + ins.rest)
        if ins.op == "constant":
            mm = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if mm:
                consts[ins.name] = int(mm.group(1))
        root = ins  # last instruction is ROOT in post-opt HLO dumps
    for ins in cond.instrs:
        if "compare" in ins.op:
            root = ins
    if root is not None:
        for opnd in root.operands:
            if opnd in consts:
                return consts[opnd]
    # fall back: any constant in cond
    return max(consts.values()) if consts else 1


def _group_size(attrs: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _dot_flops(ins: Instr, symbols: dict[str, str]) -> float:
    out_elems = type_elems(ins.type_str)
    lhs = ins.operands[0] if ins.operands else None
    lhs_type = symbols.get(lhs, "")
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    contracted = 1
    if m and lhs_type:
        sm = _SHAPE_RE.search(lhs_type)
        if sm and sm.group(2):
            dims = [int(x) for x in sm.group(2).split(",")]
            for ci in (m.group(1).split(",") if m.group(1) else []):
                ci = int(ci)
                if ci < len(dims):
                    contracted *= dims[ci]
    return 2.0 * out_elems * contracted


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    dot_count: int = 0
    while_trips: dict = dataclasses.field(default_factory=dict)
    notes: list = dataclasses.field(default_factory=list)


def analyze(text: str, total_devices: int = 1) -> HloCost:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    cost = HloCost()
    wire_factor = {
        "all-reduce": lambda g: 2 * (g - 1) / g,
        "all-gather": lambda g: (g - 1) / g,
        "reduce-scatter": lambda g: (g - 1) / g,
        "all-to-all": lambda g: (g - 1) / g,
        "collective-permute": lambda g: 1.0,
    }

    seen: set[tuple[str, float, bool, int]] = set()
    _SKIP_BYTES = ("parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "iota", "after-all", "partition-id", "while",
                   "conditional", "call")

    # perf iteration I5: VMEM crediting for loop-invariant operands.  A
    # while-body operand that the loop carries through UNCHANGED (root
    # tuple element i == gte(param, i)) stays resident in VMEM on a real
    # TPU when small (sLSTM recurrent weights, norm scales) — charge its
    # read once per loop entry, not once per iteration.
    _VMEM_BYTES = 64 * 1024 * 1024  # half of v5e VMEM as the residency cap

    def _invariant_gtes(comp: Computation) -> set[str]:
        gte_index: dict[str, int] = {}
        for ins in comp.instrs:
            if ins.op == "get-tuple-element":
                m = re.search(r"index=(\d+)", ins.attrs)
                if m and ins.operands and ins.operands[0] in comp.params:
                    gte_index[ins.name] = int(m.group(1))
        root = comp.instrs[-1] if comp.instrs else None
        if root is None or root.op != "tuple":
            return set()
        inv = set()
        for i, opnd in enumerate(root.operands):
            if gte_index.get(opnd) == i:
                inv.add(opnd)
        return inv

    def walk(comp: Computation, mult: float, materializing: bool,
             trips_here: int = 1):
        key = (comp.name, mult, materializing, trips_here)
        if key in seen:
            return
        seen.add(key)
        symbols = _symbols(comp)
        invariant = _invariant_gtes(comp) if trips_here > 1 else set()
        for ins in comps[comp.name].instrs:
            base_op = ins.op.replace("-start", "")
            # flops: dots anywhere
            if ins.op == "dot":
                cost.flops += _dot_flops(ins, symbols) * mult
                cost.dot_count += 1
            # bytes: only in materializing computations
            if materializing and ins.op not in _SKIP_BYTES:
                out_b = type_bytes(ins.type_str)
                op_types = [symbols.get(o, "") for o in
                            dict.fromkeys(ins.operands) if o in symbols]

                def _leading(ts: str) -> int:
                    m = _SHAPE_RE.search(ts)
                    if not m or not m.group(2):
                        return 0
                    return int(m.group(2).split(",")[0])

                def _stacked(ts: str) -> bool:
                    # scan stacks ys/xs along axis0 == trip count: a buffer
                    # whose leading dim equals the trip count is a carried
                    # stack, accessed one slice per iteration
                    return trips_here > 4 and _leading(ts) == trips_here

                if ins.op in ("dynamic-slice", "slice", "gather"):
                    traffic = 2 * out_b  # reads only the sliced region
                elif ins.op in ("dynamic-update-slice", "scatter"):
                    upd = ins.operands[1] if len(ins.operands) > 1 else None
                    ub = type_bytes(symbols.get(upd, "")) if upd else out_b
                    traffic = 3 * min(ub, out_b)
                elif ins.op in ("broadcast", "reshape", "copy", "transpose"):
                    traffic = 2 * out_b
                else:
                    out_charge = 3 * out_b / trips_here if _stacked(ins.type_str) \
                        else out_b
                    if trips_here > 1:
                        in_b = 0.0
                        for o in dict.fromkeys(ins.operands):
                            if o not in symbols:
                                continue
                            ts = symbols[o]
                            ob = type_bytes(ts)
                            if _stacked(ts):
                                in_b += ob / trips_here   # sliced carry
                            elif o in invariant and ob <= _VMEM_BYTES:
                                in_b += ob / trips_here   # VMEM-resident (I5)
                            else:
                                in_b += min(ob, out_b)
                    else:
                        in_b = sum(type_bytes(t) for t in op_types)
                    traffic = out_charge + in_b
                cost.hbm_bytes += traffic * mult
            # collectives
            if base_op in COLLECTIVES:
                g = _group_size(ins.attrs, total_devices)
                payload = type_bytes(ins.type_str) if base_op != "reduce-scatter" \
                    else sum(type_bytes(symbols.get(o, "")) for o in ins.operands
                             if o in symbols)
                if base_op == "all-reduce":
                    payload = type_bytes(ins.type_str)
                wb = wire_factor[base_op](max(g, 1)) * payload * mult
                cost.wire_bytes += wb
                d = cost.collective_breakdown.setdefault(
                    base_op, {"count": 0, "wire_bytes": 0.0})
                d["count"] += mult if mult >= 1 else 1
                d["wire_bytes"] += wb
            # recurse
            if ins.op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                trips = 1
                if cond and cond.group(1) in comps:
                    trips = _trip_count(comps[cond.group(1)])
                if body and body.group(1) in comps:
                    cost.while_trips[body.group(1)] = trips
                    walk(comps[body.group(1)], mult * trips, True, trips)
            elif ins.op in ("fusion", "reduce", "map", "scatter", "select-and-scatter",
                            "sort", "reduce-window", "custom-call"):
                for cm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.attrs):
                    if cm.group(1) in comps:
                        walk(comps[cm.group(1)], mult, False, trips_here)
            elif ins.op == "conditional":
                for cm in re.finditer(r"%([\w.\-]+)", ins.attrs):
                    if cm.group(1) in comps:
                        walk(comps[cm.group(1)], mult, True, trips_here)
            elif ins.op == "call":
                cm = re.search(r"to_apply=%?([\w.\-]+)", ins.attrs)
                if cm and cm.group(1) in comps:
                    walk(comps[cm.group(1)], mult, True, trips_here)

    walk(entry, 1.0, True)
    return cost
