"""Deterministic, restart-safe data pipeline.

Batches are a pure function of (seed, step): after a failure the training
loop can resume from checkpoint step k and regenerate batch k+1 bitwise —
the property the fault-tolerance tests assert.  The synthetic source
covers every input the model families declare (tokens, labels, frames,
patch embeddings) straight from the declarative batch table, and shards
host arrays onto the mesh via the same rules engine.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.models.params import ParamDef, _map_table
from repro.sharding.rules import AxisRules, DEFAULT_RULES, logical_to_spec


@dataclasses.dataclass
class SyntheticSource:
    """Language-modeling stream: labels are tokens shifted by one."""
    vocab_size: int
    seed: int = 0

    def batch(self, table: dict, step: int) -> dict:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % (2**31 - 1))

        def gen(d: ParamDef):
            if np.dtype(d.dtype) == np.int32:
                hi = max(self.vocab_size - 1, 2)
                seq = rng.randint(1, hi, size=d.shape).astype(np.int32)
                return seq
            return (rng.randn(*d.shape) * 0.02).astype(np.dtype(d.dtype))

        out = _map_table(table, gen)
        # make labels the next-token shift of tokens (real LM objective)
        if "tokens" in out and "labels" in out:
            t = out["tokens"]
            out["labels"] = np.concatenate(
                [t[:, 1:], np.ones_like(t[:, :1])], axis=1)
        return out


def shard_batch(batch: dict, table: dict, mesh, rules: AxisRules | None = None):
    """Place host arrays onto the mesh with the table's logical axes."""
    if mesh is None:
        return jax.tree.map(jnp.asarray, batch)
    rules = rules or DEFAULT_RULES
    flat_t, _ = jax.tree.flatten(
        _map_table(table, lambda d: d),
        is_leaf=lambda x: isinstance(x, ParamDef))
    flat_b, tdef = jax.tree.flatten(batch)
    out = []
    for d, arr in zip(flat_t, flat_b):
        spec = logical_to_spec(d.logical_axes, d.shape, mesh, rules)
        out.append(jax.device_put(arr, jax.NamedSharding(mesh, spec)))
    return jax.tree.unflatten(tdef, out)


class DataPipeline:
    """Pipeline facade used by the training driver."""

    def __init__(self, model, shape: ShapeConfig, seed: int = 0, mesh=None):
        self.table = model.batch_table(shape)
        self.source = SyntheticSource(model.cfg.vocab_size or 256, seed)
        self.mesh = mesh

    def batch_at(self, step: int) -> dict:
        return shard_batch(self.source.batch(self.table, step),
                           self.table, self.mesh)
