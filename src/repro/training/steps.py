"""Step builders: train (grad-accumulation scan), prefill, decode.

``build_train_step`` assembles the full training step from a model, an
optimizer and a DeploymentPlan: microbatch scan (gradient accumulation),
optional error-feedback int8 gradient compression, LR schedule, optimizer
update.  The returned function is pure and jit/pjit-able; the EASEY
BuildService owns jit+sharding+donation.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.plan import DeploymentPlan
from repro.optim.schedule import warmup_cosine


def _accum_dtype(plan):
    return jnp.bfloat16 if plan.grad_accum_dtype == "bfloat16" else jnp.float32


def _ef_int8(g, err):
    """Error-feedback int8 quantization of a gradient contribution — models
    compressed cross-replica reduction (wire bytes /4 vs fp32)."""
    x = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    deq = q * scale
    return deq, (x - deq)


def build_train_step(model, opt, plan: DeploymentPlan, mesh=None,
                     peak_lr: float = 3e-4, warmup_steps: int = 100,
                     total_steps: int = 10_000, param_specs=None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "ef" (optional), "step"}.
    param_specs: optional NamedSharding tree for the params — used to pin
    the gradient-accumulation scan carry (perf iteration I6: an
    unconstrained carry is materialized REPLICATED by XLA, turning the
    per-microbatch gradient reduction into full all-reduces and blowing
    fp32 grad buffers up by the data-axis factor).
    """
    M = plan.microbatches
    use_ef = plan.grad_compression == "ef_int8"

    def loss_fn(params, mb):
        return model.loss(params, mb, mesh)

    def _pin(gtree):
        if param_specs is None:
            return gtree
        return jax.tree.map(jax.lax.with_sharding_constraint, gtree,
                            param_specs)

    def train_step(state, batch):
        params = state["params"]
        acc_dt = _accum_dtype(plan)

        if M == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = _pin(grads)
        else:
            def split(x):
                b = x.shape[0]
                assert b % M == 0, (b, M)
                return x.reshape(M, b // M, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            g0 = _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                   params))

            def body(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                gsum = _pin(jax.tree.map(
                    lambda a, b_: a + b_.astype(acc_dt), gsum, g))
                return (gsum, lsum + l), None

            (gacc, lsum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: (g / M).astype(jnp.float32), gacc)
            loss = lsum / M
            metrics = {"loss": loss}

        if use_ef:
            pairs = jax.tree.map(_ef_int8, grads, state["ef"])
            grads = jax.tree.map(lambda pr: pr[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_ef = jax.tree.map(lambda pr: pr[1], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple))

        lr = warmup_cosine(state["step"], peak_lr=peak_lr,
                           warmup_steps=warmup_steps, total_steps=total_steps)
        new_params, new_opt, opt_metrics = opt.update(
            grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if use_ef:
            new_state["ef"] = new_ef
        metrics = dict(metrics, lr=lr, **opt_metrics)
        return new_state, metrics

    return train_step


def init_train_state(model, opt, params, plan: DeploymentPlan):
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if plan.grad_compression == "ef_int8":
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def train_state_table(model, opt, plan: DeploymentPlan):
    """Declarative (ParamDef) state table — dry-run path, no allocation."""
    from repro.models.params import ParamDef, _map_table
    import dataclasses as dc
    ptable = model.param_table()
    t = {"params": ptable, "opt": opt.state_table(ptable),
         "step": ParamDef((), (), jnp.int32, "zeros")}
    if plan.grad_compression == "ef_int8":
        t["ef"] = _map_table(ptable, lambda d: dc.replace(
            d, dtype=jnp.float32, init="zeros"))
    return t


def build_prefill_step(model, mesh=None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, mesh)
    return prefill_step


def build_prefill_chunk_step(model, mesh=None):
    """Chunked prefill straight into a *contiguous* serving KV pool.

    ``cache`` is the pool's cache tree — K/V shaped ``(layers, num_slots,
    max_len, kv_heads, head_dim)`` plus the per-slot ``index`` vector —
    and ``tokens`` is one bucketed ``(1, c)`` chunk of one request's
    prompt.  The chunk's K/V scatter directly to ``[slot, offset:offset+c)``
    (no intermediate contiguous ``(1, s)`` cache that ``insert`` would
    have to re-scatter), the chunk attends causally over everything the
    slot already holds, and the returned logits sit at the chunk's last
    valid position (``n_valid`` <= c covers bucket padding).  Jittable
    with ``kv_bound`` static (it sizes the slot's KV read-back — a short
    prompt attends its own bucketed prefix, not max_len); the engine
    donates the cache argument.
    """
    def chunk_step(params, cache, tokens, slot, offset, n_valid, kv_bound):
        return model.chunk_prefill(params, cache, tokens, slot, offset,
                                   n_valid, mesh, kv_bound)
    return chunk_step


def build_prefill_chunk_step_paged(model, mesh=None):
    """Chunked prefill straight into a *paged* serving KV pool.

    Same contract as ``build_prefill_chunk_step``, but K/V are the page
    pool ``(layers, num_pages, page_size, kv_heads, head_dim)`` and
    ``pages_row`` is the slot's ``(max_pages,)`` page-table row: chunk
    token at global position j lands in page ``pages_row[j // page_size]``
    at offset ``j % page_size`` — its final resting place, one write.
    Pages must be reserved by the pool before the call; rows past the
    reserved region (bucket padding) fall into the junk page 0.
    """
    def chunk_step(params, cache, tokens, slot, offset, n_valid, kv_bound,
                   pages_row):
        return model.chunk_prefill(params, cache, tokens, slot, offset,
                                   n_valid, mesh, kv_bound,
                                   pages_row=pages_row)
    return chunk_step


def build_decode_step(model, mesh=None):
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, mesh)
    return decode_step


def build_decode_step_slots(model, mesh=None):
    """Slot-wise decode for the continuous-batching serving engine.

    ``cache['index']`` is a per-slot length vector (one row per KV-pool
    slot) and ``active`` flags the slots holding a live request.  Inactive
    slots still ride through the batched matmuls — the fixed price of
    slot-indexed batching — but their lengths do not advance, so a freed
    slot can be re-prefilled between steps without disturbing its
    neighbours.  Jittable; the engine donates the cache argument.
    """
    def decode_step(params, cache, tokens, active):
        logits, new_cache = model.decode_step(params, cache, tokens, mesh)
        keep = active.astype(bool)
        new_index = jnp.where(keep, new_cache["index"], cache["index"])
        return logits, dict(new_cache, index=new_index)
    return decode_step


def build_decode_step_slots_paged(model, mesh=None, use_kernel: bool = False):
    """Slot-wise decode over a *paged* KV pool (PagedKVCachePool).

    Same contract as ``build_decode_step_slots``, but the cache's K/V are
    a page pool ``(layers, num_pages, page_size, kv_heads, head_dim)`` and
    the per-slot ``(num_slots, max_pages)`` int32 page table arrives as an
    extra argument each step (the pool keeps it on the host so page
    alloc/free never touches the device).  The model reads and writes K/V
    through the table; a slot whose table row is zeroed (freed) scatters
    its dead write into the reserved junk page 0.  Jittable; the engine
    donates the cache argument only — the page table is tiny and
    re-uploaded per step.

    use_kernel=True swaps the gather-then-attend read for the fused
    Pallas paged-attention kernel (kernels/paged_attention.py): the page
    table is walked inside the kernel, so the materialized
    (slots, max_pages*page_size, K, dh) read never hits HBM.  The flag is
    STATIC — it is closed over and inserted into the cache dict inside
    the traced function, never at the jit boundary, so cache pytree
    structure (and donation) is unchanged.
    """
    def decode_step(params, cache, tokens, active, pages):
        keep = active.astype(bool)
        # inactive rows (freed slots, or slots mid-prefill whose device
        # index is stale) must not write through their page table: with a
        # shared-prefix cache a stale-index write would land inside a
        # read-only page other requests attend, so their rows divert to
        # the reserved junk page 0 — same place zeroed rows already write
        safe_pages = jnp.where(keep[:, None], pages, 0)
        dcache = dict(cache, pages=safe_pages)
        if use_kernel:
            dcache["use_kernel"] = True
        logits, new_cache = model.decode_step(params, dcache, tokens, mesh)
        new_index = jnp.where(keep, new_cache["index"], cache["index"])
        return logits, {"k": new_cache["k"], "v": new_cache["v"],
                        "index": new_index}
    return decode_step


def build_verify_step_slots(model, mesh=None):
    """Speculative VERIFY step over a contiguous slot pool.

    ``tokens`` is ``(num_slots, k+1)`` — each row's pending token followed
    by its k drafted tokens — and the step returns logits at **every**
    speculated position ``(num_slots, k+1, vocab)``, scoring all of them
    against pool KV in one jitted call (the multi-position generalization
    of the single-token decode scatter in ``models/layers.attention``).
    Positions past a slot's capacity drop harmlessly; rejected-draft KV is
    overwritten by the next step before any causal mask admits it.

    The returned cache keeps ``index`` UNCHANGED: how many of the k+1
    positions became real tokens is the host's acceptance decision, so the
    scheduler re-uploads its post-acceptance length mirror
    (``pool.sync_index``) instead of trusting a device-side +k+1.
    """
    def verify_step(params, cache, tokens, active):
        logits, new_cache = model.decode_step(params, cache, tokens, mesh)
        return logits, dict(new_cache, index=cache["index"])
    return verify_step


def build_verify_step_slots_paged(model, mesh=None):
    """Speculative VERIFY step over a paged KV pool.

    Same contract as ``build_verify_step_slots`` plus the page table
    argument; inactive rows divert through junk page 0 exactly like
    ``build_decode_step_slots_paged``, and per-position page lookup keeps
    the same ok-guard, so a burst past a slot's page-run capacity can
    never scribble into a (possibly prefix-shared) live page.  The fused
    Pallas kernel is single-token-only, so verify always reads through
    the gather path — token-identical to the kernel by the PR 6 sweep.
    ``index`` stays host-authoritative (see ``build_verify_step_slots``).
    """
    def verify_step(params, cache, tokens, active, pages):
        keep = active.astype(bool)
        safe_pages = jnp.where(keep[:, None], pages, 0)
        dcache = dict(cache, pages=safe_pages)
        logits, new_cache = model.decode_step(params, dcache, tokens, mesh)
        return logits, {"k": new_cache["k"], "v": new_cache["v"],
                        "index": cache["index"]}
    return verify_step
