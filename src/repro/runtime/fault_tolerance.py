"""Fault tolerance: heartbeats, failure injection, restart policy,
straggler mitigation.

At 1000+ nodes, node loss is a WHEN not an IF.  The contract here:

* `HeartbeatMonitor` — hosts report heartbeats; silence past a deadline
  marks the host failed (the real transport would be the pod coordinator;
  the logic is transport-agnostic and fully tested).
* `FailureInjector` — deterministic fault injection for tests/examples
  (raise SimulatedFailure at step N / with probability p).
* `run_with_restarts` — the restart policy: on failure, restore the last
  complete checkpoint, rebuild step state, resume.  Combined with the
  deterministic pipeline, recovery is bitwise-exact (asserted in tests).
* `StragglerMonitor` — per-step wall-time tracker; steps slower than
  k x rolling-median flag their host for quarantine (the paper's
  "load-balancing/fault tolerance" exascale pillars, §6).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable


class SimulatedFailure(RuntimeError):
    """Stands in for a lost node / preempted slice."""


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple[int, ...] = ()
    max_failures: int = 1
    _count: int = 0

    def check(self, step: int):
        if self._count < self.max_failures and step in self.fail_at_steps:
            self._count += 1
            raise SimulatedFailure(f"injected failure at step {step}")


class HeartbeatMonitor:
    def __init__(self, deadline_s: float = 5.0):
        self.deadline = deadline_s
        self.last: dict[str, float] = {}
        self.failed: set[str] = set()

    def beat(self, host: str, now: float | None = None):
        self.last[host] = time.time() if now is None else now  # easeylint: allow[wall-clock] — injectable via now=

    def sweep(self, now: float | None = None) -> set[str]:
        now = time.time() if now is None else now  # easeylint: allow[wall-clock] — injectable via now=
        newly = {h for h, t in self.last.items()
                 if now - t > self.deadline and h not in self.failed}
        self.failed |= newly
        return newly

    @property
    def healthy(self) -> set[str]:
        return set(self.last) - self.failed


class StragglerMonitor:
    """Flags steps slower than `factor` x rolling median."""

    def __init__(self, window: int = 16, factor: float = 3.0, warmup: int = 3):
        self.times = deque(maxlen=window)
        self.factor = factor
        self.warmup = warmup
        self.flagged: list[tuple[int, float, float]] = []

    def observe(self, step: int, seconds: float) -> bool:
        is_straggler = False
        if len(self.times) >= self.warmup:
            med = sorted(self.times)[len(self.times) // 2]
            if seconds > self.factor * med:
                self.flagged.append((step, seconds, med))
                is_straggler = True
        # stragglers do not poison the baseline
        if not is_straggler:
            self.times.append(seconds)
        return is_straggler


def run_with_restarts(loop: Callable[[int], int], *, checkpointer,
                      max_restarts: int = 3, logger=print) -> dict:
    """Run `loop(start_step) -> final_step`, restarting from the last
    complete checkpoint on SimulatedFailure.  Returns run stats."""
    restarts = 0
    start = (checkpointer.latest_step() or -1) + 1 if checkpointer else 0
    while True:
        try:
            final = loop(start)
            return {"final_step": final, "restarts": restarts}
        except SimulatedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts; giving up") from e
            latest = checkpointer.latest_step() if checkpointer else None
            start = (latest + 1) if latest is not None else 0
            logger(f"[FT] {e} -> restart #{restarts} from step {start}")
