"""Elastic scaling: re-mesh after node loss and continue training.

Checkpoints are mesh-agnostic (full host arrays), so elasticity is:

    detect loss -> rebuild mesh without the lost data slice(s)
    -> re-derive shardings via the SAME rules engine (divisibility
       fallback absorbs the smaller axis) -> device_put -> resume.

The "pod" axis of the multi-pod mesh is pure DP, so losing a whole pod
degrades to the single-pod mesh with NO TP-state resharding — that is a
deliberate design decision recorded in DESIGN.md §7/§8.

The global batch is preserved by rebalancing per-replica batch (the
deterministic pipeline is keyed by global step, so data order is stable
across the transition).
"""

from __future__ import annotations

import jax

from repro.models.params import partition_specs
from repro.sharding.rules import DEFAULT_RULES


def reshard_state(state, state_table, new_mesh, rules=None,
                  fallbacks: list | None = None):
    """Place a host-restored state onto a (possibly degraded) mesh."""
    rules = rules or DEFAULT_RULES
    specs = partition_specs(state_table, new_mesh, rules,
                            [] if fallbacks is None else fallbacks)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, specs)


def rebalance_batch_size(global_batch: int, old_data: int, new_data: int,
                         *, allow_shrink: bool = False) -> tuple[int, int]:
    """Keep the global batch; per-replica batch grows on the survivors.

    Returns ``(per_replica, adjusted_global)``.  When ``global_batch``
    does not divide evenly over ``new_data`` replicas the only way to
    keep per-replica batches equal is to shrink the global batch to the
    largest divisible value — a silent semantics change for the caller
    (the optimizer sees smaller steps), so it must be opted into with
    ``allow_shrink=True``; otherwise this raises ``ValueError``.
    """
    if new_data <= 0:
        raise ValueError(f"new_data must be positive, got {new_data}")
    adjusted = global_batch
    if adjusted % new_data:
        if not allow_shrink:
            raise ValueError(
                f"global batch {global_batch} does not divide over "
                f"{new_data} replicas (was {old_data}); pass "
                f"allow_shrink=True to shrink to the largest divisible "
                f"global batch")
        adjusted = (adjusted // new_data) * new_data
    return adjusted // new_data, adjusted
