"""Atomic, async-capable, mesh-agnostic checkpointing.

* atomic: write to ``step_K.tmp/`` then ``os.rename`` — a crash mid-write
  never corrupts the latest checkpoint (fault-tolerance requirement).
* async: a writer thread drains a queue; the train loop donates a host
  snapshot and keeps stepping (overlap I/O with compute).
* mesh-agnostic: leaves are stored as full host numpy arrays keyed by
  pytree path, so a checkpoint written on a 16x16 mesh restores onto a
  15x16 degraded mesh (elastic restart) or a single CPU.
* keep-last-k with a manifest for discovery.
* deterministic bytes: the metadata timestamp is injectable (``now=``,
  advisory wall clock by default) and the array blob is written through
  a fixed-timestamp zip writer — two checkpoints of the same state at
  the same step are byte-identical, so checkpoint diffs mean state
  diffs, never clock noise (``np.savez`` would bake the wall clock into
  every zip entry's mtime).
"""

from __future__ import annotations

import io
import json
import os
import queue
import shutil
import threading
import time
import zipfile
from pathlib import Path

import jax
import numpy as np

# zip entries need a DOS timestamp; pin the epoch so identical arrays
# produce identical bytes
_ZIP_EPOCH = (1980, 1, 1, 0, 0, 0)


def _savez_deterministic(path, arrays: dict) -> None:
    """``np.savez`` minus the wall clock: sorted members, fixed zip
    timestamps, no compression (np.load reads it like any npz)."""
    with zipfile.ZipFile(path, "w", zipfile.ZIP_STORED) as zf:
        for key in sorted(arrays):
            buf = io.BytesIO()
            np.lib.format.write_array(
                buf, np.ascontiguousarray(arrays[key]), allow_pickle=False)
            info = zipfile.ZipInfo(key + ".npy", date_time=_ZIP_EPOCH)
            info.external_attr = 0o644 << 16
            zf.writestr(info, buf.getvalue())


def _flatten(state):
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_writes: bool = False,
                 # advisory default — anything needing byte-identical
                 # checkpoints injects a fixed clock; asserted in
                 # tests/test_checkpoint_ft.py
                 now=time.time):  # easeylint: allow[wall-clock]
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.now = now
        self.async_writes = async_writes
        self._q: queue.Queue | None = None
        self._thread = None
        self._error: BaseException | None = None
        if async_writes:
            self._q = queue.Queue(maxsize=2)
            self._thread = threading.Thread(target=self._drain, daemon=True)
            self._thread.start()

    # -- public API --
    def save(self, step: int, state) -> None:
        arrays = _flatten(state)  # host snapshot taken synchronously
        if self.async_writes:
            self._raise_pending()
            self._q.put((step, arrays))
        else:
            self._write(step, arrays)

    def wait(self) -> None:
        if self.async_writes:
            self._q.join()
            self._raise_pending()

    def latest_step(self) -> int | None:
        m = self.dir / "manifest.json"
        if not m.exists():
            return None
        steps = json.loads(m.read_text()).get("steps", [])
        return max(steps) if steps else None

    def restore(self, treedef_state, step: int | None = None):
        """Restore into the structure of `treedef_state` (a template pytree
        — e.g. abstract shapes or a freshly-initialized state)."""
        import ml_dtypes
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        blob = np.load(d / "arrays.npz", allow_pickle=False)
        meta = json.loads((d / "meta.json").read_text())["dtypes"]
        flat = jax.tree_util.tree_flatten_with_path(treedef_state)
        leaves = []
        for path, leaf in flat[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = blob[key]
            if meta.get(key) == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(jax.tree.structure(treedef_state),
                                            leaves), step

    # -- internals --
    def _write(self, step: int, arrays: dict):
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        # bf16 isn't a native npz dtype — store raw bytes + dtype sidecar
        savable, meta = {}, {}
        for k, v in arrays.items():
            if v.dtype.name == "bfloat16":
                savable[k] = v.view(np.uint16)
                meta[k] = "bfloat16"
            else:
                savable[k] = v
        _savez_deterministic(tmp / "arrays.npz", savable)
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, "dtypes": meta, "time": self.now()},
            sort_keys=True))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._update_manifest(step)

    def _update_manifest(self, step: int):
        m = self.dir / "manifest.json"
        steps = []
        if m.exists():
            steps = json.loads(m.read_text()).get("steps", [])
        steps = sorted(set(steps + [step]))
        while len(steps) > self.keep:
            victim = steps.pop(0)
            vdir = self.dir / f"step_{victim}"
            if vdir.exists():
                shutil.rmtree(vdir)
        tmp = self.dir / "manifest.json.tmp"
        tmp.write_text(json.dumps({"steps": steps}))
        os.rename(tmp, m)

    def _drain(self):
        while True:
            step, arrays = self._q.get()
            try:
                self._write(step, arrays)
            except BaseException as e:  # surfaced on next save()/wait()
                self._error = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e
