"""AutoTuner — EASEY's `###includelocalmpi###` mechanism for TPU (§2.1).

Given (ModelConfig, ShapeConfig, TargetSpec) it derives a DeploymentPlan by
explicit napkin math over the target's memory/compute budget:

* parameter + optimizer bytes per chip  -> optimizer variant (fp32 vs int8)
* activation bytes per microbatch       -> microbatch count + remat policy
* gradient accumulation dtype           -> fp32 unless HBM-bound
* kernel library                        -> pallas on TPU, reference on CPU
* sharding fallbacks                    -> recorded for the tuning report

Every decision lands in the DeploymentPlan (shipped in the package
manifest), so a deployment is as auditable as the paper's generated batch
files.
"""

from __future__ import annotations

import math

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.plan import DeploymentPlan
from repro.core.target import TargetSpec

# paged serve layout: tokens per KV page, and the expected fraction of
# max_len a request actually uses (heavy-tailed traces — the capacity
# quote in the napkin is per *expected* tokens, not per worst case)
SERVE_PAGE_SIZE = 16
SERVE_EXPECTED_LEN_FRACTION = 0.25
# speculative decoding: below this trace repetitiveness the n-gram
# drafter's expected accepted-tokens/verify (~1/(1-r)) does not cover the
# verify step's (k+1)-wide compute, so the tuner keeps spec off
SPEC_MIN_REPETITIVENESS = 0.35
SPEC_MAX_K = 8
# Pallas kernels budget this fraction of the target's per-core VMEM for
# block + scratch residency; the remainder covers compiler-managed
# spills and semaphores.  analysis/lint's vmem-budget rule enforces it
# statically (and mirrors the fraction for JAX-less environments —
# tests pin the two together).
VMEM_BUDGET_FRACTION = 0.9


def vmem_budget_bytes(target: TargetSpec) -> float:
    """Static VMEM byte budget a single Pallas kernel may plan for."""
    return VMEM_BUDGET_FRACTION * target.vmem_bytes


# SLO deadlines the tuner suggests, on the virtual step clock: TTFT gets
# a multiple of the expected prefill stall (queue wait + ingestion both
# have to fit under it), e2e adds a per-token decode allowance on top
SERVE_SLO_TTFT_STALL_MULT = 4
SERVE_SLO_E2E_STEPS_PER_TOKEN = 2


def ttft_napkin_steps(prompt_len: int, chunk_unit: int,
                      backlog_chunks: int = 0,
                      waited_steps: int = 0) -> int:
    """Predicted time-to-first-token, in virtual steps — the napkin the
    router's SLO admission consults before queueing a request.

    The prediction is the steps already waited, plus the fleet's pending
    prefill backlog (in chunk-equivalents — the share one replica would
    have to chew through first), plus the request's own prompt priced at
    ``ceil(prompt_len / chunk_unit)`` chunk steps.  Chunk-equivalents are
    the same unit the virtual clock prices blocking prefills in, so the
    prediction and the measured ``ttft_steps`` are directly comparable.
    """
    own = -(-max(int(prompt_len), 1) // max(int(chunk_unit), 1))
    return int(waited_steps) + int(backlog_chunks) + own


def spec_k_for(repetitiveness: float) -> int:
    """Draft length the tuner picks for a trace's repetitiveness r.

    r proxies the per-draft accept probability, so a k-draft verify step
    emits E(k) = (1 - r^{k+1})/(1 - r) tokens in expectation.  E(k) is
    increasing but saturating in k; each extra draft costs verify compute
    whether or not it is accepted, so k stops where the marginal token
    gain r^k drops below ~0.1 (diminishing returns), capped at
    SPEC_MAX_K.  r below SPEC_MIN_REPETITIVENESS turns spec off (0).
    """
    r = min(max(float(repetitiveness), 0.0), 0.99)
    if r < SPEC_MIN_REPETITIVENESS:
        return 0
    k = 1
    while k < SPEC_MAX_K and r ** (k + 1) >= 0.1:
        k += 1
    return k


def param_count_estimate(cfg: ModelConfig) -> int:
    """Exact parameter count, straight from the model's ParamDef table
    (metadata only — no allocation)."""
    if cfg.family == "stencil":
        return 0
    from repro.models.params import param_count
    from repro.models.transformer import model_for
    return param_count(model_for(cfg).param_table())


def kv_bytes_per_token(cfg: ModelConfig) -> int:
    """HBM bytes one KV-cache token costs (k+v, all layers) — the unit the
    serve-mode budget is denominated in.  Single source of truth for the
    tuner, the serving benchmark, and the budget-target tests."""
    import jax.numpy as jnp
    per = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * \
        jnp.dtype(cfg.activation_dtype).itemsize
    if cfg.family == "encdec":
        per *= 2  # self- and cross-attention caches
    return per


def prefix_cache_quota(num_pages: int) -> int:
    """LRU pin cap for the shared-prefix KV cache: ~1/4 of the
    allocatable page pool, so hot prefixes can never squeeze live
    requests below 3/4 of their pages.  Single source of truth for the
    tuner and for engines built without a plan-derived value."""
    return max((num_pages - 1) // 4, 1) if num_pages else 0


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: only top-k experts active)."""
    total = param_count_estimate(cfg)
    if cfg.family != "moe":
        return total
    gated = 3 if cfg.activation in ("silu", "geglu") else 2
    per_expert = gated * cfg.d_model * cfg.d_ff
    inactive = (cfg.num_experts - cfg.experts_per_token) * per_expert * cfg.num_layers
    return total - inactive


def tune(cfg: ModelConfig, shape: ShapeConfig, target: TargetSpec,
         overrides: dict | None = None) -> DeploymentPlan:
    chips = target.num_chips
    plan = DeploymentPlan(
        arch=cfg.name, shape=shape.name, target=target.name,
        mesh_shape=target.mesh_shape, mesh_axes=target.mesh_axes,
        kernels=target.kernels)

    P = param_count_estimate(cfg)
    param_bytes = 2 * P  # bf16
    plan.napkin["params"] = f"{P/1e9:.2f}B"
    plan.napkin["param_bytes_per_chip"] = f"{param_bytes/chips/1e9:.3f} GB"

    if shape.kind == "train":
        budget = 0.85 * target.hbm_bytes
        fixed = param_bytes / chips
        grad_fp32 = 4 * P / chips
        opt_fp32 = 8 * P / chips
        plan.napkin["opt_fp32_per_chip"] = f"{opt_fp32/1e9:.2f} GB"
        # minimum activation footprint (full remat, max microbatches) —
        # used to decide the optimizer variant up front
        axes0 = dict(zip(target.mesh_axes, target.mesh_shape))
        bs0 = axes0.get("pod", 1) * axes0.get("data", 1)
        mm0 = max(int(shape.global_batch // bs0), 1)
        w0 = cfg.d_model if cfg.family not in ("ssm_xlstm", "hybrid_mamba") \
            else (cfg.ssm_expand + 1) * cfg.d_model
        L0 = cfg.num_layers + cfg.num_encoder_layers
        min_act = 3.5 * (shape.global_batch * shape.seq_len / bs0 / mm0) * \
            L0 * w0 * 2
        if fixed + opt_fp32 + grad_fp32 + min_act > budget:
            plan.optimizer = "adamw8bit"
            opt_bytes = (2 * P + 8 * max(P // 128, 1)) / chips
            plan.notes.append(
                "fp32 Adam moments + activations exceed HBM -> int8 moments")
        else:
            plan.optimizer = "adamw"
            opt_bytes = opt_fp32
        # --- grad accumulation dtype (may be escalated by the ladder) ---
        if fixed + opt_bytes + grad_fp32 > budget:
            plan.grad_accum_dtype = "bfloat16"
            grad_bytes = 2 * P / chips
            plan.notes.append("fp32 grad accumulator exceeds budget -> bf16")
        else:
            grad_bytes = grad_fp32
        headroom = budget - fixed - opt_bytes - grad_bytes
        plan.napkin["headroom_for_activations"] = f"{headroom/1e9:.2f} GB"
        headroom_bf16_grads = budget - fixed - opt_bytes - 2 * P / chips

        # --- microbatches / remat / SP escalation ladder (perf iter I2) ---
        # Empirical calibration from the dry-run memory_analysis (see
        # EXPERIMENTS.md §Perf): XLA temp ~= FACTOR x (stacked layer inputs
        # per microbatch per device), FACTOR ~6 under 'dots' remat, ~3.5
        # under full remat (recompute working set + loop double-buffering).
        axes = dict(zip(target.mesh_axes, target.mesh_shape))
        batch_shards = axes.get("pod", 1) * axes.get("data", 1)
        model_size = axes.get("model", 1)
        L_eff = cfg.num_layers + cfg.num_encoder_layers
        tokens_local = shape.global_batch * shape.seq_len / batch_shards
        per_layer_width = cfg.d_model
        if cfg.family in ("ssm_xlstm", "hybrid_mamba"):
            per_layer_width = (cfg.ssm_expand + 1) * cfg.d_model

        def est_temp(micro, factor, seq_shards=1):
            saved = (tokens_local / micro) * L_eff * per_layer_width * 2
            return factor * saved / seq_shards

        max_micro = max(int(shape.global_batch // batch_shards), 1)
        # escalation ladder, cheapest knob first: each config is
        # (remat, factor, seq_parallel, bf16_grads).  Microbatches are the
        # inner loop (fewest first — per-micro FSDP weight re-gathers make
        # micro the most expensive collective knob, measured in it1/it2).
        # SP is skipped for MoE (I2b: expert dispatch reshards per chunk).
        ladder = [("dots", 6.0, False, False), ("full", 3.5, False, False),
                  ("dots", 6.0, False, True), ("full", 3.5, False, True)]
        if cfg.family != "moe":
            ladder += [("dots", 6.0, True, False), ("full", 3.5, True, False),
                       ("dots", 6.0, True, True), ("full", 3.5, True, True)]
        chosen = None
        for remat, factor, sp, bf16g in ladder:
            room = headroom_bf16_grads if bf16g else headroom
            shards = model_size if sp else 1
            micro = 1
            while micro <= max_micro:
                if shape.global_batch % micro == 0 and \
                        est_temp(micro, factor, shards) <= room:
                    chosen = (remat, micro, sp, bf16g)
                    break
                micro *= 2
            if chosen:
                break
        if not chosen:
            chosen = ("full", max_micro, cfg.family != "moe", True)
            plan.notes.append("I2: memory estimate exceeds HBM even at the "
                              "top of the escalation ladder")
        plan.remat_policy, plan.microbatches, plan.sequence_parallel, bf16g = chosen
        if bf16g and plan.grad_accum_dtype != "bfloat16":
            plan.grad_accum_dtype = "bfloat16"
            plan.notes.append("I2: bf16 grad accumulation (ladder escalation)")
        if chosen[2]:
            plan.notes.append("I2: sequence-parallel activations "
                              "(saved tensors shard over the model axis)")
        factor = 6.0 if plan.remat_policy == "dots" else 3.5
        shards = model_size if plan.sequence_parallel else 1
        plan.napkin["est_temp_per_chip"] = (
            f"{est_temp(plan.microbatches, factor, shards) / 1e9:.2f} GB")
    else:
        plan.microbatches = 1
        plan.remat_policy = "none"
        # decode/prefill memory: params + kv cache
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            kv_per_token = kv_bytes_per_token(cfg)
            kv = kv_per_token * shape.global_batch * shape.seq_len
            plan.napkin["kv_cache_per_chip"] = f"{kv/chips/1e9:.3f} GB"
            # --- serve-mode KV pool sizing ---------------------------------
            # The continuous-batching engine asks for (slots x max_len);
            # the requested batch is honoured only while params + pool fit
            # the HBM budget, otherwise the pool is capped — the serving
            # analogue of the training escalation ladder.  Both KV layouts
            # are sized and recorded: the contiguous pool reserves
            # worst-case (max_len) per admitted request, the paged pool
            # turns the same budget into *pages* so capacity is measured
            # in expected tokens instead of worst cases.
            #
            # With `shape.serve_replicas` > 1 the KV budget is split evenly
            # across N co-resident engines (params are shared weights, so
            # only the pools divide), every slot/page count below is *per
            # replica*, and the napkin quotes the fleet-aggregate capacity
            # — the quantity the ReplicaRouter balances.
            replicas = max(int(getattr(shape, "serve_replicas", 1) or 1), 1)
            plan.serve_replicas = replicas
            budget = (0.85 * target.hbm_bytes - param_bytes / chips) / replicas
            replica_batch = max(math.ceil(shape.global_batch / replicas), 1)
            per_slot = kv_per_token * shape.seq_len / chips
            cap = max(int(budget // per_slot), 1) if per_slot > 0 else \
                replica_batch
            plan.serve_max_len = shape.seq_len
            plan.serve_slots = max(1, min(replica_batch, cap))
            per = " per replica" if replicas > 1 else ""
            plan.napkin["serve_pool"] = (
                f"{plan.serve_slots} slots x {shape.seq_len} "
                f"({plan.serve_slots * per_slot / 1e9:.3f} GB/chip{per})")
            if plan.serve_slots < replica_batch:
                plan.notes.append(
                    f"serve: requested {replica_batch} slots{per} exceed the "
                    f"HBM budget -> pool capped at {plan.serve_slots}")
            # paged layout: same budget buys a page pool.  Pages beyond the
            # requested batch's worst case are pointless, so the pool is
            # capped there; capacity is then quoted against the *expected*
            # request length (heavy-tailed traces use ~1/4 of max_len on
            # average), not against max_len.
            page_size = min(SERVE_PAGE_SIZE, shape.seq_len)
            page_bytes = kv_per_token * page_size / chips
            worst_pages = replica_batch * \
                math.ceil(shape.seq_len / page_size) + 1  # + junk page 0
            budget_pages = max(int(budget // page_bytes), 2) \
                if page_bytes > 0 else worst_pages
            plan.serve_page_size = page_size
            plan.serve_num_pages = min(budget_pages, worst_pages)
            expected_len = max(
                int(shape.seq_len * SERVE_EXPECTED_LEN_FRACTION), 1)
            usable_tokens = (plan.serve_num_pages - 1) * page_size
            paged_reqs = max(usable_tokens // expected_len, 1)
            plan.napkin["kv_pages"] = plan.serve_num_pages
            plan.napkin["page_size"] = page_size
            plan.napkin["serve_pool_paged"] = (
                f"{plan.serve_num_pages} pages x {page_size} "
                f"({plan.serve_num_pages * page_bytes / 1e9:.3f} GB/chip{per})")
            delta = paged_reqs / max(plan.serve_slots, 1) - 1.0
            plan.napkin["serve_capacity_delta"] = (
                f"contiguous {plan.serve_slots} worst-case reqs vs paged "
                f"~{paged_reqs} expected-len({expected_len}) reqs "
                f"({delta:+.0%}){per}")
            # --- chunked-prefill grain + TTFT napkin -----------------------
            # Prompt ingestion interleaves with decode ticks; the chunk is
            # sized so one chunk's FLOPs fit inside one decode tick's
            # budget (decode is bandwidth-bound on the weights, so a tick
            # costs ~max(param-read time, batch compute) — prefill chunks
            # ride in that shadow without stretching the tick).  Bucketed
            # to a power of two so the chunk jit cache stays small.
            flops_tok = 2 * active_param_count(cfg)
            t_tick = max(param_bytes / chips / target.hbm_bw,
                         plan.serve_slots * flops_tok / target.peak_flops)
            c_raw = t_tick * target.peak_flops / max(flops_tok, 1)
            chunk = 8
            while chunk * 2 <= min(c_raw, 128, shape.seq_len):
                chunk *= 2
            plan.serve_prefill_chunk = chunk
            stall = -(-expected_len // chunk)     # chunk-equivalent ticks
            plan.napkin["serve_prefill_chunk"] = chunk
            plan.napkin["ttft_estimate"] = (
                f"expected {expected_len}-token prompt = {stall} chunk(s) "
                f"x ~{t_tick*1e3:.2f} ms/tick ≈ {stall*t_tick*1e3:.1f} ms "
                f"to first token; chunked ingest overlaps those ticks "
                f"with decode, blocking stalls the loop for all of them")
            # --- SLO deadlines (virtual step clock) ------------------------
            # The same stall estimate, held to a deadline: TTFT gets a
            # SERVE_SLO_TTFT_STALL_MULT x headroom over the expected
            # prefill (queue wait + ingestion must both fit), e2e adds
            # SERVE_SLO_E2E_STEPS_PER_TOKEN vsteps per expected generated
            # token on top.  Virtual steps, never wall-clock — the router
            # judges goodput and rejects hopeless admissions against
            # these (launch/serve.py --slo-ttft/-e2e -1 = use the plan's).
            plan.serve_slo_ttft_steps = \
                SERVE_SLO_TTFT_STALL_MULT * (stall + 1)
            plan.serve_slo_e2e_steps = plan.serve_slo_ttft_steps + \
                SERVE_SLO_E2E_STEPS_PER_TOKEN * expected_len
            plan.napkin["serve_slo"] = (
                f"ttft <= {plan.serve_slo_ttft_steps} vsteps "
                f"({SERVE_SLO_TTFT_STALL_MULT}x expected prefill stall), "
                f"e2e <= {plan.serve_slo_e2e_steps} vsteps "
                f"(+{SERVE_SLO_E2E_STEPS_PER_TOKEN}/token over "
                f"{expected_len} expected tokens)")
            # --- shared-prefix KV cache budget -----------------------------
            # The cache pins already-resident page runs (LRU) so repeat
            # prefixes re-prefill nothing; it spends no new HBM — the cap
            # carves a pin quota out of the page pool above so hot
            # prefixes can't squeeze live requests below ~3/4 of the
            # pool.  Savings quote: a hit on an expected-length prompt
            # skips every fully-covered page's worth of chunk steps.
            cache_pages = prefix_cache_quota(plan.serve_num_pages)
            plan.serve_prefix_cache_pages = cache_pages
            if cache_pages:
                # probe caps a hit at (len-1)//page_size pages (>= 1
                # suffix token always re-prefills, its logits seed the
                # first sample), so a page-aligned prompt still pays one
                # page — quote that, not a zero-cost hit
                aligned = (expected_len - 1) // page_size * page_size
                saved = stall - -(-(expected_len - aligned) // chunk)
                plan.napkin["serve_prefix_cache"] = (
                    f"{cache_pages} pages ({cache_pages * page_size} "
                    f"tokens) LRU-pinnable for shared prefixes; a hit on "
                    f"an expected {expected_len}-token prompt re-prefills "
                    f"{expected_len - aligned} instead of {expected_len} "
                    f"tokens (~{saved} of {stall} chunk steps saved)")
            # --- paged decode attention kernel -----------------------------
            # Pallas targets get the fused paged-attention kernel (page
            # table walked in-kernel); reference targets keep the
            # gather-then-attend read.  The napkin quotes what the gather
            # materializes per decode tick: the full worst-case
            # (slots, max_pages*page_size, K, dh) K/V read, vs the fused
            # kernel touching only pages each slot actually holds.
            plan.serve_kv_kernel = \
                "pallas" if target.kernels == "pallas" else "gather"
            slot_cap = math.ceil(shape.seq_len / page_size) * page_size
            gather_bytes = kv_per_token * plan.serve_slots * slot_cap / chips
            fused_bytes_est = \
                kv_per_token * plan.serve_slots * expected_len / chips
            plan.napkin["serve_kv_kernel"] = (
                f"{plan.serve_kv_kernel}: gather materializes "
                f"{gather_bytes/1e9:.3f} GB/chip of K/V per decode tick "
                f"(worst-case page runs); fused pallas streams only held "
                f"pages (~{fused_bytes_est/1e9:.3f} GB/chip at expected "
                f"lengths)")
            # --- speculative decoding (draft-then-verify) ------------------
            # The trace's repetitiveness r (n-gram self-overlap in [0, 1],
            # measured by serving/trace.trace_repetitiveness and passed in
            # as a shape hint) doubles as the napkin's per-draft accept
            # probability: a k-draft verify step then emits
            # E(k) = 1 + r + r^2 + ... + r^k = (1 - r^{k+1}) / (1 - r)
            # tokens in expectation for ONE jitted call.  Verify compute
            # grows ~(k+1)x but decode is bandwidth-bound on the weights,
            # so E(k) > 1 is (napkin-)free throughput; below the
            # break-even repetitiveness the drafts just miss and the plan
            # keeps spec off.
            rep = float(getattr(shape, "serve_repetitiveness", 0.0) or 0.0)
            plan.serve_spec_k = spec_k_for(rep)
            if plan.serve_spec_k:
                k = plan.serve_spec_k
                est = (1.0 - rep ** (k + 1)) / (1.0 - rep)
                plan.napkin["serve_spec"] = (
                    f"spec_k={k} at repetitiveness {rep:.2f}: expected "
                    f"~{est:.2f} accepted tokens/verify step "
                    f"(1 guaranteed + drafts while they match)")
            elif rep:
                plan.napkin["serve_spec"] = (
                    f"spec off: repetitiveness {rep:.2f} < "
                    f"{SPEC_MIN_REPETITIVENESS} — expected accepted "
                    f"tokens/verify ~{1.0 / (1.0 - min(rep, 0.99)):.2f} "
                    f"does not cover the verify overhead")
            # fleet capacity: what N replicas hold together, in tokens —
            # the quantity a router's least-loaded policy balances
            fleet_tokens = replicas * usable_tokens
            plan.napkin["serve_fleet_tokens"] = fleet_tokens
            plan.napkin["serve_fleet_capacity"] = (
                f"{replicas} replica(s) x {usable_tokens} paged tokens = "
                f"{fleet_tokens} tokens | {replicas} x {plan.serve_slots} "
                f"contiguous slots = {replicas * plan.serve_slots} "
                f"worst-case reqs")

    # --- long-context sequence parallelism ---
    if shape.kind != "train" and shape.seq_len >= 131072 and \
            shape.global_batch < dict(zip(target.mesh_axes, target.mesh_shape)).get("data", 1):
        plan.sequence_parallel = True
        plan.notes.append("batch smaller than data axis at long context -> "
                          "sequence-parallel activations")

    if overrides:
        for k, v in overrides.items():
            setattr(plan, k, v)
    return plan
