"""DeploymentPlan — the record of every decision the EASEY AutoTuner makes.

This is the TPU analogue of the paper's injected "local building bricks"
(§2.1: local MPI purge/compile, symlinks, mounts): a portable AppSpec plus
a TargetSpec deterministically produce a DeploymentPlan, and the plan is
shipped inside the package manifest so a deployment is reproducible and
auditable (the paper's tuning report).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any


@dataclasses.dataclass
class DeploymentPlan:
    arch: str
    shape: str
    target: str
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    microbatches: int = 1
    remat_policy: str = "dots"            # none | dots | full
    grad_accum_dtype: str = "float32"     # float32 | bfloat16
    optimizer: str = "adamw"              # adamw | adamw8bit
    kernels: str = "reference"            # pallas | reference
    sequence_parallel: bool = False
    grad_compression: str = "none"        # none | ef_int8
    donate_state: bool = True
    serve_slots: int = 0                  # KV-pool slots (serve mode; 0 = n/a)
    serve_max_len: int = 0                # per-slot KV capacity (serve mode)
    serve_page_size: int = 0              # paged KV: tokens per page
    serve_num_pages: int = 0              # paged KV: pool pages (incl. junk 0)
    serve_replicas: int = 1               # engines the serve budget is split over
    serve_prefill_chunk: int = 0          # prompt tokens ingested per decode tick
    serve_prefix_cache_pages: int = 0     # paged KV: LRU pin cap for the
    #                                       shared-prefix cache (same pool)
    serve_kv_kernel: str = ""             # paged decode attn: gather | pallas
    #                                       ("" = n/a / contiguous layout)
    serve_spec_k: int = 0                 # speculative draft tokens per slot
    #                                       per verify step (0 = spec off)
    serve_slo_ttft_steps: int = 0         # TTFT deadline (virtual steps) the
    #                                       tuner suggests for SLO admission
    serve_slo_e2e_steps: int = 0          # end-to-end deadline (virtual steps)
    sharding_fallbacks: list = dataclasses.field(default_factory=list)
    napkin: dict = dataclasses.field(default_factory=dict)
    notes: list = dataclasses.field(default_factory=list)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["mesh_shape"] = list(self.mesh_shape)
        d["mesh_axes"] = list(self.mesh_axes)
        return json.dumps(d, indent=2)

    @classmethod
    def from_json(cls, s: str) -> "DeploymentPlan":
        d = json.loads(s)
        d["mesh_shape"] = tuple(d["mesh_shape"])
        d["mesh_axes"] = tuple(d["mesh_axes"])
        return cls(**d)

    def report(self) -> str:
        lines = [f"EASEY tuning report — {self.arch} × {self.shape} on {self.target}",
                 f"  mesh            : {dict(zip(self.mesh_axes, self.mesh_shape))}",
                 f"  microbatches    : {self.microbatches}",
                 f"  remat           : {self.remat_policy}",
                 f"  grad accum dtype: {self.grad_accum_dtype}",
                 f"  optimizer       : {self.optimizer}",
                 f"  kernels         : {self.kernels}",
                 f"  seq parallel    : {self.sequence_parallel}",
                 f"  grad compression: {self.grad_compression}"]
        if self.serve_slots:
            per = " per replica" if self.serve_replicas > 1 else ""
            lines.append(f"  serve kv pool   : {self.serve_slots} slots "
                         f"x {self.serve_max_len}{per}")
        if self.serve_num_pages:
            per = " per replica" if self.serve_replicas > 1 else ""
            lines.append(f"  serve kv pages  : {self.serve_num_pages} pages "
                         f"x {self.serve_page_size} tokens (paged layout{per})")
        if self.serve_replicas > 1:
            lines.append(f"  serve replicas  : {self.serve_replicas} "
                         f"(HBM budget split per replica)")
        if self.serve_prefill_chunk:
            lines.append(f"  serve prefill   : {self.serve_prefill_chunk} "
                         f"tokens/chunk interleaved with decode ticks")
        if self.serve_prefix_cache_pages:
            lines.append(f"  serve prefix $  : up to "
                         f"{self.serve_prefix_cache_pages} pages LRU-pinned "
                         f"for shared-prefix reuse (paged layout)")
        if self.serve_kv_kernel:
            lines.append(f"  serve kv kernel : {self.serve_kv_kernel} "
                         f"(paged decode attention)")
        if self.serve_spec_k:
            lines.append(f"  serve spec k    : {self.serve_spec_k} draft "
                         f"tokens per verify step (draft-then-verify)")
        if self.serve_slo_ttft_steps or self.serve_slo_e2e_steps:
            lines.append(f"  serve SLO       : ttft <= "
                         f"{self.serve_slo_ttft_steps} vsteps, e2e <= "
                         f"{self.serve_slo_e2e_steps} vsteps "
                         f"(goodput deadlines, virtual step clock)")
        if self.napkin:
            lines.append("  napkin math:")
            for k, v in self.napkin.items():
                lines.append(f"    {k}: {v}")
        for n in self.notes:
            lines.append(f"  note: {n}")
        for f in self.sharding_fallbacks:
            lines.append(f"  sharding fallback: {f}")
        return "\n".join(lines)
