"""BuildService — the EASEY client's `docker build` analogue (§2.1).

    AppSpec (portable) + TargetSpec (local) --tune--> DeploymentPlan
        --lower--> SPMD program for the target mesh
        --package--> deployable artifact (core/package.py)

The directives in the Appfile are resolved here: ``###include_local_kernels###``
selects the Pallas vs reference compute library, ``###include_local_collectives###``
binds the sharding rules to the target mesh, ``###include_local_optimizer###``
lets the tuner swap the optimizer variant.  The lowered/compiled program is
the TPU equivalent of the Charliecloud image: portable spec in,
target-optimized executable out.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.core.appspec import AppSpec
from repro.core.plan import DeploymentPlan
from repro.core.target import TargetSpec, get_target
from repro.core.tuning import tune
from repro.launch.mesh import mesh_for_target
from repro.models.params import (partition_specs, shape_structs, _map_table,
                                 ParamDef)
from repro.models.transformer import model_for
from repro.optim import make_optimizer
from repro.sharding.rules import (DECODE_SEQ_CACHE_RULES, DEFAULT_RULES,
                                  SEQUENCE_PARALLEL_RULES)
from repro.training.steps import (build_decode_step, build_prefill_step,
                                  build_train_step, train_state_table)


@dataclasses.dataclass
class BuildResult:
    appspec: AppSpec
    target: TargetSpec
    plan: DeploymentPlan
    mesh: Any
    step_name: str
    step_fn: Callable
    lowered: Any = None
    compiled: Any = None
    in_structs: tuple = ()
    in_shardings: tuple = ()
    out_shardings: Any = None
    donate_argnums: tuple = ()
    tables: dict = dataclasses.field(default_factory=dict)
    timings: dict = dataclasses.field(default_factory=dict)

    @property
    def rules(self):
        return SEQUENCE_PARALLEL_RULES if self.plan.sequence_parallel \
            else DEFAULT_RULES


class BuildService:
    """Stateless builder; all outputs are in the BuildResult."""

    def build(self, appspec: AppSpec, target: TargetSpec | str,
              overrides: dict | None = None, lower: bool = True,
              compile_now: bool = False) -> BuildResult:
        t0 = time.perf_counter()
        if isinstance(target, str):
            target = get_target(target)
        cfg = appspec.model_config
        shape = appspec.shape_config
        if cfg.family == "stencil":
            return self._build_stencil(appspec, target, lower, t0)
        plan = tune(cfg, shape, target, overrides)
        # directive resolution (###include_local_kernels###)
        if "###include_local_kernels###" not in appspec.directives:
            plan.kernels = "reference"
            plan.notes.append("local-kernel directive absent -> reference ops")
        model = model_for(cfg, remat=plan.remat_policy)
        mesh = mesh_for_target(target)
        rules = SEQUENCE_PARALLEL_RULES if plan.sequence_parallel else DEFAULT_RULES
        opt = make_optimizer(plan.optimizer)
        t_tune = time.perf_counter()

        fallbacks: list[str] = []

        def specs(table):
            return partition_specs(table, mesh, rules, fallbacks)

        batch_table = model.batch_table(shape)
        if shape.kind == "train":
            state_table = train_state_table(model, opt, plan)
            state_specs = specs(state_table)
            step_fn = build_train_step(model, opt, plan, mesh,
                                       param_specs=state_specs["params"])
            in_structs = (shape_structs(state_table), shape_structs(batch_table))
            in_shardings = (state_specs, specs(batch_table))
            out_shardings = (in_shardings[0], None)
            donate = (0,)
            step_name = "train_step"
            tables = {"state": state_table, "batch": batch_table,
                      "params": model.param_table()}
        elif shape.kind == "prefill":
            param_table = model.param_table()
            cache_table = model.cache_table(shape.global_batch, shape.seq_len)
            step_fn = build_prefill_step(model, mesh)
            in_structs = (shape_structs(param_table), shape_structs(batch_table))
            in_shardings = (specs(param_table), specs(batch_table))
            out_shardings = (None, specs(cache_table))
            donate = ()
            step_name = "prefill_step"
            tables = {"params": param_table, "batch": batch_table,
                      "cache": cache_table}
        else:  # decode
            param_table = model.param_table()
            kv_len = shape.seq_len
            cache_table = model.cache_table(shape.global_batch, kv_len)
            step_fn = build_decode_step(model, mesh)
            in_structs = (shape_structs(param_table), shape_structs(cache_table),
                          shape_structs(batch_table)["tokens"])
            # perf iteration I1: kv_heads that don't divide the model axis
            # would replicate the cache 16x -> shard the cache seq axis
            # (flash-decode pattern) instead
            model_size = dict(zip(target.mesh_axes,
                                  target.mesh_shape)).get("model", 1)
            cache_rules = rules
            if cfg.num_kv_heads and cfg.num_kv_heads % model_size:
                cache_rules = DECODE_SEQ_CACHE_RULES
                plan.notes.append(
                    "I1: kv cache sharded on seq axis (kv_heads % model != 0)")
            cache_specs = partition_specs(cache_table, mesh, cache_rules,
                                          fallbacks)
            in_shardings = (specs(param_table), cache_specs,
                            specs(batch_table)["tokens"])
            out_shardings = (None, cache_specs)
            donate = (1,)
            step_name = "decode_step"
            tables = {"params": param_table, "batch": batch_table,
                      "cache": cache_table}

        plan.sharding_fallbacks = sorted(set(fallbacks))
        result = BuildResult(
            appspec=appspec, target=target, plan=plan, mesh=mesh,
            step_name=step_name, step_fn=step_fn, in_structs=in_structs,
            in_shardings=in_shardings, out_shardings=out_shardings,
            donate_argnums=donate, tables=tables)
        result.timings["tune_s"] = t_tune - t0

        if lower:
            t1 = time.perf_counter()
            jitted = jax.jit(step_fn, in_shardings=in_shardings,
                             out_shardings=out_shardings,
                             donate_argnums=donate)
            if shape.kind == "decode":
                lowered = jitted.lower(*in_structs)
            else:
                lowered = jitted.lower(*in_structs)
            result.lowered = lowered
            result.timings["lower_s"] = time.perf_counter() - t1
            if compile_now:
                t2 = time.perf_counter()
                result.compiled = lowered.compile()
                result.timings["compile_s"] = time.perf_counter() - t2
        return result

    def _build_stencil(self, appspec: AppSpec, target: TargetSpec,
                       lower: bool, t0: float) -> BuildResult:
        """LULESH-family build: the deployable unit is one fused hydro
        step on the target mesh (grid parsed from the RUN command)."""
        import re as _re
        from repro.models import lulesh as lu

        m = _re.search(r"-s\s+(\d+)", appspec.run)
        grid = int(m.group(1)) if m else 16
        plan = DeploymentPlan(
            arch=appspec.arch, shape=f"grid{grid}", target=target.name,
            mesh_shape=target.mesh_shape, mesh_axes=target.mesh_axes,
            kernels=target.kernels, remat_policy="none")
        plan.notes.append("stencil app: fields sharded (grid_x->data, "
                          "grid_y->model); dt via global all-reduce")
        mesh = mesh_for_target(target)
        cfg = lu.LuleshConfig(grid=grid)
        use_mesh = mesh if target.num_chips > 1 else None

        def step_fn(state):
            return lu.step(state, cfg, use_mesh)

        dt = jnp.float32
        structs = {"rho": jax.ShapeDtypeStruct((grid,) * 3, dt),
                   "e": jax.ShapeDtypeStruct((grid,) * 3, dt),
                   "v": jax.ShapeDtypeStruct((3, grid, grid, grid), dt),
                   "t": jax.ShapeDtypeStruct((), dt)}
        result = BuildResult(
            appspec=appspec, target=target, plan=plan, mesh=mesh,
            step_name="sedov_step", step_fn=step_fn,
            in_structs=(structs,), in_shardings=(None,),
            out_shardings=None, donate_argnums=(0,),
            tables={"state": structs})
        result.timings["tune_s"] = time.perf_counter() - t0
        if lower:
            t1 = time.perf_counter()
            result.lowered = jax.jit(step_fn, donate_argnums=(0,)).lower(structs)
            result.timings["lower_s"] = time.perf_counter() - t1
        return result

    # -- runnable path for local targets (smoke/examples/FOM benches) --
    def materialize(self, result: BuildResult, rng=None):
        """Initialize real weights/state for a runnable (small) config."""
        from repro.models.params import init_params
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        if result.step_name == "train_step":
            return init_params(result.tables["state"], rng)
        return init_params(result.tables["params"], rng)
