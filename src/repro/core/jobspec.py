"""The paper's four-part JSON job configuration (§3, Listings 1.1–1.5).

    {"job": {"name", "id", "mail"},
     "data": {"input": [{source, protocol, user, auth}],
              "output": [{destination, protocol, user, auth}],
              "mount": {"container-path"}},
     "deployment": {"nodes", "ram", "cores-per-task", "tasks-per-node",
                    "clocktime"},
     "execution": [{"serial": {"command"}} |
                   {"mpi": {"command", "mpi-tasks"}}]}

Faithfully parsed/validated here; the TPU deployment extension adds an
optional "easey" block (arch/shape/target) so the same file drives both the
paper's LULESH-style jobs and LM deployments.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any

PROTOCOLS = ("https", "scp", "ftp", "gridftp", "file")


@dataclasses.dataclass
class DataItem:
    source: str = ""
    destination: str = ""
    protocol: str = "file"
    user: str = ""
    auth: str = "publickey"

    def validate(self):
        if self.protocol not in PROTOCOLS:
            raise ValueError(f"unsupported protocol {self.protocol!r}")
        if self.protocol == "gridftp":
            raise NotImplementedError(
                "gridftp is planned for the next release (paper §3)")


@dataclasses.dataclass
class Deployment:
    nodes: int = 1
    ram: str = ""
    cores_per_task: int = 1
    tasks_per_node: int = 1
    clocktime: str = "01:00:00"


@dataclasses.dataclass
class Execution:
    kind: str = "serial"            # serial | mpi
    command: str = ""
    mpi_tasks: int = 0


@dataclasses.dataclass
class JobSpec:
    name: str
    job_id: str = ""
    mail: str = ""
    inputs: list = dataclasses.field(default_factory=list)
    outputs: list = dataclasses.field(default_factory=list)
    mount: str = "/data"
    deployment: Deployment = dataclasses.field(default_factory=Deployment)
    executions: list = dataclasses.field(default_factory=list)
    easey: dict = dataclasses.field(default_factory=dict)

    def ensure_id(self) -> str:
        """'a hash which is determined by the system at the moment of
        submission' (paper §3)."""
        if not self.job_id:
            # submission-moment entropy is the paper's spec — hash input,
            # never a metric
            payload = f"{self.name}:{time.time_ns()}"  # easeylint: allow[wall-clock]
            self.job_id = hashlib.sha256(payload.encode()).hexdigest()[:12]
        return self.job_id

    @property
    def has_data(self) -> bool:
        return bool(self.inputs or self.outputs)


def parse_jobspec(text_or_dict: str | dict) -> JobSpec:
    d = json.loads(text_or_dict) if isinstance(text_or_dict, str) else text_or_dict
    if "job" not in d:
        raise ValueError("missing required 'job' section")
    job = d["job"]
    spec = JobSpec(name=job.get("name", "easey-job"),
                   job_id=job.get("id", ""), mail=job.get("mail", ""))

    data = d.get("data", {})
    for item in data.get("input", []):
        di = DataItem(source=item.get("source", ""),
                      protocol=item.get("protocol", "file"),
                      user=item.get("user", ""), auth=item.get("auth", "publickey"))
        di.validate()
        spec.inputs.append(di)
    for item in data.get("output", []):
        do = DataItem(destination=item.get("destination", ""),
                      protocol=item.get("protocol", "file"),
                      user=item.get("user", ""), auth=item.get("auth", "publickey"))
        do.validate()
        spec.outputs.append(do)
    if "mount" in data:
        spec.mount = data["mount"].get("container-path", "/data")

    dep = d.get("deployment", {})
    spec.deployment = Deployment(
        nodes=int(dep.get("nodes", 1)), ram=str(dep.get("ram", "")),
        cores_per_task=int(dep.get("cores-per-task", 1)),
        tasks_per_node=int(dep.get("tasks-per-node", 1)),
        clocktime=str(dep.get("clocktime", "01:00:00")))

    for entry in d.get("execution", []):
        if "serial" in entry:
            spec.executions.append(Execution("serial", entry["serial"]["command"]))
        elif "mpi" in entry:
            spec.executions.append(Execution(
                "mpi", entry["mpi"]["command"],
                int(entry["mpi"].get("mpi-tasks", 1))))
        else:
            raise ValueError(f"execution entries must be serial|mpi: {entry}")

    spec.easey = d.get("easey", {})
    return spec


def lulesh_example() -> dict:
    """The paper's Listing 1.5 (LULESH:DASH on SuperMUC-NG), verbatim-shaped."""
    return {
        "job": {"name": "lulesh_dash", "mail": "hoeb@mnm-team.org"},
        "data": {},
        "deployment": {"nodes": 46, "tasks-per-node": 48,
                       "clocktime": "06:00:00"},
        "execution": [{
            "mpi": {
                "command": "ch-run -b ./data:/data lulesh.dash -- "
                           "/built/lulesh.dash -i 1000 -s 13",
                "mpi-tasks": 2197}}],
    }
