"""AppSpec — the portable application description (Dockerfile analogue).

The paper's EASEY client consumes a Dockerfile with injection hooks
(``###includelocalmpi###``).  Our client consumes an **Appfile**: a small
line-oriented spec naming the architecture, the input shape and the
execution, with the same hook mechanism — directives the BuildService
replaces with target-specific bricks:

    FROM arch:deepseek-7b
    SHAPE train_4k
    ###include_local_kernels###      <- swapped for the target's Pallas lib
    ###include_local_collectives###  <- target sharding rules / mesh axes
    RUN train --steps 50

An AppSpec can equally be constructed programmatically.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_config

KNOWN_DIRECTIVES = (
    "###include_local_kernels###",
    "###include_local_collectives###",
    "###include_local_optimizer###",
    "###includelocalmpi###",   # accepted for paper compatibility
)


@dataclasses.dataclass
class AppSpec:
    arch: str
    shape: str
    run: str = "train --steps 10"
    directives: tuple[str, ...] = KNOWN_DIRECTIVES[:3]
    overrides: dict = dataclasses.field(default_factory=dict)
    shape_overrides: dict = dataclasses.field(default_factory=dict)

    @property
    def model_config(self) -> ModelConfig:
        cfg = get_config(self.arch)
        return cfg.replace(**self.overrides) if self.overrides else cfg

    @property
    def shape_config(self) -> ShapeConfig:
        import dataclasses as dc
        sc = SHAPES[self.shape]
        return dc.replace(sc, **self.shape_overrides) if self.shape_overrides else sc

    def content_hash(self) -> str:
        payload = json.dumps(
            {"arch": self.arch, "shape": self.shape, "run": self.run,
             "directives": list(self.directives),
             "overrides": {k: str(v) for k, v in self.overrides.items()}},
            sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    def to_appfile(self) -> str:
        lines = [f"FROM arch:{self.arch}", f"SHAPE {self.shape}"]
        lines += list(self.directives)
        for k, v in self.overrides.items():
            lines.append(f"SET {k}={v}")
        lines.append(f"RUN {self.run}")
        return "\n".join(lines) + "\n"


def parse_appfile(text: str) -> AppSpec:
    arch = shape = None
    run = "train --steps 10"
    directives: list[str] = []
    overrides: dict = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#") and not line.startswith("###"):
            continue
        if line.startswith("###"):
            if line not in KNOWN_DIRECTIVES:
                raise ValueError(f"unknown directive {line!r}")
            directives.append(line)
        elif line.startswith("FROM "):
            ref = line[5:].strip()
            if not ref.startswith("arch:"):
                raise ValueError(f"FROM must reference arch:<name>, got {ref!r}")
            arch = ref[5:]
        elif line.startswith("SHAPE "):
            shape = line[6:].strip()
        elif line.startswith("SET "):
            k, v = line[4:].split("=", 1)
            try:
                overrides[k.strip()] = json.loads(v)
            except json.JSONDecodeError:
                overrides[k.strip()] = v.strip()
        elif line.startswith("RUN "):
            run = line[4:].strip()
        else:
            raise ValueError(f"unparseable Appfile line: {raw!r}")
    if arch is None or shape is None:
        raise ValueError("Appfile must contain FROM arch:<name> and SHAPE <name>")
    if shape not in SHAPES:
        raise ValueError(f"unknown shape {shape!r}; known: {sorted(SHAPES)}")
    return AppSpec(arch=arch, shape=shape, run=run,
                   directives=tuple(directives), overrides=overrides)
