"""Job state machine + LocalScheduler (paper §2.2: job management,
"pending/running/finished/failed + error log and standard output ...
also at an intermediate state").

The LocalScheduler stands in for SLURM inside this container: queued jobs
run on worker threads, status/logs are pollable mid-run, and the runtime
layer uses the same interface for failure injection and straggler
simulation.
"""

from __future__ import annotations

import dataclasses
import enum
import io
import threading
import time
import traceback
import uuid
from typing import Callable


class JobState(str, enum.Enum):
    PENDING = "pending"
    STAGING = "staging"
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"
    CANCELLED = "cancelled"


_VALID = {
    JobState.PENDING: {JobState.STAGING, JobState.RUNNING, JobState.CANCELLED,
                       JobState.FAILED},
    JobState.STAGING: {JobState.RUNNING, JobState.FAILED, JobState.CANCELLED},
    JobState.RUNNING: {JobState.FINISHED, JobState.FAILED, JobState.CANCELLED},
    JobState.FINISHED: set(),
    JobState.FAILED: {JobState.PENDING},   # requeue after failure (restart)
    JobState.CANCELLED: set(),
}


@dataclasses.dataclass
class Job:
    job_id: str
    name: str
    fn: Callable | None = None
    state: JobState = JobState.PENDING
    stdout: io.StringIO = dataclasses.field(default_factory=io.StringIO)
    stderr: io.StringIO = dataclasses.field(default_factory=io.StringIO)
    result: object = None
    # SLURM-stand-in bookkeeping; never feeds a gated metric
    submitted_at: float = dataclasses.field(default_factory=time.time)  # easeylint: allow[wall-clock]
    started_at: float = 0.0
    finished_at: float = 0.0
    restarts: int = 0

    def transition(self, new: JobState):
        if new not in _VALID[self.state]:
            raise ValueError(f"illegal transition {self.state} -> {new}")
        self.state = new

    def log(self, msg: str):
        self.stdout.write(msg.rstrip("\n") + "\n")

    @property
    def runtime(self) -> float:
        end = self.finished_at or time.time()  # easeylint: allow[wall-clock] — advisory job runtime
        return max(end - self.started_at, 0.0) if self.started_at else 0.0


class LocalScheduler:
    """In-process SLURM stand-in. submit() -> jobID; poll via status()."""

    def __init__(self, synchronous: bool = True):
        self.jobs: dict[str, Job] = {}
        self.synchronous = synchronous
        self._lock = threading.Lock()

    def submit(self, fn: Callable[[Job], object], name: str = "job") -> str:
        job_id = uuid.uuid4().hex[:12]
        job = Job(job_id=job_id, name=name, fn=fn)
        with self._lock:
            self.jobs[job_id] = job
        if self.synchronous:
            self._run(job)
        else:
            threading.Thread(target=self._run, args=(job,), daemon=True).start()
        return job_id

    def _run(self, job: Job):
        job.transition(JobState.RUNNING)
        job.started_at = time.time()  # easeylint: allow[wall-clock] — job metadata
        try:
            job.result = job.fn(job)
            job.transition(JobState.FINISHED)
        except Exception as e:  # noqa: BLE001 — job isolation is the point
            job.stderr.write("".join(traceback.format_exception(e)))
            job.transition(JobState.FAILED)
        finally:
            job.finished_at = time.time()  # easeylint: allow[wall-clock] — job metadata

    # -- paper §2.2 monitoring interface --
    def status(self, job_id: str) -> JobState:
        return self.jobs[job_id].state

    def logs(self, job_id: str) -> tuple[str, str]:
        j = self.jobs[job_id]
        return j.stdout.getvalue(), j.stderr.getvalue()

    def result(self, job_id: str):
        return self.jobs[job_id].result

    def requeue(self, job_id: str) -> str:
        """Restart a failed job (fault-tolerance path)."""
        old = self.jobs[job_id]
        if old.state is not JobState.FAILED:
            raise ValueError("only failed jobs can be requeued")
        old.transition(JobState.PENDING)
        old.restarts += 1
        self._run(old)
        return job_id

    def wait(self, job_id: str, timeout: float = 300.0) -> JobState:
        t0 = time.time()  # easeylint: allow[wall-clock] — real timeout on a host-side wait
        while time.time() - t0 < timeout:  # easeylint: allow[wall-clock]
            st = self.status(job_id)
            if st in (JobState.FINISHED, JobState.FAILED, JobState.CANCELLED):
                return st
            time.sleep(0.01)
        raise TimeoutError(job_id)
