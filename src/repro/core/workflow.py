"""End-to-end EASEY workflow (paper Fig. 2) + the `easey` CLI.

    user --Appfile+JobSpec--> CLIENT (build docker image -> charliecloud tar)
         --package--> MIDDLEWARE (stage, batch, submit) --> jobID
         --poll--> pending/running/finished + logs --> stage-out

`run_easey` wires BuildService -> write_package -> Middleware.submit with a
runner that executes the app's RUN command (train/serve/lulesh) through the
launch layer.
"""

from __future__ import annotations

import argparse
import json
import shlex
import tempfile
from pathlib import Path

from repro.core.appspec import AppSpec, parse_appfile
from repro.core.build import BuildService
from repro.core.jobspec import JobSpec, parse_jobspec
from repro.core.middleware import Middleware
from repro.core.package import write_package
from repro.core.target import get_target


def default_runner(job, workdir: Path, spec: JobSpec):
    """Execute the JobSpec's execution commands via the launch layer."""
    from repro.launch.run import run_command  # late import: launch -> core
    results = []
    for ex in spec.executions:
        job.log(f"$ {ex.command}")
        results.append(run_command(ex.command, job=job, workdir=workdir,
                                   spec=spec))
    return results


def run_easey(appspec: AppSpec, target_name: str, jobspec: JobSpec,
              storage: str | Path | None = None, execute: bool = True,
              overrides: dict | None = None):
    """build -> package -> stage -> submit -> wait. Returns (middleware,
    job_id, build_result)."""
    storage = Path(storage) if storage else Path(tempfile.mkdtemp(prefix="easey_"))
    target = get_target(target_name)
    svc = BuildService()
    result = svc.build(appspec, target, overrides=overrides, lower=True)
    pkg = write_package(result, storage / "packages")

    mw = Middleware(storage / "cluster")
    if execute:
        # bind the build result so the runner executes the REAL compiled step
        def runner(job, workdir, spec):
            from repro.launch.run import run_command
            outs = []
            for ex in spec.executions:
                job.log(f"$ {ex.command}")
                outs.append(run_command(ex.command, job=job, workdir=workdir,
                                        spec=spec, build_result=result))
            return outs
    else:
        runner = None
    job_id = mw.submit(pkg, jobspec, runner=runner,
                       scheduler_dialect=target.scheduler
                       if target.scheduler != "local" else "slurm")
    return mw, job_id, result


def _cli():
    p = argparse.ArgumentParser(prog="easey")
    sub = p.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("build", help="build an Appfile for a target "
                                     "(paper: easey build Dockerfile --target ...)")
    b.add_argument("appfile")
    b.add_argument("--target", required=True)
    b.add_argument("--out", default="./packages")

    s = sub.add_parser("submit", help="submit a package with a job config")
    s.add_argument("package")
    s.add_argument("--config", required=True)
    s.add_argument("--storage", default="./easey_cluster")

    r = sub.add_parser("run", help="build + submit + execute in one step")
    r.add_argument("appfile")
    r.add_argument("--target", required=True)
    r.add_argument("--config", required=True)

    args = p.parse_args()
    if args.cmd == "build":
        spec = parse_appfile(Path(args.appfile).read_text())
        res = BuildService().build(spec, args.target)
        pkg = write_package(res, args.out)
        print(f"built {pkg}")
        print(res.plan.report())
    elif args.cmd == "submit":
        spec = parse_jobspec(Path(args.config).read_text())
        mw = Middleware(args.storage)
        job_id = mw.submit(args.package, spec)
        print(f"jobID={job_id} state={mw.status(job_id).value}")
    elif args.cmd == "run":
        app = parse_appfile(Path(args.appfile).read_text())
        spec = parse_jobspec(Path(args.config).read_text())
        mw, job_id, _ = run_easey(app, args.target, spec)
        out, err = mw.logs(job_id)
        print(f"jobID={job_id} state={mw.status(job_id).value}")
        print(out)
        if err:
            print("STDERR:", err)


if __name__ == "__main__":
    _cli()
