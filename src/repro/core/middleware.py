"""EASEY Middleware (§2.2) — Algorithm 1, line for line.

    Require: Charliecloud tar-ball            -> .easey.tar package
    Require: EASEY configuration file         -> JobSpec (4-part JSON)
    Require: User credentials                 -> public-key stub
      Move tar-ball to cluster storage
      Extract tar-ball and create execution environment
      if data in configuration then mkdir data_folder
      while input in configuration do transfer input[source] to data_folder
      create batch_file
      for each deployment: parse to SLURM or PBS command in batch_file
      while execution in configuration do add command to batch_file
      submit batch_file to local scheduler and return jobID to EASEY

The batch file is really synthesized (core/batch.py); execution in this
container goes through the LocalScheduler with the same state machine and
monitoring interface the paper describes.
"""

from __future__ import annotations

import shutil
import urllib.parse
from pathlib import Path
from typing import Callable

from repro.core.batch import make_batch
from repro.core.jobs import Job, JobState, LocalScheduler
from repro.core.jobspec import DataItem, JobSpec
from repro.core.package import extract_package


class StageError(RuntimeError):
    pass


def _transfer(item: DataItem, dest: Path, direction: str = "in"):
    """Data service (§3): https/scp/ftp handled; gridftp next release.
    In this offline container all protocols resolve to local file copies;
    the handler validates the URL shape exactly as the real mover would."""
    src = item.source if direction == "in" else item.destination
    proto = item.protocol
    if proto in ("https", "scp", "ftp"):
        parsed = urllib.parse.urlparse(src if "://" in src else f"{proto}://{src}")
        if not parsed.path:
            raise StageError(f"malformed {proto} url: {src}")
        local = Path(parsed.path)
    elif proto == "file":
        local = Path(src)
    else:
        raise StageError(f"unsupported protocol {proto}")
    if direction == "in":
        if not local.exists():
            raise StageError(f"input not found: {local}")
        shutil.copy2(local, dest / local.name)
        return dest / local.name
    dest.mkdir(parents=True, exist_ok=True)
    return local


class Middleware:
    """Connects the EASEY client's package to the cluster scheduler."""

    def __init__(self, cluster_storage: str | Path,
                 scheduler: LocalScheduler | None = None):
        self.storage = Path(cluster_storage)
        self.storage.mkdir(parents=True, exist_ok=True)
        self.scheduler = scheduler or LocalScheduler()

    def submit(self, package_path: str | Path, spec: JobSpec,
               runner: Callable[[Job, Path, JobSpec], object] | None = None,
               scheduler_dialect: str = "slurm") -> str:
        """Algorithm 1. Returns the local jobID."""
        spec.ensure_id()
        workdir = self.storage / spec.job_id
        workdir.mkdir(parents=True, exist_ok=True)

        # 1. move tar-ball to cluster storage
        staged_pkg = workdir / Path(package_path).name
        shutil.copy2(package_path, staged_pkg)

        # 2. extract tar-ball, create execution environment
        env_dir = workdir / "env"
        manifest = extract_package(staged_pkg, env_dir)

        # 3-4. data folder + stage-in
        data_dir = workdir / "data"
        if spec.has_data:
            data_dir.mkdir(exist_ok=True)
            for item in spec.inputs:
                _transfer(item, data_dir, "in")

        # 5-7. synthesize the batch file
        batch = make_batch(spec, scheduler_dialect, workdir=str(workdir))
        (workdir / "batch.sh").write_text(batch)

        # 8. submit to the local scheduler -> jobID
        def job_fn(job: Job):
            job.log(f"EASEY job {spec.job_id} ({manifest['arch']} x "
                    f"{manifest['shape']} on {manifest['target']})")
            job.log(f"batch file: {workdir / 'batch.sh'}")
            if runner is None:
                job.log("no runner bound (dry deployment) — batch file only")
                return {"manifest": manifest, "batch": str(workdir / "batch.sh")}
            out = runner(job, workdir, spec)
            job.log("execution finished")
            return out

        job_id = self.scheduler.submit(job_fn, name=spec.name)
        # keep the paper's ID visible
        self.scheduler.jobs[job_id].log(f"scheduler jobID={job_id}")
        return job_id

    # -- monitoring (paper: status + stdout/stderr at intermediate state) --
    def status(self, job_id: str) -> JobState:
        return self.scheduler.status(job_id)

    def logs(self, job_id: str) -> tuple[str, str]:
        return self.scheduler.logs(job_id)

    def stage_out(self, job_id: str, spec: JobSpec):
        """'After the job ended EASEY will transfer output files if
        specified.'"""
        workdir = self.storage / spec.job_id
        out_paths = []
        for item in spec.outputs:
            dest = _transfer(item, workdir, "out")
            produced = workdir / "data"
            if produced.exists():
                for f in produced.iterdir():
                    shutil.copy2(f, dest / f.name if dest.is_dir() else dest)
            out_paths.append(dest)
        return out_paths
