"""Package — the `ch-builder2tar` analogue (§2.1).

A deployable EASEY artifact is a tarball:

    manifest.json        app hash, arch, shape, target, step name, timings
    plan.json            the DeploymentPlan (tuning decisions)
    tuning_report.txt    human-readable report
    Appfile              the portable spec that produced the build
    module.stablehlo.gz  lowered StableHLO for the target mesh

The StableHLO module plays the role of the container image: it is the
exact program that will run on the target, produced without the user ever
touching target-specific code.
"""

from __future__ import annotations

import gzip
import hashlib
import io
import json
import tarfile
import time
from pathlib import Path

from repro.core.build import BuildResult


def write_package(result: BuildResult, out_dir: str | Path) -> Path:
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    app = result.appspec
    name = f"{app.arch}__{app.shape}__{result.target.name.replace(':', '_')}"
    path = out_dir / f"{name}.easey.tar"

    hlo_text = result.lowered.as_text() if result.lowered is not None else ""
    hlo_gz = gzip.compress(hlo_text.encode())
    manifest = {
        "app_hash": app.content_hash(),
        "arch": app.arch,
        "shape": app.shape,
        "target": result.target.name,
        "step": result.step_name,
        "built_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "timings": result.timings,
        "hlo_sha256": hashlib.sha256(hlo_gz).hexdigest(),
        "mesh": {"shape": list(result.target.mesh_shape),
                 "axes": list(result.target.mesh_axes)},
    }

    def add(tar, arcname: str, data: bytes):
        info = tarfile.TarInfo(arcname)
        info.size = len(data)
        tar.addfile(info, io.BytesIO(data))

    with tarfile.open(path, "w") as tar:
        add(tar, "manifest.json", json.dumps(manifest, indent=2).encode())
        add(tar, "plan.json", result.plan.to_json().encode())
        add(tar, "tuning_report.txt", result.plan.report().encode())
        add(tar, "Appfile", app.to_appfile().encode())
        add(tar, "module.stablehlo.gz", hlo_gz)
    return path


def read_manifest(path: str | Path) -> dict:
    with tarfile.open(path) as tar:
        return json.loads(tar.extractfile("manifest.json").read())


def extract_package(path: str | Path, workdir: str | Path) -> dict:
    """Algorithm 1: 'Extract tar-ball and create execution environment'."""
    workdir = Path(workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    with tarfile.open(path) as tar:
        tar.extractall(workdir, filter="data")
    manifest = json.loads((workdir / "manifest.json").read_text())
    # integrity check against the manifest hash
    hlo_gz = (workdir / "module.stablehlo.gz").read_bytes()
    if hashlib.sha256(hlo_gz).hexdigest() != manifest["hlo_sha256"]:
        raise ValueError("package integrity check failed (hlo hash mismatch)")
    return manifest
