"""TargetSpec registry — the `--target lrz:supermuc-ng` analogue (§2.1).

A TargetSpec captures everything the AutoTuner needs to inject
target-specific building bricks: chip roofline constants, HBM capacity,
mesh topology, the local scheduler dialect, and which kernel library the
target supports.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class TargetSpec:
    name: str
    chip: str                       # tpu-v5e | cpu
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    peak_flops: float               # per chip, bf16
    hbm_bw: float                   # bytes/s per chip
    hbm_bytes: float                # capacity per chip
    ici_bw: float                   # bytes/s per link
    scheduler: str = "slurm"        # slurm | pbs | local
    kernels: str = "pallas"         # pallas | reference
    # per-core VMEM capacity: the static budget Pallas block + scratch
    # shapes are linted against (analysis/lint vmem-budget rule).  CPU
    # targets keep the v5e figure — interpret-mode kernels must fit the
    # real accelerator they are rehearsing for.
    vmem_bytes: float = 128 * 2**20
    description: str = ""

    @property
    def num_chips(self) -> int:
        return math.prod(self.mesh_shape)


# TPU v5e constants (per assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI, 16 GB HBM.
_V5E = dict(peak_flops=197e12, hbm_bw=819e9, hbm_bytes=16e9, ici_bw=50e9)

TARGETS: dict[str, TargetSpec] = {}


def register(t: TargetSpec) -> TargetSpec:
    TARGETS[t.name] = t
    return t


register(TargetSpec(
    name="lrz:tpu-v5e-pod", chip="tpu-v5e",
    mesh_shape=(16, 16), mesh_axes=("data", "model"),
    scheduler="slurm", kernels="pallas",
    description="single v5e pod, 256 chips, 16x16 (data, model)", **_V5E))

register(TargetSpec(
    name="lrz:tpu-v5e-2pod", chip="tpu-v5e",
    mesh_shape=(2, 16, 16), mesh_axes=("pod", "data", "model"),
    scheduler="slurm", kernels="pallas",
    description="two v5e pods, 512 chips, pod axis is pure DP", **_V5E))

register(TargetSpec(
    name="local:cpu", chip="cpu",
    mesh_shape=(1,), mesh_axes=("data",),
    peak_flops=5e10, hbm_bw=2e10, hbm_bytes=8e9, ici_bw=1e9,
    scheduler="local", kernels="reference",
    description="single-process CPU debug target (smoke tests, examples)"))

register(TargetSpec(
    name="local:cpu-mesh8", chip="cpu",
    mesh_shape=(2, 4), mesh_axes=("data", "model"),
    peak_flops=5e10, hbm_bw=2e10, hbm_bytes=8e9, ici_bw=1e9,
    scheduler="local", kernels="reference",
    description="8 forced host devices — integration tests of the SPMD path"))


def get_target(name: str) -> TargetSpec:
    if name not in TARGETS:
        raise KeyError(f"unknown target {name!r}; known: {sorted(TARGETS)}")
    return TARGETS[name]
