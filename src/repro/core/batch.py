"""Batch-file synthesis for SLURM and PBS (paper §2.2, Algorithm 1:
"create batch_file; for each deployment parse to SLURM or PBS command").

Pure text generation — golden-tested.  Real TPU pods sit behind the same
schedulers, so this transfers unchanged; the LocalScheduler executes the
equivalent in-process for this container.
"""

from __future__ import annotations

from repro.core.jobspec import JobSpec


def slurm_batch(spec: JobSpec, workdir: str = "$EASEY_WORKDIR") -> str:
    d = spec.deployment
    lines = [
        "#!/bin/bash",
        f"#SBATCH --job-name={spec.name}",
        f"#SBATCH --nodes={d.nodes}",
        f"#SBATCH --ntasks-per-node={d.tasks_per_node}",
        f"#SBATCH --cpus-per-task={d.cores_per_task}",
        f"#SBATCH --time={d.clocktime}",
    ]
    if d.ram:
        lines.append(f"#SBATCH --mem={d.ram}")
    if spec.mail:
        lines += [f"#SBATCH --mail-user={spec.mail}",
                  "#SBATCH --mail-type=END,FAIL"]
    lines += ["", f"cd {workdir}"]
    if spec.has_data:
        lines.append("mkdir -p data")
    for ex in spec.executions:
        if ex.kind == "mpi":
            lines.append(f"srun --ntasks={ex.mpi_tasks} {ex.command}")
        else:
            lines.append(ex.command)
    return "\n".join(lines) + "\n"


def pbs_batch(spec: JobSpec, workdir: str = "$EASEY_WORKDIR") -> str:
    d = spec.deployment
    lines = [
        "#!/bin/bash",
        f"#PBS -N {spec.name}",
        f"#PBS -l nodes={d.nodes}:ppn={d.tasks_per_node}",
        f"#PBS -l walltime={d.clocktime}",
    ]
    if d.ram:
        lines.append(f"#PBS -l mem={d.ram}")
    if spec.mail:
        lines += [f"#PBS -M {spec.mail}", "#PBS -m ae"]
    lines += ["", f"cd {workdir}"]
    if spec.has_data:
        lines.append("mkdir -p data")
    for ex in spec.executions:
        if ex.kind == "mpi":
            lines.append(f"mpirun -np {ex.mpi_tasks} {ex.command}")
        else:
            lines.append(ex.command)
    return "\n".join(lines) + "\n"


def make_batch(spec: JobSpec, scheduler: str, workdir: str = "$EASEY_WORKDIR") -> str:
    if scheduler == "slurm":
        return slurm_batch(spec, workdir)
    if scheduler == "pbs":
        return pbs_batch(spec, workdir)
    if scheduler == "local":
        return "\n".join(["#!/bin/bash"] + [e.command for e in spec.executions]) + "\n"
    raise ValueError(f"unsupported scheduler {scheduler!r} "
                     "(paper: 'other scheduler are not supported so far')")
