"""Logical-axis sharding rules with divisibility fallback.

This is the sharding half of the EASEY AutoTuner (paper §2.1): a portable
model declares *logical* axis names on every parameter / activation
dimension, and the deployment layer maps them onto the *target* mesh.  The
mapping is target-dependent (the paper's ``###includelocalmpi###`` idea):
the same AppSpec deploys onto a 16x16 single pod, a 2x16x16 multi-pod or a
1-device debug CPU mesh, and the rules engine silently drops mesh axes that
do not divide the concrete dimension (e.g. 8 KV heads on a 16-way model
axis fall back to replication), recording every fallback for the tuning
report.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis vocabulary.
#   parameters:  "embed", "mlp", "heads", "kv_heads", "head_dim", "vocab",
#                "experts", "layers", "conv", "state"
#   activations: "act_batch", "act_seq", "act_embed", "act_heads",
#                "act_kv_heads", "act_vocab", "act_experts"
LOGICAL_AXES = (
    "embed", "mlp", "heads", "kv_heads", "head_dim", "vocab", "experts",
    "layers", "conv", "state", "vocab_in", "embed_feat",
    "act_batch", "act_seq", "act_embed", "act_heads", "act_kv_heads",
    "act_vocab", "act_experts", "act_state", "act_mlp",
    # LULESH / stencil domain axes
    "grid_x", "grid_y", "grid_z", "act_grid_x", "act_grid_y", "act_grid_z",
)


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis -> tuple of candidate mesh axes (priority order).

    Each logical axis may list several mesh axes; they are applied jointly
    (PartitionSpec tuple entry) when all of them divide the dimension and
    none has been consumed by an earlier dimension of the same spec.
    """

    rules: Mapping[str, tuple[str, ...]]

    def get(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(self.rules.get(logical, ()))

    def replace(self, **updates: tuple[str, ...]) -> "AxisRules":
        merged = dict(self.rules)
        merged.update(updates)
        return AxisRules(rules=merged)


# Baseline rules for the production meshes ("pod", "data", "model").
# FSDP: parameter "embed" dim over the data axis (ZeRO-3 storage sharding);
# TP: "mlp"/"heads"/"vocab" over the model axis; DP: batch over (pod, data).
DEFAULT_RULES = AxisRules(rules={
    "embed": ("data",),
    "vocab_in": (),            # input embedding: vocab replicated (I3)
    "embed_feat": ("model",),  # input embedding: features TP-sharded (I3)
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
    "conv": (),
    "state": (),
    "act_batch": ("pod", "data"),
    "act_seq": (),
    "act_embed": (),
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_vocab": ("model",),
    "act_experts": ("model",),
    "act_state": (),
    "act_mlp": ("model",),
    "grid_x": ("data",),
    "grid_y": ("model",),
    "grid_z": (),
    "act_grid_x": ("data",),
    "act_grid_y": ("model",),
    "act_grid_z": (),
})

# Sequence-parallel variant: long activations sharded along the model axis.
SEQUENCE_PARALLEL_RULES = DEFAULT_RULES.replace(
    act_seq=("model",),
    act_heads=(),
    act_kv_heads=(),
)

# Decode-cache variant (perf iteration I1, EXPERIMENTS.md §Perf): when
# num_kv_heads doesn't divide the model axis the default rules replicate
# the KV cache 16x; sharding the cache SEQUENCE axis instead distributes
# it and turns decode attention into a ring/flash-decode pattern (partial
# softmax + small all-reduces).
DECODE_SEQ_CACHE_RULES = DEFAULT_RULES.replace(
    act_seq=("model",),
    act_kv_heads=(),
    act_heads=(),
)


def logical_to_spec(
    logical_axes: Sequence[str | None],
    dim_sizes: Sequence[int],
    mesh: Mesh,
    rules: AxisRules,
    fallbacks: list[str] | None = None,
) -> P:
    """Translate per-dimension logical axes into a PartitionSpec.

    A mesh axis is used on a dimension only if (a) it exists on the mesh,
    (b) it has not been consumed by an earlier dimension of this spec, and
    (c) the product of chosen axis sizes divides the dimension size.  Axes
    failing (c) are dropped (replication fallback) and reported.
    """
    if len(logical_axes) != len(dim_sizes):
        raise ValueError(
            f"logical axes {logical_axes} do not match rank {len(dim_sizes)}")
    used: set[str] = set()
    entries: list = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for logical, dim in zip(logical_axes, dim_sizes):
        chosen: list[str] = []
        prod = 1
        for mesh_axis in rules.get(logical):
            if mesh_axis not in axis_sizes or mesh_axis in used:
                continue
            nxt = prod * axis_sizes[mesh_axis]
            if dim % nxt == 0:
                chosen.append(mesh_axis)
                prod = nxt
            elif fallbacks is not None:
                fallbacks.append(
                    f"{logical}:{mesh_axis} dropped (dim {dim} % {nxt} != 0)")
        used.update(chosen)
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    return P(*entries)


def spec_for(
    logical_axes: Sequence[str | None],
    dim_sizes: Sequence[int],
    mesh: Mesh,
    rules: AxisRules | None = None,
) -> NamedSharding:
    rules = rules or DEFAULT_RULES
    return NamedSharding(mesh, logical_to_spec(logical_axes, dim_sizes, mesh, rules))


def shard_constraint(x: jax.Array, logical_axes: Sequence[str | None],
                     mesh: Mesh | None, rules: AxisRules | None = None):
    """with_sharding_constraint by logical axes; no-op off-mesh.

    Used inside model code so the same definition runs on a laptop (mesh is
    None -> identity) and on the production mesh (constraint applied).
    """
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return x
    rules = rules or DEFAULT_RULES
    spec = logical_to_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
