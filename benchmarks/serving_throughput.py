"""Serving throughput: static (gang-scheduled) vs continuous batching.

One engine, one Zipf-length request trace (heavy-tailed prompts and
generation lengths — the regime real serving traffic lives in), both
scheduling policies over the same jitted steps and KV pool shape.  The
paper's claim transfers: auto-derived deployment parameters (here: the
KV pool and in-flight batching) give the optimized run "with negligible
overhead" vs the naive static deployment.

Reports tokens/sec for both policies, the speedup, and the decode-step
counts (deterministic for the fixed trace, so the speedup is explainable:
static burns steps waiting for each batch's longest request).
"""

from __future__ import annotations

import time

SLOTS = 8
MAX_LEN = 128
N_REQUESTS = 32
TRACE_SEED = 0


def _setup():
    from repro.serving import ServeEngine, zipf_trace
    engine = ServeEngine(arch="deepseek-7b-smoke", target="local:cpu",
                         num_slots=SLOTS, max_len=MAX_LEN, seed=0,
                         log=lambda *a, **k: None)
    reqs = zipf_trace(N_REQUESTS, engine.cfg.vocab_size, max_prompt=48,
                      max_new=64, alpha=1.3, seed=TRACE_SEED)
    return engine, reqs


def run(report) -> None:
    engine, reqs = _setup()
    # warm ALL jit caches the trace will touch (every prompt-length bucket
    # compiles its own prefill/insert) so neither timed run pays compile
    engine.run(reqs, policy="continuous")

    t0 = time.perf_counter()
    static = engine.run(reqs, policy="static")
    t_static = time.perf_counter() - t0
    t0 = time.perf_counter()
    cont = engine.run(reqs, policy="continuous")
    t_cont = time.perf_counter() - t0

    speedup = cont.tokens_per_s / max(static.tokens_per_s, 1e-9)
    report("serve_static_batching",
           t_static / max(static.decode_steps, 1) * 1e6,
           f"{static.tokens_per_s:.1f} tok/s; {static.decode_steps} steps; "
           f"occupancy {static.occupancy:.0%}")
    report("serve_continuous_batching",
           t_cont / max(cont.decode_steps, 1) * 1e6,
           f"{cont.tokens_per_s:.1f} tok/s; {cont.decode_steps} steps; "
           f"occupancy {cont.occupancy:.0%}; speedup {speedup:.2f}x")


def main():
    def report(name, us, derived=""):
        print(f"{name},{us:.3f},{derived}")
    print("name,us_per_call,derived")
    run(report)


if __name__ == "__main__":
    main()
