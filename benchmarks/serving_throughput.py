"""Serving throughput: scheduling policies x KV memory layouts x replicas.

Three comparisons over the same jitted steps and seeded Zipf traces
(heavy-tailed prompt and generation lengths — the regime real serving
traffic lives in):

1. **static vs continuous** (PR 1): gang scheduling burns decode steps
   waiting for each batch's longest request; continuous batching refills
   freed slots between steps (~2x on the Zipf trace).
2. **contiguous vs paged KV** (PR 2): under the same tuner HBM budget
   — enforced with a deliberately tight benchmark target — the
   contiguous layout reserves slots x max_len worst cases and gets its
   slot count capped, while the paged layout spends the budget on pages
   and admits requests by *actual* tokens: strictly more in flight, and
   fewer HBM bytes per admitted token.
3. **router vs single engine** (PR 3): the same tight-budget Zipf
   trace through a ``least_loaded`` ``ReplicaRouter`` over ``FLEET``
   tight replicas vs one tight engine — fleet tok/s, aggregate
   in-flight, and load imbalance (max/mean peak resident tokens).
4. **blocking vs chunked prefill** (PR 4): a long-prompt-heavy trace
   (``longprompt_trace`` — the prefill-stall regime) through the same
   fleet with prompt ingestion blocking at dispatch vs chunked and
   interleaved with decode ticks.  Compared on the deterministic
   **TTFT step proxy** (virtual clock: one unit per jitted invocation,
   blocking prefills priced serially at their chunk-equivalents, round
   cost = busiest replica) — chunked must be strictly lower.
5. **cold vs prefix-cached shared prefixes** (PR 5): a trace whose
   prompts open with Zipf-clustered shared heads (``sharedprefix_trace``)
   through a paged ``prefix_affinity`` fleet with the shared-prefix KV
   cache off vs on.  The cached fleet must prefill strictly fewer
   prompt tokens (hit rate > 0) while emitting bit-identical token
   streams — reuse is free or it is a bug.
6. **gather vs fused-kernel paged decode** (PR 6): the same tight
   paged trace with ``kv_kernel='pallas'`` — the fused Pallas
   paged-attention kernel walking the page table in-kernel instead of
   materializing the (slots, max_pages*page_size, K, dh) gather each
   tick.  Gated to be token-identical to the gather cell; wall time on
   CPU is interpret-mode emulation (the bytes-moved win is quoted by
   ``benchmarks/kernel_bench.py``'s ``kernel_paged_decode_*`` cells).
7. **spec-off vs draft-then-verify decode** (this PR): a repetitive
   greedy trace (``repetitive_trace`` over the 4-token-vocab
   ``picolm-4-smoke``, whose streams settle into n-gram-predictable
   cycles — the stand-in for template/boilerplate traffic) through the
   same paged engine with ``spec_k=0`` vs ``spec_k=4``.  Gated on
   bit-identical token streams AND accepted-tokens/verify-step > 1 —
   the spec path must buy multi-token ticks or it is dead weight.
8. **fixed vs autoscaling fleet under open-loop Poisson traffic**
   (this PR): the same Zipf trace stamped with Poisson
   ``arrival_vstep``s (exponential gaps on the VIRTUAL step clock —
   never wall time) through a 1-replica router vs an autoscaling
   1..``FLEET`` router with a TTFT SLO.  Gated on bit-identical
   streams vs the closed-loop replay of the same trace (arrival
   timing moves latency, never sampling) AND the autoscaler strictly
   beating the fixed fleet on both goodput-under-SLO and p99 TTFT
   (vsteps).  The regression gate then guards ``p99_ttft_steps``
   (ceiling) and ``goodput_tokens`` (floor) — wall-clock never enters
   an SLO metric.

The layout x policy grid cells run with ``prefill_chunk=0`` (blocking)
so their decode-step counts stay comparable across baselines; the
``longprompt_*`` cells carry the chunked-prefill trajectory.

``--smoke`` runs a tiny version of the full grid and writes
``BENCH_serving.json`` with tokens/sec and HBM-bytes-per-admitted-token
per cell plus the fleet metrics, so CI tracks the perf trajectory;
``--check-baseline`` additionally fails if any cell's throughput
regressed more than ``REGRESSION_TOLERANCE`` vs the checked-in baseline
— enforced on deterministic tokens-per-decode-step AND the TTFT step
proxy (the components of latency/throughput the code controls;
wall-clock on shared CI runners swings with load and is advisory only).
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

SLOTS = 8
MAX_LEN = 128
N_REQUESTS = 32
TRACE_SEED = 0
TIGHT_SLOTS = 3          # contiguous slots the tight target affords
FLEET = 3                # router replicas in the fleet comparison
REGRESSION_TOLERANCE = 0.20   # max fractional tok/s drop vs baseline
ARCH = "deepseek-7b-smoke"
SPEC_ARCH = "picolm-4-smoke"  # 4-token-vocab probe: n-gram-predictable
#                               greedy streams, the spec-decode regime
SPEC_K = 4               # draft tokens per verify step in the spec cells
OPENLOOP_GAP = 6.0       # mean Poisson inter-arrival gap, virtual steps
OPENLOOP_SEED = 3        # arrival-process seed (trace seed stays TRACE_SEED)
OPENLOOP_SLO_TTFT = 20   # TTFT goodput deadline, vsteps — sits between the
#                          autoscaled and fixed fleets' p99 so the goodput
#                          separation the autoscaler buys is visible
OPENLOOP_SLO_E2E = 120   # end-to-end goodput deadline, vsteps


def _kv_token_bytes(cfg) -> int:
    from repro.core.tuning import kv_bytes_per_token
    return kv_bytes_per_token(cfg)


def _register_tight_target(max_len: int = MAX_LEN) -> str:
    """A CPU target whose HBM budget affords only TIGHT_SLOTS worst-case
    contiguous slots — the regime where the paged layout's
    tokens-not-worst-cases accounting shows up."""
    from repro.configs.base import get_config
    from repro.core.target import TARGETS, TargetSpec, register
    from repro.core.tuning import param_count_estimate

    name = "bench:serve-tight"
    if name in TARGETS:
        return name
    cfg = get_config(ARCH)
    param_bytes = 2 * param_count_estimate(cfg)
    kv_budget = (TIGHT_SLOTS + 0.5) * _kv_token_bytes(cfg) * max_len
    register(TargetSpec(
        name=name, chip="cpu", mesh_shape=(1,), mesh_axes=("data",),
        peak_flops=5e10, hbm_bw=2e10,
        hbm_bytes=(param_bytes + kv_budget) / 0.85, ici_bw=1e9,
        scheduler="local", kernels="reference",
        description=f"serving-bench budget target: ~{TIGHT_SLOTS} "
                    f"contiguous slots x {max_len}"))
    return name


def _engine(kv_layout: str, target: str = "local:cpu", slots: int = SLOTS,
            max_len: int = MAX_LEN, kv_kernel: str = "auto"):
    from repro.serving import ServeEngine
    return ServeEngine(arch=ARCH, target=target, num_slots=slots,
                       max_len=max_len, seed=0, kv_layout=kv_layout,
                       kv_kernel=kv_kernel, log=lambda *a, **k: None)


def _pool_bytes(engine) -> int:
    cfg = engine.cfg
    tok = _kv_token_bytes(cfg)
    if engine.kv_layout == "paged":
        return engine.num_pages * engine.page_size * tok
    return engine.num_slots * engine.max_len * tok


def _trace(n: int, engine, max_new: int = 64, seed: int = TRACE_SEED):
    from repro.serving import zipf_trace
    return zipf_trace(n, engine.cfg.vocab_size, max_prompt=48,
                      max_new=max_new, alpha=1.3, seed=seed)


def _router(engine, fleet: int = FLEET, policy: str = "least_loaded"):
    """A fleet of `fleet` replicas of `engine` — the same object repeated,
    so the jitted steps compile once and only the pools are per-replica
    (each replica models a host with the engine's full HBM budget)."""
    from repro.serving import ReplicaRouter
    return ReplicaRouter([engine] * fleet, policy=policy,
                         log=lambda *a, **k: None)


def _bytes_per_token(engine, stats) -> float:
    """Pool HBM bytes per admitted *resident* token at peak occupancy —
    the over-reservation metric: a contiguous pool pins max_len per
    request however short it is, so its peak resident tokens stay far
    below capacity and the ratio stays high."""
    return _pool_bytes(engine) / max(stats.peak_resident_tokens, 1)


def _longprompt(n: int, engine, max_new: int = 8, seed: int = TRACE_SEED):
    """Prompts clustered near max_len, short generations — the regime
    where admission-time prefill stalls dominate."""
    from repro.serving import longprompt_trace
    return longprompt_trace(n, engine.cfg.vocab_size, max_prompt=MAX_LEN,
                            max_new=max_new, seed=seed)


def _sharedprefix(n: int, engine, seed: int = TRACE_SEED):
    """Prompts opening with Zipf-clustered shared heads (two 16-token
    pages each) — the regime where prefix-cache page reuse shows up."""
    from repro.serving import sharedprefix_trace
    return sharedprefix_trace(n, engine.cfg.vocab_size, seed=seed)


def _spec_engine(target: str = "local:cpu"):
    """A paged engine on the 4-token-vocab probe arch — the only extra
    compile the spec cells pay (picolm shares deepseek-7b-smoke's layer
    shapes except the tiny vocab head)."""
    from repro.serving import ServeEngine
    return ServeEngine(arch=SPEC_ARCH, target=target, num_slots=4,
                       max_len=MAX_LEN, seed=0, kv_layout="paged",
                       log=lambda *a, **k: None)


def _repetitive(n: int, engine, max_new: int = 48, seed: int = TRACE_SEED):
    """Short cyclic prompts, long greedy generations — the regime where
    the n-gram drafter's accepted-tokens/verify-step clears 1."""
    from repro.serving import repetitive_trace
    return repetitive_trace(n, engine.cfg.vocab_size, max_new=max_new,
                            seed=seed)


def _num(x, nd: int = 4):
    """Round for the JSON emitter; NaN (e.g. imbalance of an idle fleet)
    becomes None — valid strict JSON instead of a bare NaN literal."""
    return None if x != x else round(x, nd)


def _timed(fn, *args, **kwargs):
    """Run ``fn(*args, **kwargs)`` under the wall clock; returns
    ``(result, seconds)`` — the one timing idiom every cell shares."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0


def run(report) -> None:
    engine = _engine("contiguous")
    reqs = _trace(N_REQUESTS, engine)
    # warm ALL jit caches the trace will touch (every prompt-length bucket
    # compiles its own prefill/insert) so neither timed run pays compile
    engine.run(reqs, policy="continuous")

    static, t_static = _timed(engine.run, reqs, policy="static")
    cont, t_cont = _timed(engine.run, reqs, policy="continuous")

    speedup = cont.tokens_per_s / max(static.tokens_per_s, 1e-9)
    report("serve_static_batching",
           t_static / max(static.decode_steps, 1) * 1e6,
           f"{static.tokens_per_s:.1f} tok/s; {static.decode_steps} steps; "
           f"occupancy {static.occupancy:.0%}")
    report("serve_continuous_batching",
           t_cont / max(cont.decode_steps, 1) * 1e6,
           f"{cont.tokens_per_s:.1f} tok/s; {cont.decode_steps} steps; "
           f"occupancy {cont.occupancy:.0%}; speedup {speedup:.2f}x")

    # --- long-tail layout comparison under one tight HBM budget ----------
    tight = _register_tight_target()
    e_cont = _engine("contiguous", target=tight)
    e_paged = _engine("paged", target=tight)
    ltrace = _trace(N_REQUESTS, e_cont)
    e_cont.run(ltrace, policy="continuous")       # warm
    e_paged.run(ltrace, policy="continuous")
    s_cont, t_c = _timed(e_cont.run, ltrace, policy="continuous")
    s_paged, t_p = _timed(e_paged.run, ltrace, policy="continuous")
    report("serve_contiguous_tight_budget",
           t_c / max(s_cont.decode_steps, 1) * 1e6,
           f"{s_cont.tokens_per_s:.1f} tok/s; {e_cont.num_slots} slots; "
           f"peak {s_cont.peak_active} in flight; "
           f"{_bytes_per_token(e_cont, s_cont):.0f} B/admitted-token")
    report("serve_paged_tight_budget",
           t_p / max(s_paged.decode_steps, 1) * 1e6,
           f"{s_paged.tokens_per_s:.1f} tok/s; {e_paged.num_slots} slots; "
           f"peak {s_paged.peak_active} in flight "
           f"(+{s_paged.peak_active - s_cont.peak_active} vs contiguous); "
           f"{_bytes_per_token(e_paged, s_paged):.0f} B/admitted-token; "
           f"{s_paged.preemptions} preemptions")

    # --- router over a fleet of tight replicas vs the single engine ------
    router = _router(e_cont)
    s_fleet, t_f = _timed(router.run, ltrace, policy="continuous")
    steps = max(max(s.decode_steps for s in s_fleet.replica_stats), 1)
    report("serve_router_least_loaded_fleet",
           t_f / steps * 1e6,
           f"{s_fleet.tokens_per_s:.1f} tok/s fleet over "
           f"{FLEET} replicas (single: {s_cont.tokens_per_s:.1f}); peak "
           f"{s_fleet.peak_in_flight} in flight "
           f"({s_fleet.peak_in_flight / max(s_cont.peak_active, 1):.1f}x "
           f"single); imbalance {s_fleet.imbalance:.2f}; "
           f"{s_fleet.reroutes} reroutes")

    # --- blocking vs chunked prefill on the long-prompt trace ------------
    ptrace = _longprompt(N_REQUESTS, e_cont)
    router.run(ptrace, policy="continuous", prefill_chunk=0)      # warm
    router.run(ptrace, policy="continuous")
    p_block, t_b = _timed(router.run, ptrace, policy="continuous",
                          prefill_chunk=0)
    p_chunk, t_c2 = _timed(router.run, ptrace, policy="continuous")
    report("serve_longprompt_router_blocking", t_b * 1e6,
           f"mean TTFT {p_block.mean_ttft_steps:.1f} vsteps; "
           f"{p_block.tokens_per_s:.1f} tok/s fleet")
    report("serve_longprompt_router_chunked", t_c2 * 1e6,
           f"mean TTFT {p_chunk.mean_ttft_steps:.1f} vsteps "
           f"({p_block.mean_ttft_steps / max(p_chunk.mean_ttft_steps, 1e-9):.2f}x "
           f"lower); {p_chunk.tokens_per_s:.1f} tok/s fleet; "
           f"{p_chunk.prefill_chunks} chunks, "
           f"{p_chunk.overlap_steps} overlapped ticks")

    # --- shared-prefix trace: cold vs prefix-cached paged fleet ----------
    sp_router = _router(e_paged, policy="prefix_affinity")
    strace = _sharedprefix(N_REQUESTS, e_paged)
    sp_router.run(strace)                                         # warm
    sp_router.run(strace, prefix_cache=True)
    sp_cold, t_sc = _timed(sp_router.run, strace)
    sp_hot, t_sh = _timed(sp_router.run, strace, prefix_cache=True)
    report("serve_sharedprefix_router_cold", t_sc * 1e6,
           f"{sp_cold.prefill_tokens} prompt tokens prefilled; "
           f"mean TTFT {sp_cold.mean_ttft_steps:.1f} vsteps; "
           f"{sp_cold.tokens_per_s:.1f} tok/s fleet")
    report("serve_sharedprefix_router_cached", t_sh * 1e6,
           f"{sp_hot.prefill_tokens} prompt tokens prefilled "
           f"({sp_hot.prefill_tokens_saved} saved, hit rate "
           f"{sp_hot.prefix_hit_rate:.0%}); mean TTFT "
           f"{sp_hot.mean_ttft_steps:.1f} vsteps; "
           f"{sp_hot.tokens_per_s:.1f} tok/s fleet")

    # --- spec-off vs draft-then-verify on the repetitive trace -----------
    e_spec = _spec_engine()
    rtrace = _repetitive(N_REQUESTS, e_spec)
    e_spec.run(rtrace, spec_k=0, prefill_chunk=0)               # warm
    e_spec.run(rtrace, spec_k=SPEC_K, prefill_chunk=0)
    spc_off, t_o = _timed(e_spec.run, rtrace, spec_k=0, prefill_chunk=0)
    spc_on, t_v = _timed(e_spec.run, rtrace, spec_k=SPEC_K,
                         prefill_chunk=0)
    report("serve_repetitive_spec_off",
           t_o / max(spc_off.decode_steps, 1) * 1e6,
           f"{spc_off.tokens_per_s:.1f} tok/s; "
           f"{spc_off.decode_steps} steps")
    report("serve_repetitive_spec_on",
           t_v / max(spc_on.decode_steps, 1) * 1e6,
           f"{spc_on.tokens_per_s:.1f} tok/s; {spc_on.decode_steps} steps "
           f"({spc_off.decode_steps / max(spc_on.decode_steps, 1):.2f}x "
           f"fewer); {spc_on.accepted_per_verify:.2f} tokens/verify")


def run_smoke(out_path: str = "BENCH_serving.json",
              n_requests: int = 12, max_new: int = 32,
              check_baseline: bool = False) -> dict:
    """Tiny grid (both layouts x both policies, plus the router fleet) on
    the tight-budget target; emits tokens/sec and
    HBM-bytes-per-admitted-token per cell and the fleet metrics.  With
    ``check_baseline`` the previous ``out_path`` contents gate the run:
    any cell regressing more than REGRESSION_TOLERANCE in tok/s fails."""
    baseline = None
    if check_baseline:
        if not Path(out_path).exists():
            # a missing baseline must not silently disable the gate
            raise SystemExit(f"SMOKE FAIL: --check-baseline but no "
                             f"checked-in {out_path} to compare against")
        baseline = json.loads(Path(out_path).read_text())
    tight = _register_tight_target()
    cells = {}
    single_cont = single_paged = None
    paged_cont_stats = None
    for layout in ("contiguous", "paged"):
        engine = _engine(layout, target=tight)
        if layout == "contiguous":
            single_cont = engine
        else:
            single_paged = engine
        reqs = _trace(n_requests, engine, max_new=max_new)
        engine.run(reqs, policy="continuous", prefill_chunk=0)  # warm jits
        for policy in ("static", "continuous"):
            # blocking prefill keeps these cells' decode-step counts
            # comparable with pre-chunking baselines; the longprompt
            # cells below track the chunked path
            stats = engine.run(reqs, policy=policy, prefill_chunk=0)
            if layout == "paged" and policy == "continuous":
                paged_cont_stats = stats
            cells[f"{layout}_{policy}"] = {
                "tokens_per_s": round(stats.tokens_per_s, 2),
                "tokens_per_step": round(
                    stats.generated_tokens / max(stats.decode_steps, 1), 4),
                "hbm_bytes_per_admitted_token":
                    round(_bytes_per_token(engine, stats), 1),
                "pool_bytes": _pool_bytes(engine),
                "slots": engine.num_slots,
                "decode_steps": stats.decode_steps,
                "generated_tokens": stats.generated_tokens,
                "occupancy": round(stats.occupancy, 4),
                "peak_active": stats.peak_active,
                "preemptions": stats.preemptions,
                "mean_ttft_steps": round(stats.mean_ttft_steps, 4),
            }
    # paged decode through the fused Pallas paged-attention kernel (page
    # table walked in-kernel, interpret mode on CPU): same trace, same
    # tight budget — gated below to be token-identical to the gather
    # paged_continuous cell, and recorded so the kernel path has a
    # throughput baseline from day one
    e_kernel = _engine("paged", target=tight, kv_kernel="pallas")
    kreqs = _trace(n_requests, e_kernel, max_new=max_new)
    e_kernel.run(kreqs, policy="continuous", prefill_chunk=0)   # warm jits
    kstats = e_kernel.run(kreqs, policy="continuous", prefill_chunk=0)
    cells["paged_continuous_kernel"] = {
        "tokens_per_s": round(kstats.tokens_per_s, 2),
        "tokens_per_step": round(
            kstats.generated_tokens / max(kstats.decode_steps, 1), 4),
        "hbm_bytes_per_admitted_token":
            round(_bytes_per_token(e_kernel, kstats), 1),
        "pool_bytes": _pool_bytes(e_kernel),
        "slots": e_kernel.num_slots,
        "kv_kernel": e_kernel.kv_kernel,
        "decode_steps": kstats.decode_steps,
        "generated_tokens": kstats.generated_tokens,
        "occupancy": round(kstats.occupancy, 4),
        "peak_active": kstats.peak_active,
        "preemptions": kstats.preemptions,
        "mean_ttft_steps": round(kstats.mean_ttft_steps, 4),
    }
    # draft-then-verify speculative decoding: the repetitive greedy trace
    # on the 4-token-vocab probe arch, same paged engine with spec off vs
    # spec_k=SPEC_K — gated below on bit-identical streams AND
    # accepted-tokens/verify-step > 1 (the multi-token-tick win shows up
    # in tokens_per_step, which the regression gate then guards)
    e_spec = _spec_engine()
    rtrace = _repetitive(n_requests, e_spec)
    e_spec.run(rtrace, spec_k=0, prefill_chunk=0)           # warm both
    e_spec.run(rtrace, spec_k=SPEC_K, prefill_chunk=0)      # step shapes
    spc_off = e_spec.run(rtrace, spec_k=0, prefill_chunk=0)
    spc_on = e_spec.run(rtrace, spec_k=SPEC_K, prefill_chunk=0)
    for name, k, stats in (("paged_spec_off", 0, spc_off),
                           ("paged_spec_on", SPEC_K, spc_on)):
        cells[name] = {
            "tokens_per_s": round(stats.tokens_per_s, 2),
            "tokens_per_step": round(
                stats.generated_tokens / max(stats.decode_steps, 1), 4),
            "arch": SPEC_ARCH,
            "spec_k": k,
            "decode_steps": stats.decode_steps,
            "generated_tokens": stats.generated_tokens,
            "spec_verify_steps": stats.spec_verify_steps,
            "spec_drafted_tokens": stats.spec_drafted_tokens,
            "spec_accepted_tokens": stats.spec_accepted_tokens,
            "accepted_per_verify": round(stats.accepted_per_verify, 4),
        }
    # router fleet: FLEET tight contiguous replicas, least-loaded routing,
    # same trace — fleet tok/s, aggregate in-flight, and load imbalance
    # no extra warm pass: the fleet reuses single_cont's already-warmed
    # jitted steps (same engine object), and only one pool shape exists
    router = _router(single_cont)
    reqs = _trace(n_requests, single_cont, max_new=max_new)
    fleet = router.run(reqs, policy="continuous", prefill_chunk=0)
    cc = cells["contiguous_continuous"]
    rounds = max(max(s.decode_steps for s in fleet.replica_stats), 1)
    cells[f"router_least_loaded_x{FLEET}"] = {
        "tokens_per_s": round(fleet.tokens_per_s, 2),
        "tokens_per_step": round(fleet.generated_tokens / rounds, 4),
        "replicas": FLEET,
        "route_policy": "least_loaded",
        "generated_tokens": fleet.generated_tokens,
        "decode_steps": rounds,               # lockstep rounds, fleet-wide
        "peak_in_flight": fleet.peak_in_flight,
        "in_flight_vs_single":
            round(fleet.peak_in_flight / max(cc["peak_active"], 1), 2),
        "load_imbalance": _num(fleet.imbalance),
        "reroutes": fleet.reroutes,
    }
    # long-prompt trace, blocking vs chunked prompt ingestion: the TTFT
    # proxy comparison the chunked-prefill pipeline is judged on
    ptrace = _longprompt(n_requests, single_cont)
    # warm BOTH ingestion modes (chunked compiles the small chunk
    # buckets, blocking the whole-prompt ones) so neither timed cell
    # pays compilation
    router.run(ptrace, policy="continuous")
    router.run(ptrace, policy="continuous", prefill_chunk=0)
    for name, chunk in (("longprompt_router_blocking", 0),
                        ("longprompt_router_chunked", None)):
        stats = router.run(ptrace, policy="continuous", prefill_chunk=chunk)
        rounds = max(max(s.decode_steps for s in stats.replica_stats), 1)
        cells[name] = {
            "tokens_per_s": round(stats.tokens_per_s, 2),
            "tokens_per_step": round(stats.generated_tokens / rounds, 4),
            "mean_ttft_steps": round(stats.mean_ttft_steps, 4),
            "prefill_chunk": (0 if chunk == 0 else
                              single_cont.prefill_chunk),
            "prefill_chunks": stats.prefill_chunks,
            "prefill_compiles": max(
                s.prefill_compiles for s in stats.replica_stats),
            "prefill_queue_peak": max(
                s.prefill_queue_peak for s in stats.replica_stats),
            "overlap_steps": stats.overlap_steps,
            "generated_tokens": stats.generated_tokens,
            "decode_steps": rounds,
            "replicas": FLEET,
            "reroutes": stats.reroutes,
        }
    # shared-prefix trace, cache off vs on, through a paged
    # prefix_affinity fleet (sharers colocate, so per-replica caches
    # compose): the reuse comparison the prefix KV cache is judged on.
    # 3x the fleet-capacity request count — hits need waves that arrive
    # after an earlier sharer's prefill completed (no in-flight dedup).
    # Warm both modes — cached suffix chunks start mid-prompt, so their
    # (bucket, kv_bound) pairs can differ from the cold run's
    strace = _sharedprefix(3 * n_requests, single_paged)
    sp_router = _router(single_paged, policy="prefix_affinity")
    sp_router.run(strace, policy="continuous")
    sp_router.run(strace, policy="continuous", prefix_cache=True)
    sp_cold = sp_router.run(strace, policy="continuous")
    sp_hot = sp_router.run(strace, policy="continuous", prefix_cache=True)
    for name, stats in (("sharedprefix_router_cold", sp_cold),
                        ("sharedprefix_router_cached", sp_hot)):
        rounds = max(max(s.decode_steps for s in stats.replica_stats), 1)
        cells[name] = {
            "tokens_per_s": round(stats.tokens_per_s, 2),
            "tokens_per_step": round(stats.generated_tokens / rounds, 4),
            "mean_ttft_steps": _num(stats.mean_ttft_steps),
            "prefill_tokens": stats.prefill_tokens,
            "prefill_tokens_saved": stats.prefill_tokens_saved,
            "prefix_hits": stats.prefix_hits,
            "prefix_misses": stats.prefix_misses,
            "prefix_hit_rate": _num(stats.prefix_hit_rate),
            "generated_tokens": stats.generated_tokens,
            "decode_steps": rounds,
            "replicas": FLEET,
            "route_policy": "prefix_affinity",
            "load_imbalance": _num(stats.imbalance),
        }
    # open-loop Poisson traffic: the same Zipf trace stamped with
    # virtual-step arrivals, through a fixed 1-replica router vs an
    # autoscaling 1..FLEET router under a TTFT/e2e SLO.  All SLO and
    # percentile metrics below are vstep-derived (deterministic);
    # tokens_per_s stays the only wall-clock (advisory) field.
    import dataclasses

    from repro.serving import AutoscalePolicy, with_arrivals
    oreqs = with_arrivals(_trace(n_requests, single_cont, max_new=max_new),
                          "poisson", mean_gap=OPENLOOP_GAP,
                          seed=OPENLOOP_SEED)
    closed_reqs = [dataclasses.replace(r, arrival_vstep=0) for r in oreqs]
    slo = dict(slo_ttft_steps=OPENLOOP_SLO_TTFT,
               slo_e2e_steps=OPENLOOP_SLO_E2E)
    fixed_router = _router(single_cont, fleet=1)
    # no extra warm pass: same engine object as the cells above
    ol_closed = fixed_router.run(closed_reqs, policy="continuous",
                                 prefill_chunk=0, **slo)
    ol_fixed = fixed_router.run(oreqs, policy="continuous",
                                prefill_chunk=0, **slo)
    auto_router = _router(single_cont)
    ol_auto = auto_router.run(
        oreqs, policy="continuous", prefill_chunk=0,
        autoscale=AutoscalePolicy(min_replicas=1, max_replicas=FLEET),
        **slo)
    for name, stats in (("openloop_poisson_fixed", ol_fixed),
                        ("openloop_poisson_autoscale", ol_auto)):
        m = stats.to_metrics()
        cells[name] = {
            "tokens_per_s": round(stats.tokens_per_s, 2),
            "arrivals": "poisson",
            "arrival_gap": OPENLOOP_GAP,
            "arrival_seed": OPENLOOP_SEED,
            "slo_ttft_steps": OPENLOOP_SLO_TTFT,
            "slo_e2e_steps": OPENLOOP_SLO_E2E,
            "p50_ttft_steps": _num(stats.p50_ttft_steps),
            "p99_ttft_steps": _num(stats.p99_ttft_steps),
            "p50_e2e_steps": _num(stats.p50_e2e_steps),
            "p99_e2e_steps": _num(stats.p99_e2e_steps),
            "goodput_tokens": stats.goodput_tokens,
            "generated_tokens": stats.generated_tokens,
            "total_vsteps": stats.total_vsteps,
            "peak_replicas": m["router_peak_replicas"],
            "autoscale_grows": m["router_autoscale_grows"],
            "autoscale_drains": m["router_autoscale_drains"],
            "replicas": 1 if stats is ol_fixed else FLEET,
        }
    # telemetry overhead: the exact paged/continuous drain of the
    # paged_continuous cell, re-run with a Tracer attached.  Tracing is
    # pure host-side bookkeeping on the virtual clock, so the gate below
    # demands EXACT stream and tokens-per-decode-step equality with the
    # tracing-off run — wall clock stays advisory, like everywhere else.
    from repro.serving import Tracer
    tel_tracer = Tracer()
    tel_stats = single_paged.run(
        _trace(n_requests, single_paged, max_new=max_new),
        policy="continuous", prefill_chunk=0, tracer=tel_tracer)
    cells["telemetry_overhead"] = {
        "tokens_per_s": round(tel_stats.tokens_per_s, 2),
        "tokens_per_step": round(
            tel_stats.generated_tokens / max(tel_stats.decode_steps, 1), 4),
        "decode_steps": tel_stats.decode_steps,
        "generated_tokens": tel_stats.generated_tokens,
        "trace_spans": len(tel_tracer.spans),
        "ring_events": tel_tracer.total_events,
        "mean_ttft_steps": round(tel_stats.mean_ttft_steps, 4),
    }
    out = {"arch": ARCH, "target": tight, "n_requests": n_requests,
           "max_len": MAX_LEN, "trace_seed": TRACE_SEED, "cells": cells}
    pc = cells["paged_continuous"]
    pk = cells["paged_continuous_kernel"]
    so = cells["paged_spec_off"]
    sn = cells["paged_spec_on"]
    rc = cells[f"router_least_loaded_x{FLEET}"]
    lb = cells["longprompt_router_blocking"]
    lc = cells["longprompt_router_chunked"]
    sc = cells["sharedprefix_router_cold"]
    sh = cells["sharedprefix_router_cached"]
    of_cell = cells["openloop_poisson_fixed"]
    oa_cell = cells["openloop_poisson_autoscale"]
    print(f"paged {pc['tokens_per_s']} tok/s @ "
          f"{pc['hbm_bytes_per_admitted_token']} B/tok, peak "
          f"{pc['peak_active']} (fused kernel {pk['tokens_per_s']} tok/s, "
          f"token-identical) | contiguous {cc['tokens_per_s']} tok/s @ "
          f"{cc['hbm_bytes_per_admitted_token']} B/tok, peak "
          f"{cc['peak_active']} | router x{FLEET} {rc['tokens_per_s']} "
          f"tok/s fleet, peak {rc['peak_in_flight']} "
          f"({rc['in_flight_vs_single']}x single), imbalance "
          f"{rc['load_imbalance']} | longprompt TTFT "
          f"{lc['mean_ttft_steps']} vsteps chunked vs "
          f"{lb['mean_ttft_steps']} blocking "
          f"({lc['overlap_steps']} overlapped ticks) | sharedprefix "
          f"prefill {sh['prefill_tokens']} vs {sc['prefill_tokens']} cold "
          f"({sh['prefill_tokens_saved']} saved, hit rate "
          f"{sh['prefix_hit_rate']}) | spec k={SPEC_K} "
          f"{sn['accepted_per_verify']} tok/verify, "
          f"{sn['decode_steps']} steps vs {so['decode_steps']} spec-off "
          f"(token-identical) | openloop poisson p99 TTFT "
          f"{oa_cell['p99_ttft_steps']} vsteps autoscaled "
          f"(peak {oa_cell['peak_replicas']} replicas, "
          f"{oa_cell['autoscale_grows']}g/{oa_cell['autoscale_drains']}d) "
          f"vs {of_cell['p99_ttft_steps']} fixed; goodput "
          f"{oa_cell['goodput_tokens']}t vs {of_cell['goodput_tokens']}t "
          f"under ttft<={OPENLOOP_SLO_TTFT}")
    # gates run BEFORE the write: a failing run must not replace the
    # checked-in baseline with its own (regressed) numbers
    try:
        if not pc["peak_active"] > cc["peak_active"]:
            raise SystemExit("SMOKE FAIL: paged did not admit more "
                             "concurrent requests than contiguous in the "
                             "same budget")
        if rc["peak_in_flight"] < 2.5 * cc["peak_active"]:
            raise SystemExit(
                f"SMOKE FAIL: router fleet held {rc['peak_in_flight']} in "
                f"flight, < 2.5x the single engine's {cc['peak_active']}")
        if not lc["mean_ttft_steps"] < lb["mean_ttft_steps"]:
            raise SystemExit(
                f"SMOKE FAIL: chunked prefill mean TTFT "
                f"{lc['mean_ttft_steps']} vsteps is not strictly lower "
                f"than blocking's {lb['mean_ttft_steps']} on the "
                f"long-prompt trace")
        sp_tok = lambda stats: [r.tokens for r in stats.results]  # noqa: E731
        if sp_tok(kstats) != sp_tok(paged_cont_stats):
            raise SystemExit(
                "SMOKE FAIL: fused-kernel paged token streams differ from "
                "the gather path on the same trace — the kernel must be "
                "token-identical")
        if sp_tok(sp_hot) != sp_tok(sp_cold):
            raise SystemExit(
                "SMOKE FAIL: prefix-cached token streams differ from the "
                "cache-off run on the shared-prefix trace — reuse must "
                "never change output")
        if sp_tok(spc_on) != sp_tok(spc_off):
            raise SystemExit(
                "SMOKE FAIL: speculative token streams differ from the "
                "spec-off run on the repetitive trace — draft-then-verify "
                "must be bit-identical to sequential decode")
        if not sn["accepted_per_verify"] > 1.0:
            raise SystemExit(
                f"SMOKE FAIL: accepted-tokens/verify-step "
                f"{sn['accepted_per_verify']} <= 1 on the repetitive "
                f"trace — the drafter is accepting nothing and every "
                f"verify is a wasted wide step")
        if not sh["prefill_tokens_saved"] > 0:
            raise SystemExit(
                "SMOKE FAIL: prefix cache saved no prefill tokens on the "
                "shared-prefix trace (hit rate "
                f"{sh['prefix_hit_rate']}) — the reuse layer is dead")
        if sh["prefill_tokens"] + sh["prefill_tokens_saved"] != \
                sc["prefill_tokens"]:
            raise SystemExit(
                "SMOKE FAIL: cached prefill tokens + saved tokens != cold "
                f"prefill tokens ({sh['prefill_tokens']} + "
                f"{sh['prefill_tokens_saved']} vs {sc['prefill_tokens']}) "
                "— the savings accounting leaks")
        tok_by_rid = lambda stats: {r.rid: r.tokens  # noqa: E731
                                    for r in stats.results}
        if tok_by_rid(ol_fixed) != tok_by_rid(ol_closed) or \
                tok_by_rid(ol_auto) != tok_by_rid(ol_closed):
            raise SystemExit(
                "SMOKE FAIL: open-loop token streams differ from the "
                "closed-loop replay of the same trace — arrival timing "
                "and autoscaling must move latency, never sampling")
        if not of_cell["goodput_tokens"] < oa_cell["goodput_tokens"] or \
                not oa_cell["goodput_tokens"] == \
                oa_cell["generated_tokens"]:
            raise SystemExit(
                f"SMOKE FAIL: autoscaled goodput "
                f"{oa_cell['goodput_tokens']}t under the "
                f"{OPENLOOP_SLO_TTFT}-vstep TTFT SLO must beat the fixed "
                f"fleet's {of_cell['goodput_tokens']}t and cover all "
                f"{oa_cell['generated_tokens']}t generated — scaling out "
                f"is buying nothing")
        if not (oa_cell["p99_ttft_steps"] or 0) < \
                (of_cell["p99_ttft_steps"] or float("inf")):
            raise SystemExit(
                f"SMOKE FAIL: autoscaled p99 TTFT "
                f"{oa_cell['p99_ttft_steps']} vsteps is not strictly "
                f"below the fixed fleet's {of_cell['p99_ttft_steps']}")
        if not oa_cell["autoscale_grows"] > 0:
            raise SystemExit(
                "SMOKE FAIL: the autoscaler never grew under Poisson "
                "load — the open-loop cell is not exercising scaling")
        tel = cells["telemetry_overhead"]
        if sp_tok(tel_stats) != sp_tok(paged_cont_stats):
            raise SystemExit(
                "SMOKE FAIL: telemetry-on token streams differ from the "
                "tracing-off paged_continuous run — tracing must be "
                "observationally free")
        if tel["tokens_per_step"] != pc["tokens_per_step"] or \
                tel["decode_steps"] != pc["decode_steps"]:
            raise SystemExit(
                f"SMOKE FAIL: telemetry-on tokens/step "
                f"{tel['tokens_per_step']} @ {tel['decode_steps']} steps "
                f"!= tracing-off {pc['tokens_per_step']} @ "
                f"{pc['decode_steps']} — tracing moved the schedule")
        if not tel["trace_spans"]:
            raise SystemExit(
                "SMOKE FAIL: the telemetry run recorded no spans — the "
                "tracer hook is dead")
        if baseline is not None:
            _check_regression(baseline, out, out_path)
    except SystemExit:
        print("fresh cells (NOT written):\n" + json.dumps(cells, indent=2))
        raise
    if baseline is not None and \
            _strip_wall(baseline.get("cells", {})) == _strip_wall(cells):
        # deterministic metrics are bit-identical: rewriting would only
        # churn this machine's wall-clock numbers into the tracked file
        print(f"{out_path} unchanged (deterministic metrics match "
              f"baseline); not rewritten")
    else:
        Path(out_path).write_text(json.dumps(out, indent=2))
        print(f"wrote {out_path}")
    return out


def _strip_wall(cells: dict) -> dict:
    """Cells without their machine-dependent wall-clock field."""
    return {n: {k: v for k, v in c.items() if k != "tokens_per_s"}
            for n, c in cells.items()}


def _check_regression(baseline: dict, fresh: dict,
                      out_path: str = "BENCH_serving.json") -> None:
    """Fail when a cell's throughput regresses > REGRESSION_TOLERANCE vs
    the checked-in baseline.

    The *enforced* metrics are ``tokens_per_step`` (generated tokens per
    decode step — the machine-independent component of tok/s, exactly
    what a batching/routing regression moves), the ``mean_ttft_steps``
    proxy (deterministic like tokens/step; lower is better, so the gate
    is a ceiling), ``p99_ttft_steps`` / ``goodput_tokens`` (the
    open-loop SLO metrics — vstep percentiles gate as ceilings, goodput
    as a floor; an idle fleet's NaN percentile serializes to null and
    skips the gate rather than tripping it), and
    ``prefill_tokens_saved`` (the prefix cache's reuse, which must stay
    strictly positive wherever the baseline had it).  Each metric guards **independently**: a baseline cell that
    predates one metric must not silently skip the others' gates.
    Wall-clock tok/s swings 2-3x with CI-runner load on these sub-second
    cells, so it is reported as an advisory only.  Cells that vanished
    from the grid fail (a silently dropped comparison is a regression in
    coverage, not just speed) — and cells *new* to the grid fail too:
    an ungated cell ships no protection, so the baseline file must be
    refreshed in the same PR that adds the cell."""
    old_cells = baseline.get("cells", {})
    missing = [n for n in old_cells if n not in fresh["cells"]]
    if missing:
        raise SystemExit("SMOKE FAIL: cells missing from fresh run vs "
                         "checked-in baseline: " + ", ".join(missing))
    added = [n for n in fresh["cells"] if n not in old_cells]
    if added:
        raise SystemExit(
            f"SMOKE FAIL: {len(added)} new cell(s) not in baseline — "
            f"refresh {out_path} in this PR so they are gated from day "
            f"one: " + ", ".join(sorted(added)))
    bad = []
    for name in sorted(old_cells):
        old, new = old_cells[name], fresh["cells"][name]
        if "tokens_per_step" in old:
            floor = old["tokens_per_step"] * (1.0 - REGRESSION_TOLERANCE)
            if new.get("tokens_per_step", 0.0) < floor:
                bad.append(
                    f"{name}: {new.get('tokens_per_step')} tokens/step < "
                    f"{floor:.3f} (baseline {old['tokens_per_step']} "
                    f"- {REGRESSION_TOLERANCE:.0%})")
        if (old.get("mean_ttft_steps") or 0) > 0:
            ceiling = old["mean_ttft_steps"] * (1.0 + REGRESSION_TOLERANCE)
            if (new.get("mean_ttft_steps") or 0) > ceiling:
                bad.append(
                    f"{name}: {new.get('mean_ttft_steps')} TTFT vsteps > "
                    f"{ceiling:.3f} (baseline {old['mean_ttft_steps']} "
                    f"+ {REGRESSION_TOLERANCE:.0%})")
        # percentile/goodput gates (open-loop cells): vstep-derived and
        # deterministic like mean_ttft_steps.  `or 0` maps the null an
        # idle fleet's NaN percentile serializes to — a baseline (or
        # fresh) null never trips a gate, it just skips it.
        if (old.get("p99_ttft_steps") or 0) > 0:
            ceiling = old["p99_ttft_steps"] * (1.0 + REGRESSION_TOLERANCE)
            if (new.get("p99_ttft_steps") or float("inf")) > ceiling:
                bad.append(
                    f"{name}: {new.get('p99_ttft_steps')} p99 TTFT vsteps "
                    f"> {ceiling:.3f} (baseline {old['p99_ttft_steps']} "
                    f"+ {REGRESSION_TOLERANCE:.0%})")
        if old.get("goodput_tokens", 0) > 0:
            floor = old["goodput_tokens"] * (1.0 - REGRESSION_TOLERANCE)
            if new.get("goodput_tokens", 0) < floor:
                bad.append(
                    f"{name}: {new.get('goodput_tokens', 0)} goodput "
                    f"tokens under SLO < {floor:.1f} (baseline "
                    f"{old['goodput_tokens']} "
                    f"- {REGRESSION_TOLERANCE:.0%})")
        if old.get("prefill_tokens_saved", 0) > 0 and \
                new.get("prefill_tokens_saved", 0) <= 0:
            bad.append(f"{name}: prefix cache saved "
                       f"{new.get('prefill_tokens_saved', 0)} prefill "
                       f"tokens (baseline {old['prefill_tokens_saved']}) "
                       f"— reuse went dead")
        if "tokens_per_s" in old and \
                new.get("tokens_per_s", 0.0) < \
                old["tokens_per_s"] * (1.0 - REGRESSION_TOLERANCE):
            print(f"advisory: {name} wall-clock {new.get('tokens_per_s')} "
                  f"tok/s below baseline {old['tokens_per_s']} - "
                  f"{REGRESSION_TOLERANCE:.0%} (not enforced: wall time "
                  f"tracks runner load, tokens/step tracks the code)")
    if bad:
        raise SystemExit("SMOKE FAIL: deterministic-metric regression vs "
                         "checked-in baseline:\n  " + "\n  ".join(bad))
    print(f"baseline check OK: {len(old_cells)} cells within "
          f"{REGRESSION_TOLERANCE:.0%} of checked-in tokens/step + "
          f"TTFT vsteps (+ prefix-cache savings alive)")


def main():
    if "--smoke" in sys.argv[1:]:
        run_smoke(check_baseline="--check-baseline" in sys.argv[1:])
        return

    def report(name, us, derived=""):
        print(f"{name},{us:.3f},{derived}")
    print("name,us_per_call,derived")
    run(report)


if __name__ == "__main__":
    main()
