"""Serving throughput: scheduling policies x KV memory layouts.

Two comparisons over the same jitted steps and seeded Zipf traces
(heavy-tailed prompt and generation lengths — the regime real serving
traffic lives in):

1. **static vs continuous** (PR 1): gang scheduling burns decode steps
   waiting for each batch's longest request; continuous batching refills
   freed slots between steps (~2x on the Zipf trace).
2. **contiguous vs paged KV** (this PR): under the same tuner HBM budget
   — enforced with a deliberately tight benchmark target — the
   contiguous layout reserves slots x max_len worst cases and gets its
   slot count capped, while the paged layout spends the budget on pages
   and admits requests by *actual* tokens: strictly more in flight, and
   fewer HBM bytes per admitted token.

``--smoke`` runs a tiny version of the full grid (both layouts x both
policies) and writes ``BENCH_serving.json`` with tokens/sec and
HBM-bytes-per-admitted-token per cell, so CI tracks the perf trajectory.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

SLOTS = 8
MAX_LEN = 128
N_REQUESTS = 32
TRACE_SEED = 0
TIGHT_SLOTS = 3          # contiguous slots the tight target affords
ARCH = "deepseek-7b-smoke"


def _kv_token_bytes(cfg) -> int:
    from repro.core.tuning import kv_bytes_per_token
    return kv_bytes_per_token(cfg)


def _register_tight_target(max_len: int = MAX_LEN) -> str:
    """A CPU target whose HBM budget affords only TIGHT_SLOTS worst-case
    contiguous slots — the regime where the paged layout's
    tokens-not-worst-cases accounting shows up."""
    from repro.configs.base import get_config
    from repro.core.target import TARGETS, TargetSpec, register
    from repro.core.tuning import param_count_estimate

    name = "bench:serve-tight"
    if name in TARGETS:
        return name
    cfg = get_config(ARCH)
    param_bytes = 2 * param_count_estimate(cfg)
    kv_budget = (TIGHT_SLOTS + 0.5) * _kv_token_bytes(cfg) * max_len
    register(TargetSpec(
        name=name, chip="cpu", mesh_shape=(1,), mesh_axes=("data",),
        peak_flops=5e10, hbm_bw=2e10,
        hbm_bytes=(param_bytes + kv_budget) / 0.85, ici_bw=1e9,
        scheduler="local", kernels="reference",
        description=f"serving-bench budget target: ~{TIGHT_SLOTS} "
                    f"contiguous slots x {max_len}"))
    return name


def _engine(kv_layout: str, target: str = "local:cpu", slots: int = SLOTS,
            max_len: int = MAX_LEN):
    from repro.serving import ServeEngine
    return ServeEngine(arch=ARCH, target=target, num_slots=slots,
                       max_len=max_len, seed=0, kv_layout=kv_layout,
                       log=lambda *a, **k: None)


def _pool_bytes(engine) -> int:
    cfg = engine.cfg
    tok = _kv_token_bytes(cfg)
    if engine.kv_layout == "paged":
        return engine.num_pages * engine.page_size * tok
    return engine.num_slots * engine.max_len * tok


def _trace(n: int, engine, max_new: int = 64, seed: int = TRACE_SEED):
    from repro.serving import zipf_trace
    return zipf_trace(n, engine.cfg.vocab_size, max_prompt=48,
                      max_new=max_new, alpha=1.3, seed=seed)


def _bytes_per_token(engine, stats) -> float:
    """Pool HBM bytes per admitted *resident* token at peak occupancy —
    the over-reservation metric: a contiguous pool pins max_len per
    request however short it is, so its peak resident tokens stay far
    below capacity and the ratio stays high."""
    return _pool_bytes(engine) / max(stats.peak_resident_tokens, 1)


def run(report) -> None:
    engine = _engine("contiguous")
    reqs = _trace(N_REQUESTS, engine)
    # warm ALL jit caches the trace will touch (every prompt-length bucket
    # compiles its own prefill/insert) so neither timed run pays compile
    engine.run(reqs, policy="continuous")

    t0 = time.perf_counter()
    static = engine.run(reqs, policy="static")
    t_static = time.perf_counter() - t0
    t0 = time.perf_counter()
    cont = engine.run(reqs, policy="continuous")
    t_cont = time.perf_counter() - t0

    speedup = cont.tokens_per_s / max(static.tokens_per_s, 1e-9)
    report("serve_static_batching",
           t_static / max(static.decode_steps, 1) * 1e6,
           f"{static.tokens_per_s:.1f} tok/s; {static.decode_steps} steps; "
           f"occupancy {static.occupancy:.0%}")
    report("serve_continuous_batching",
           t_cont / max(cont.decode_steps, 1) * 1e6,
           f"{cont.tokens_per_s:.1f} tok/s; {cont.decode_steps} steps; "
           f"occupancy {cont.occupancy:.0%}; speedup {speedup:.2f}x")

    # --- long-tail layout comparison under one tight HBM budget ----------
    tight = _register_tight_target()
    e_cont = _engine("contiguous", target=tight)
    e_paged = _engine("paged", target=tight)
    ltrace = _trace(N_REQUESTS, e_cont)
    e_cont.run(ltrace, policy="continuous")       # warm
    e_paged.run(ltrace, policy="continuous")
    t0 = time.perf_counter()
    s_cont = e_cont.run(ltrace, policy="continuous")
    t_c = time.perf_counter() - t0
    t0 = time.perf_counter()
    s_paged = e_paged.run(ltrace, policy="continuous")
    t_p = time.perf_counter() - t0
    report("serve_contiguous_tight_budget",
           t_c / max(s_cont.decode_steps, 1) * 1e6,
           f"{s_cont.tokens_per_s:.1f} tok/s; {e_cont.num_slots} slots; "
           f"peak {s_cont.peak_active} in flight; "
           f"{_bytes_per_token(e_cont, s_cont):.0f} B/admitted-token")
    report("serve_paged_tight_budget",
           t_p / max(s_paged.decode_steps, 1) * 1e6,
           f"{s_paged.tokens_per_s:.1f} tok/s; {e_paged.num_slots} slots; "
           f"peak {s_paged.peak_active} in flight "
           f"(+{s_paged.peak_active - s_cont.peak_active} vs contiguous); "
           f"{_bytes_per_token(e_paged, s_paged):.0f} B/admitted-token; "
           f"{s_paged.preemptions} preemptions")


def run_smoke(out_path: str = "BENCH_serving.json",
              n_requests: int = 12, max_new: int = 32) -> dict:
    """Tiny grid (both layouts x both policies) on the tight-budget target;
    emits tokens/sec and HBM-bytes-per-admitted-token per cell."""
    tight = _register_tight_target()
    cells = {}
    for layout in ("contiguous", "paged"):
        engine = _engine(layout, target=tight)
        reqs = _trace(n_requests, engine, max_new=max_new)
        engine.run(reqs, policy="continuous")     # warm the jit caches
        for policy in ("static", "continuous"):
            stats = engine.run(reqs, policy=policy)
            cells[f"{layout}_{policy}"] = {
                "tokens_per_s": round(stats.tokens_per_s, 2),
                "hbm_bytes_per_admitted_token":
                    round(_bytes_per_token(engine, stats), 1),
                "pool_bytes": _pool_bytes(engine),
                "slots": engine.num_slots,
                "decode_steps": stats.decode_steps,
                "generated_tokens": stats.generated_tokens,
                "occupancy": round(stats.occupancy, 4),
                "peak_active": stats.peak_active,
                "preemptions": stats.preemptions,
            }
    out = {"arch": ARCH, "target": tight, "n_requests": n_requests,
           "max_len": MAX_LEN, "trace_seed": TRACE_SEED, "cells": cells}
    Path(out_path).write_text(json.dumps(out, indent=2))
    pc = cells["paged_continuous"]
    cc = cells["contiguous_continuous"]
    print(f"wrote {out_path}: paged {pc['tokens_per_s']} tok/s @ "
          f"{pc['hbm_bytes_per_admitted_token']} B/tok, peak "
          f"{pc['peak_active']} | contiguous {cc['tokens_per_s']} tok/s @ "
          f"{cc['hbm_bytes_per_admitted_token']} B/tok, peak "
          f"{cc['peak_active']}")
    if not pc["peak_active"] > cc["peak_active"]:
        raise SystemExit("SMOKE FAIL: paged did not admit more concurrent "
                         "requests than contiguous in the same budget")
    return out


def main():
    if "--smoke" in sys.argv[1:]:
        run_smoke()
        return

    def report(name, us, derived=""):
        print(f"{name},{us:.3f},{derived}")
    print("name,us_per_call,derived")
    run(report)


if __name__ == "__main__":
    main()
