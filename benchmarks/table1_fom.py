"""Paper Table 1: FOM comparison — native vs EASEY-deployed LULESH.

The paper runs LULESH:DASH natively and inside an EASEY-deployed
Charliecloud container on SuperMUC-NG (cube lengths p = 10..32, cores =
p^3) and reports FOM deltas of +0.8%..-3.6%.  We reproduce the experiment
shape on CPU: the same Sedov solver run (a) directly jit-compiled
("native") and (b) through the full EASEY pipeline — build, package,
stage, submit, execute under the LocalScheduler ("easey") — and report
the FOM delta.  Cube sizes are scaled to CPU (the paper's p is a core
count; ours is the grid side), iterations fixed per run.
"""

from __future__ import annotations

import time

import jax

from repro.models import lulesh

# (grid side, iterations) — scaled-down analogue of the paper's p sweep
CASES = [(8, 60), (13, 40), (16, 30), (20, 20)]
WARMUP = 3


def _native_fom(grid: int, iters: int) -> float:
    cfg = lulesh.LuleshConfig(grid=grid, iters=iters)
    state = lulesh.init_state(cfg)
    # warm with the SAME static iters (a different count is a different
    # compilation — timing it would charge compile to the native side)
    lulesh.run(state, cfg, iters)["e"].block_until_ready()
    state = lulesh.init_state(cfg)
    t0 = time.perf_counter()
    out = lulesh.run(state, cfg, iters)
    out["e"].block_until_ready()
    return lulesh.fom(grid ** 3, iters, time.perf_counter() - t0)


def _easey_fom(grid: int, iters: int, storage) -> float:
    """Through the full workflow: Fig. 2 path, execution timed inside."""
    from repro.core.appspec import AppSpec
    from repro.core.jobspec import parse_jobspec
    from repro.core.workflow import run_easey

    app = AppSpec(arch="lulesh-dash", shape="train_4k",
                  run=f"lulesh -i {iters} -s {grid}")
    spec = parse_jobspec({
        "job": {"name": f"lulesh_p{grid}"},
        "deployment": {"nodes": 1, "tasks-per-node": 1,
                       "clocktime": "06:00:00"},
        "execution": [{"mpi": {
            "command": f"ch-run -b ./data:/data lulesh.dash -- "
                       f"/built/lulesh.dash -i {iters} -s {grid}",
            "mpi-tasks": grid ** 3}}],
    })
    # warm the jit cache through the same path so both sides measure steady
    # state (the paper also reports steady-state FOM, not first-build)
    mw, job_id, _ = run_easey(app, "local:cpu", spec, storage=storage)
    res = mw.scheduler.result(job_id)[0]
    mw2, job_id2, _ = run_easey(app, "local:cpu", spec, storage=storage)
    res2 = mw2.scheduler.result(job_id2)[0]
    return max(res["fom"], res2["fom"])


def run(report) -> None:
    import tempfile
    storage = tempfile.mkdtemp(prefix="easey_bench_")
    for grid, iters in CASES:
        nat = _native_fom(grid, iters)
        eas = _easey_fom(grid, iters, storage)
        delta = (eas - nat) / nat * 100.0
        report(f"table1_fom_native_p{grid}", 1e6 * grid ** 3 * iters / nat,
               f"fom={nat:.0f}")
        report(f"table1_fom_easey_p{grid}", 1e6 * grid ** 3 * iters / eas,
               f"fom={eas:.0f},delta={delta:+.2f}%")
