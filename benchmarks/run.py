"""Benchmark driver — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (harness contract)."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig3_weak_scaling, kernel_bench,
                            overhead_breakdown, roofline_report,
                            serving_throughput, table1_fom)

    rows: list[tuple[str, float, str]] = []

    def report(name: str, us_per_call: float, derived: str = ""):
        rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.3f},{derived}", flush=True)

    print("name,us_per_call,derived")
    for mod in (table1_fom, fig3_weak_scaling, overhead_breakdown,
                kernel_bench, roofline_report, serving_throughput):
        try:
            mod.run(report)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{mod.__name__}_FAILED,0,{type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    print(f"# {len(rows)} benchmark rows", flush=True)


if __name__ == "__main__":
    main()
