"""Structural validator for ``BENCH_serving.json``.

The serving benchmark table is a regression *baseline*: downstream
gates diff it cell-by-cell, so its shape has to be stable — known cell
names, known metric keys per cell, and the NaN→null convention (the
file is strict JSON; non-finite floats are written as ``null``, never
as the ``NaN`` / ``Infinity`` literals Python's ``json`` would happily
emit and almost nothing else can parse).

This module checks exactly that, with no repo imports, so CI can run
it *before* the (much slower) smoke benchmark and fail fast when a PR
adds a cell or key without updating the schema here — the same
add-a-cell-refresh-the-baseline discipline ``serving_throughput.py``
enforces at run time, applied statically to the checked-in file.

Usage::

    python benchmarks/validate_bench.py [BENCH_serving.json]

Exit status 0 and silence on success; a numbered list of problems and
exit status 1 otherwise.  ``check(data)`` returns the problem list for
use from tests.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

# Top-level keys of the bench file.  "cells" holds the table proper.
TOP_KEYS = {"arch", "cells", "max_len", "n_requests", "target",
            "trace_seed"}

# Metric-key sets shared by several cells.
_SINGLE = {"decode_steps", "generated_tokens",
           "hbm_bytes_per_admitted_token", "mean_ttft_steps",
           "occupancy", "peak_active", "pool_bytes", "preemptions",
           "slots", "tokens_per_s", "tokens_per_step"}
_SPEC = {"accepted_per_verify", "arch", "decode_steps",
         "generated_tokens", "spec_accepted_tokens",
         "spec_drafted_tokens", "spec_k", "spec_verify_steps",
         "tokens_per_s", "tokens_per_step"}
_LONGPROMPT = {"decode_steps", "generated_tokens", "mean_ttft_steps",
               "overlap_steps", "prefill_chunk", "prefill_chunks",
               "prefill_compiles", "prefill_queue_peak", "replicas",
               "reroutes", "tokens_per_s", "tokens_per_step"}
_SHAREDPREFIX = {"decode_steps", "generated_tokens", "load_imbalance",
                 "mean_ttft_steps", "prefill_tokens",
                 "prefill_tokens_saved", "prefix_hit_rate",
                 "prefix_hits", "prefix_misses", "replicas",
                 "route_policy", "tokens_per_s", "tokens_per_step"}
_OPENLOOP = {"arrival_gap", "arrival_seed", "arrivals",
             "autoscale_drains", "autoscale_grows", "generated_tokens",
             "goodput_tokens", "p50_e2e_steps", "p50_ttft_steps",
             "p99_e2e_steps", "p99_ttft_steps", "peak_replicas",
             "replicas", "slo_e2e_steps", "slo_ttft_steps",
             "tokens_per_s", "total_vsteps"}

# The full cell schema: every cell the smoke bench emits, with its
# exact key set.  Adding a bench cell means adding a row here — the
# validator (and the CI step running it) fails otherwise.
CELL_SCHEMA = {
    "contiguous_static": _SINGLE,
    "contiguous_continuous": _SINGLE,
    "paged_static": _SINGLE,
    "paged_continuous": _SINGLE,
    "paged_continuous_kernel": _SINGLE | {"kv_kernel"},
    "paged_spec_off": _SPEC,
    "paged_spec_on": _SPEC,
    "router_least_loaded_x3": {
        "decode_steps", "generated_tokens", "in_flight_vs_single",
        "load_imbalance", "peak_in_flight", "replicas", "reroutes",
        "route_policy", "tokens_per_s", "tokens_per_step"},
    "longprompt_router_blocking": _LONGPROMPT,
    "longprompt_router_chunked": _LONGPROMPT,
    "sharedprefix_router_cold": _SHAREDPREFIX,
    "sharedprefix_router_cached": _SHAREDPREFIX,
    "openloop_poisson_fixed": _OPENLOOP,
    "openloop_poisson_autoscale": _OPENLOOP,
    "telemetry_overhead": {
        "decode_steps", "generated_tokens", "mean_ttft_steps",
        "ring_events", "tokens_per_s", "tokens_per_step",
        "trace_spans"},
}

# Keys whose values are strings, not numbers.
_STR_KEYS = {"arch", "arrivals", "kv_kernel", "route_policy"}

# Cells are gated positions: downstream regression gates diff every
# cell key against the baseline, so a wall-clock-derived key here would
# gate on machine noise.  `tokens_per_s` is the one advisory wall
# metric the table carries (the run-time gate strips it); anything
# spelled `wall_*` is rejected outright.
_WALL_PREFIX = "wall_"


def _reject_constant(name: str) -> float:
    raise ValueError(f"non-finite JSON literal {name!r} — the bench "
                     f"writes NaN as null")


def parse_strict(text: str):
    """``json.loads`` that rejects NaN / Infinity literals."""
    return json.loads(text, parse_constant=_reject_constant)


def check(data) -> list[str]:
    """Return a list of structural problems (empty when valid)."""
    problems: list[str] = []
    if not isinstance(data, dict):
        return [f"top level is {type(data).__name__}, expected object"]

    missing = TOP_KEYS - data.keys()
    extra = data.keys() - TOP_KEYS
    if missing:
        problems.append(f"missing top-level keys: {sorted(missing)}")
    if extra:
        problems.append(f"unknown top-level keys: {sorted(extra)}")

    cells = data.get("cells")
    if not isinstance(cells, dict):
        problems.append("'cells' is not an object")
        return problems

    missing_cells = CELL_SCHEMA.keys() - cells.keys()
    extra_cells = cells.keys() - CELL_SCHEMA.keys()
    if missing_cells:
        problems.append(f"missing cells: {sorted(missing_cells)}")
    if extra_cells:
        problems.append(f"unknown cells: {sorted(extra_cells)} — "
                        f"register new cells in CELL_SCHEMA")

    for name in sorted(CELL_SCHEMA.keys() & cells.keys()):
        cell, want = cells[name], CELL_SCHEMA[name]
        if not isinstance(cell, dict):
            problems.append(f"cell {name!r} is not an object")
            continue
        if missing := want - cell.keys():
            problems.append(f"cell {name!r} missing keys: "
                            f"{sorted(missing)}")
        if extra := cell.keys() - want:
            problems.append(f"cell {name!r} unknown keys: "
                            f"{sorted(extra)}")
        if wall := sorted(k for k in (cell.keys() | want)
                          if k.startswith(_WALL_PREFIX)):
            problems.append(
                f"cell {name!r} carries wall-clock keys {wall} in a "
                f"gated position — gated metrics must be vstep-derived"
                f" (tokens_per_s is the only advisory wall metric)")
        for key in sorted(want & cell.keys()):
            val = cell[key]
            if key in _STR_KEYS:
                if not isinstance(val, str):
                    problems.append(f"{name}.{key} should be a string, "
                                    f"got {val!r}")
            elif not (val is None or isinstance(val, (int, float))):
                problems.append(f"{name}.{key} should be numeric or "
                                f"null, got {val!r}")
            elif isinstance(val, float) and val != val:
                problems.append(f"{name}.{key} is NaN — write null")
    return problems


def validate_file(path) -> list[str]:
    """Parse *path* strictly and return its problem list."""
    try:
        text = Path(path).read_text()
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    try:
        data = parse_strict(text)
    except ValueError as e:
        return [f"{path} is not strict JSON: {e}"]
    return check(data)


def main(argv=None) -> int:
    args = sys.argv[1:] if argv is None else list(argv)
    path = args[0] if args else "BENCH_serving.json"
    problems = validate_file(path)
    if problems:
        print(f"{path}: {len(problems)} problem(s)")
        for i, p in enumerate(problems, 1):
            print(f"  {i}. {p}")
        return 1
    print(f"{path}: OK ({len(CELL_SCHEMA)} cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
