"""Aggregate the dry-run artifacts into the §Roofline table.

Reads artifacts/dryrun/*.json (written by launch/dryrun.py) and prints
per-cell roofline terms; also emits the markdown table EXPERIMENTS.md
embeds.  No jax needed — pure JSON aggregation."""

from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_records(tag: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(ART.glob("*.json")):
        r = json.loads(f.read_text())
        rtag = r.get("tag", "")
        if (tag or "") != rtag:
            continue
        recs.append(r)
    return recs


def markdown_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bound | roofline frac | MODEL/HLO | HBM GB/chip |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for r in recs:
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR: {r.get('error', '?')[:60]} |" + " |" * 6)
            continue
        ro = r["roofline"]
        mem = r["memory_analysis"]["per_chip_total_gb"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {ro['t_compute_s']*1e3:.1f} | {ro['t_memory_s']*1e3:.1f} "
            f"| {ro['t_collective_s']*1e3:.1f} | {ro['bottleneck']} "
            f"| {ro['roofline_fraction']:.3f} | {ro['useful_ratio']:.2f} "
            f"| {mem:.1f} |")
    return "\n".join(rows)


def run(report) -> None:
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in ok:
        ro = r["roofline"]
        report(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
               max(ro["t_compute_s"], ro["t_memory_s"],
                   ro["t_collective_s"]) * 1e6,
               f"bound={ro['bottleneck']},frac={ro['roofline_fraction']:.3f}")
    if ok:
        fracs = [r["roofline"]["roofline_fraction"] for r in ok]
        report("roofline_mean_fraction", sum(fracs) / len(fracs) * 100,
               f"cells={len(ok)}")


if __name__ == "__main__":
    import sys
    tag = sys.argv[1] if len(sys.argv) > 1 else None
    print(markdown_table(load_records(tag)))
