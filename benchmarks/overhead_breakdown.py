"""Paper §4 'negligible overhead' claim, quantified per pipeline stage.

Times every EASEY stage for a smoke LM deployment: tune, lower(build),
package, stage+submit (middleware), and the actual execution — the
paper's argument is that the framework cost is amortized noise; here we
measure exactly how much it is.
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core.appspec import AppSpec
from repro.core.build import BuildService
from repro.core.jobspec import parse_jobspec
from repro.core.middleware import Middleware
from repro.core.package import write_package


def run(report) -> None:
    app = AppSpec(arch="stablelm-1.6b-smoke", shape="train_4k",
                  shape_overrides={"seq_len": 32, "global_batch": 2},
                  run="train --steps 5")
    svc = BuildService()

    t0 = time.perf_counter()
    res = svc.build(app, "local:cpu", lower=True)
    t_build = time.perf_counter() - t0

    tmp = Path(tempfile.mkdtemp(prefix="easey_ovh_"))
    t0 = time.perf_counter()
    pkg = write_package(res, tmp / "pkgs")
    t_pkg = time.perf_counter() - t0

    mw = Middleware(tmp / "cluster")
    spec = parse_jobspec({
        "job": {"name": "ovh"},
        "deployment": {"nodes": 1},
        "execution": [{"serial": {
            "command": "train --steps 5 --seq-len 32 --global-batch 2 "
                       "--arch stablelm-1.6b-smoke"}}],
    })

    t0 = time.perf_counter()
    runner_time = {}

    def runner(job, workdir, jspec):
        from repro.launch.run import run_command
        t = time.perf_counter()
        out = [run_command(ex.command, job=job, workdir=workdir, spec=jspec)
               for ex in jspec.executions]
        runner_time["exec"] = time.perf_counter() - t
        return out

    jid = mw.submit(pkg, spec, runner=runner)
    t_total_submit = time.perf_counter() - t0
    t_exec = runner_time["exec"]
    t_middleware = t_total_submit - t_exec

    report("overhead_tune", res.timings["tune_s"] * 1e6, "stage=tune")
    report("overhead_lower", res.timings["lower_s"] * 1e6, "stage=lower")
    report("overhead_package", t_pkg * 1e6, "stage=package")
    report("overhead_middleware", t_middleware * 1e6,
           "stage=stage+batch+submit")
    report("overhead_execution", t_exec * 1e6, "stage=execution")
    framework = res.timings["tune_s"] + t_pkg + t_middleware
    report("overhead_framework_pct", framework / t_exec * 100,
           f"framework/exec={framework / t_exec * 100:.2f}%")
