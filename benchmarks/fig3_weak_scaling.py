"""Paper Figs. 3-4: weak scaling FOM and FOM-per-core.

The paper scales LULESH 1,000 -> 32,768 cores at fixed per-core work and
plots (3) total FOM and (4) FOM/cores.  CPU analogue: fixed per-"rank"
work with grid volume scaled as p^3 (p the paper's cube length), FOM
measured for native and EASEY paths; FOM/zones is the Fig.4 analogue
(flat = perfect weak scaling).  The 256-chip projection for the real mesh
comes from the dry-run roofline artifacts (benchmarks/roofline_report.py).
"""

from __future__ import annotations

import time

from repro.models import lulesh

CASES = [8, 10, 13, 16, 20]     # paper's cube lengths (scaled)
ITERS = 20


def run(report) -> None:
    base = None
    for p in CASES:
        cfg = lulesh.LuleshConfig(grid=p, iters=ITERS)
        state = lulesh.init_state(cfg)
        lulesh.run(state, cfg, 2)["e"].block_until_ready()
        state = lulesh.init_state(cfg)
        t0 = time.perf_counter()
        lulesh.run(state, cfg, ITERS)["e"].block_until_ready()
        dt = time.perf_counter() - t0
        fom = lulesh.fom(p ** 3, ITERS, dt)
        per_zone = fom / p ** 3          # Fig. 4: flat line == ideal
        base = base or per_zone
        report(f"fig3_weak_scaling_p{p}", dt / ITERS * 1e6,
               f"fom={fom:.0f}")
        report(f"fig4_fom_per_zone_p{p}", per_zone,
               f"scaling_eff={per_zone / base:.3f}")
