"""Kernel microbenchmarks: Pallas (interpret) vs reference, plus the
reference path timings that stand for the unfused baseline.  On CPU the
interpret-mode kernel is an emulation (correctness vehicle); the headline
number for the TPU target is the HBM-traffic reduction, reported by the
roofline pass — here we record wall times + bytes-moved estimates."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ops import flash_attention, rmsnorm, sedov_step_kernel
from repro.models import lulesh


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(report) -> None:
    rng = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)

    b, s, H, K, dh = 1, 512, 4, 2, 64
    q = jax.random.normal(k1, (b, s, H, dh), jnp.float32)
    k = jax.random.normal(k2, (b, s, K, dh), jnp.float32)
    v = jax.random.normal(k3, (b, s, K, dh), jnp.float32)
    t_ref = _time(lambda *a: ref.attention_ref(*a), q, k, v)
    t_pal = _time(lambda *a: flash_attention(*a, causal=True), q, k, v)
    # HBM traffic: unfused materializes s^2 scores fp32 (x2 passes) + probs
    unfused_bytes = b * H * s * s * 4 * 3
    fused_bytes = (3 * b * s * H * dh + b * s * H * dh) * 4
    report("kernel_flash_ref", t_ref * 1e6, f"bytes={unfused_bytes}")
    report("kernel_flash_pallas_interp", t_pal * 1e6,
           f"bytes={fused_bytes},traffic_reduction="
           f"{unfused_bytes / fused_bytes:.1f}x")

    x = jax.random.normal(k1, (4096, 2048), jnp.bfloat16)
    w = jnp.ones((2048,), jnp.float32)
    t_ref = _time(ref.rmsnorm_ref, x, w)
    t_pal = _time(rmsnorm, x, w)
    report("kernel_rmsnorm_ref", t_ref * 1e6, "bytes=5x")
    report("kernel_rmsnorm_pallas_interp", t_pal * 1e6, "bytes=2x")

    cfg = lulesh.LuleshConfig(grid=16)
    st = lulesh.init_state(cfg)
    t_ref = _time(lambda s_: lulesh.step(s_, cfg), st)
    t_pal = _time(lambda s_: sedov_step_kernel(s_, cfg, block_x=8), st)
    report("kernel_sedov_ref", t_ref * 1e6, "passes=8")
    report("kernel_sedov_pallas_interp", t_pal * 1e6, "passes=1")
