"""Kernel microbenchmarks: Pallas (interpret) vs reference, plus the
reference path timings that stand for the unfused baseline.  On CPU the
interpret-mode kernel is an emulation (correctness vehicle); the headline
number for the TPU target is the HBM-traffic reduction, reported by the
roofline pass — here we record wall times + bytes-moved estimates."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ops import (flash_attention, paged_attention, rmsnorm,
                               sedov_step_kernel)
from repro.models import lulesh


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(report) -> None:
    rng = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)

    b, s, H, K, dh = 1, 512, 4, 2, 64
    q = jax.random.normal(k1, (b, s, H, dh), jnp.float32)
    k = jax.random.normal(k2, (b, s, K, dh), jnp.float32)
    v = jax.random.normal(k3, (b, s, K, dh), jnp.float32)
    t_ref = _time(lambda *a: ref.attention_ref(*a), q, k, v)
    t_pal = _time(lambda *a: flash_attention(*a, causal=True), q, k, v)
    # HBM traffic: unfused materializes s^2 scores fp32 (x2 passes) + probs
    unfused_bytes = b * H * s * s * 4 * 3
    # fused touches q + out at H heads but K/V at only K kv heads (GQA)
    fused_bytes = (2 * H + 2 * K) * b * s * dh * 4
    report("kernel_flash_ref", t_ref * 1e6, f"bytes={unfused_bytes}")
    report("kernel_flash_pallas_interp", t_pal * 1e6,
           f"bytes={fused_bytes},traffic_reduction="
           f"{unfused_bytes / fused_bytes:.1f}x")

    # --- paged decode: gather-then-attend vs fused page-walk kernel -------
    # one decode tick over a heavy-tailed slot mix: the gather path
    # materializes every slot's WORST-CASE (max_pages*page_size) K/V run
    # through the page table before attending; the fused kernel streams
    # only the pages each slot actually holds (3 phases, never written)
    from repro.models.layers import dot_attention
    slots, psize, max_pages = 4, 16, 8
    Kp, dhp = 2, 64
    Hp = 4
    lens = [128, 48, 16, 96]                   # heavy-tailed slot lengths
    held = [-(-L // psize) for L in lens]
    num_pages = sum(held) + 1                  # + reserved junk page 0
    table = jnp.zeros((slots, max_pages), jnp.int32)
    nxt = 1
    for i, h in enumerate(held):
        table = table.at[i, :h].set(jnp.arange(nxt, nxt + h))
        nxt += h
    kv_lens = jnp.asarray(lens, jnp.int32)
    qd = jax.random.normal(k1, (slots, Hp, dhp), jnp.float32) \
        .astype(jnp.bfloat16)
    kp = jax.random.normal(k2, (num_pages, psize, Kp, dhp), jnp.float32) \
        .astype(jnp.bfloat16)
    vp = jax.random.normal(k3, (num_pages, psize, Kp, dhp), jnp.float32) \
        .astype(jnp.bfloat16)

    @jax.jit
    def gather_decode(qd, kp, vp, table, kv_lens):
        kg = jnp.take(kp, table, axis=0).reshape(
            slots, max_pages * psize, Kp, dhp)
        vg = jnp.take(vp, table, axis=0).reshape(
            slots, max_pages * psize, Kp, dhp)
        return dot_attention(qd[:, None], kg, vg, causal=True,
                             q_offset=kv_lens - 1, kv_len=kv_lens)

    t_gather = _time(gather_decode, qd, kp, vp, table, kv_lens)
    t_fused = _time(paged_attention, qd, kp, vp, table, kv_lens)
    item = 2                                   # bf16 K/V pool
    # gather: the materialized (slots, max_pages*psize, K, dh) K+V tensor
    # is written once and read back by attention
    gather_bytes = 2 * 2 * slots * max_pages * psize * Kp * dhp * item
    # fused: held pages streamed from the pool, once per phase, no write
    fused_paged_bytes = 3 * 2 * sum(held) * psize * Kp * dhp * item
    report("kernel_paged_decode_gather", t_gather * 1e6,
           f"bytes={gather_bytes}")
    report("kernel_paged_decode_fused", t_fused * 1e6,
           f"bytes={fused_paged_bytes},traffic_reduction="
           f"{gather_bytes / fused_paged_bytes:.1f}x")

    x = jax.random.normal(k1, (4096, 2048), jnp.bfloat16)
    w = jnp.ones((2048,), jnp.float32)
    t_ref = _time(ref.rmsnorm_ref, x, w)
    t_pal = _time(rmsnorm, x, w)
    report("kernel_rmsnorm_ref", t_ref * 1e6, "bytes=5x")
    report("kernel_rmsnorm_pallas_interp", t_pal * 1e6, "bytes=2x")

    cfg = lulesh.LuleshConfig(grid=16)
    st = lulesh.init_state(cfg)
    t_ref = _time(lambda s_: lulesh.step(s_, cfg), st)
    t_pal = _time(lambda s_: sedov_step_kernel(s_, cfg, block_x=8), st)
    report("kernel_sedov_ref", t_ref * 1e6, "passes=8")
    report("kernel_sedov_pallas_interp", t_pal * 1e6, "passes=1")
