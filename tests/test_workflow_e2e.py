"""End-to-end EASEY workflow (paper Fig. 2 + Algorithm 1): build ->
package -> stage -> submit -> poll -> logs, with real execution, plus the
package-equivalence check behind the paper's negligible-overhead claim."""

import json
import tarfile
from pathlib import Path

import pytest

from repro.core.appspec import AppSpec
from repro.core.build import BuildService
from repro.core.jobspec import parse_jobspec
from repro.core.middleware import Middleware
from repro.core.package import extract_package, read_manifest, write_package
from repro.core.workflow import run_easey


@pytest.fixture(scope="module")
def small_app():
    return AppSpec(arch="stablelm-1.6b-smoke", shape="train_4k",
                   shape_overrides={"seq_len": 32, "global_batch": 2},
                   run="train --steps 3")


def test_build_and_package(tmp_path, small_app):
    res = BuildService().build(small_app, "local:cpu", lower=True)
    pkg = write_package(res, tmp_path)
    assert pkg.exists()
    names = tarfile.open(pkg).getnames()
    assert set(names) == {"manifest.json", "plan.json", "tuning_report.txt",
                          "Appfile", "module.stablehlo.gz"}
    man = read_manifest(pkg)
    assert man["arch"] == "stablelm-1.6b-smoke"
    # extraction verifies the hlo hash (Charliecloud image integrity)
    man2 = extract_package(pkg, tmp_path / "env")
    assert man2["hlo_sha256"] == man["hlo_sha256"]


def test_package_tamper_detected(tmp_path, small_app):
    res = BuildService().build(small_app, "local:cpu", lower=True)
    pkg = write_package(res, tmp_path)
    # corrupt the module
    import io
    with tarfile.open(pkg) as tar:
        members = {m.name: tar.extractfile(m).read() for m in tar}
    members["module.stablehlo.gz"] = b"corrupt"
    with tarfile.open(pkg, "w") as tar:
        for name, data in members.items():
            info = tarfile.TarInfo(name)
            info.size = len(data)
            tar.addfile(info, io.BytesIO(data))
    with pytest.raises(ValueError, match="integrity"):
        extract_package(pkg, tmp_path / "env2")


def test_algorithm1_data_staging(tmp_path, small_app):
    res = BuildService().build(small_app, "local:cpu", lower=True)
    pkg = write_package(res, tmp_path)
    input_file = tmp_path / "input.bin"
    input_file.write_bytes(b"data!")
    spec = parse_jobspec({
        "job": {"name": "staged"},
        "data": {"input": [{"source": str(input_file), "protocol": "file"}],
                 "mount": {"container-path": "/data"}},
        "deployment": {"nodes": 1},
        "execution": [],
    })
    mw = Middleware(tmp_path / "cluster")
    jid = mw.submit(pkg, spec, runner=None)
    assert mw.status(jid).value == "finished"
    workdir = tmp_path / "cluster" / spec.job_id
    assert (workdir / "data" / "input.bin").read_bytes() == b"data!"
    assert (workdir / "batch.sh").exists()
    assert "#SBATCH" in (workdir / "batch.sh").read_text()


def test_missing_input_fails_staging(tmp_path, small_app):
    res = BuildService().build(small_app, "local:cpu", lower=True)
    pkg = write_package(res, tmp_path)
    spec = parse_jobspec({
        "job": {"name": "bad"},
        "data": {"input": [{"source": "/nonexistent", "protocol": "file"}]},
        "execution": [],
    })
    mw = Middleware(tmp_path / "cluster")
    with pytest.raises(Exception, match="input not found"):
        mw.submit(pkg, spec)


def test_full_easey_run_executes_training(tmp_path, small_app):
    spec = parse_jobspec({
        "job": {"name": "e2e", "mail": "a@b.c"},
        "deployment": {"nodes": 1, "tasks-per-node": 1},
        "execution": [{"serial": {
            "command": "train --steps 3 --seq-len 32 --global-batch 2"}}],
    })
    mw, jid, res = run_easey(small_app, "local:cpu", spec,
                             storage=tmp_path / "s")
    assert mw.status(jid).value == "finished"
    out, err = mw.logs(jid)
    assert "loss" in out
    assert mw.scheduler.result(jid)[0]["steps"] == 3


def test_deployment_equivalence_easey_vs_direct(small_app):
    """The paper's central claim, ported: deploying through EASEY yields
    the SAME program as hand-rolled jit -> on-device overhead ~ 0."""
    import jax
    from repro.models.transformer import model_for
    from repro.models.params import shape_structs
    from repro.optim import make_optimizer
    from repro.training.steps import build_train_step, train_state_table

    res = BuildService().build(small_app, "local:cpu", lower=True)
    easey_hlo = res.lowered.as_text()

    cfg = small_app.model_config
    model = model_for(cfg, remat=res.plan.remat_policy)
    opt = make_optimizer(res.plan.optimizer)
    step = build_train_step(model, opt, res.plan, res.mesh,
                            param_specs=res.in_shardings[0]["params"])
    direct = jax.jit(step, in_shardings=res.in_shardings,
                     out_shardings=res.out_shardings,
                     donate_argnums=(0,)).lower(*res.in_structs)
    assert direct.as_text() == easey_hlo
