"""Chunked-prefill pipeline: token-identity with blocking prefill (swept
over chunk sizes, KV layouts, and preemption resumes), scheduler- and
router-level overlap (a replica mid-prefill keeps serving decode ticks),
the deterministic TTFT step proxy, the long-prompt trace preset, and the
tuner's chunk-size + napkin plumbing."""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.serving import (PoolExhausted, ReplicaRouter, Request, Scheduler,
                           ServeEngine, longprompt_trace, zipf_trace)
from repro.serving.prefill import bucket_len
from repro.serving.scheduler import _Entry

ARCH = "deepseek-7b-smoke"
SLOTS, MAX_LEN = 4, 64

_ENGINES: dict = {}


def engine_for(layout="contiguous", page_size=0, num_pages=0, slots=SLOTS,
               max_len=MAX_LEN):
    """Engines are expensive (jit); share them across tests by config."""
    key = (layout, page_size, num_pages, slots, max_len)
    if key not in _ENGINES:
        _ENGINES[key] = ServeEngine(
            arch=ARCH, num_slots=slots, max_len=max_len, seed=0,
            kv_layout=layout, page_size=page_size, num_pages=num_pages,
            log=lambda *a, **k: None)
    return _ENGINES[key]


def _tokens(stats):
    return [r.tokens for r in sorted(stats.results, key=lambda r: r.rid)]


# ---------------------------------------------------------------------------
# Token identity: chunked == blocking


def test_chunked_matches_blocking_across_chunk_sizes():
    """The keystone: the chunk-prefill step scatters KV to the same final
    positions blocking prefill + insert produced, bitwise, so every chunk
    size decodes the identical stream."""
    e = engine_for()
    reqs = zipf_trace(10, e.cfg.vocab_size, max_prompt=24, max_new=16,
                      seed=3)
    ref = e.run(reqs, prefill_chunk=0)            # blocking baseline
    for chunk in (4, 8, 16, 64):
        got = e.run(reqs, prefill_chunk=chunk)
        assert _tokens(got) == _tokens(ref), f"chunk={chunk}"
    # no preemptions on a roomy contiguous pool: every prompt token was
    # ingested through the chunk pipeline exactly once
    chunked = e.run(reqs, prefill_chunk=8)
    assert chunked.prefill_tokens == sum(len(r.prompt) for r in reqs)


def test_chunked_matches_blocking_moe_family():
    """The chunk scan rides the MoE backbone (aux-loss carry) too."""
    e = ServeEngine(arch="granite-moe-3b-a800m-smoke", num_slots=3,
                    max_len=48, seed=0, log=lambda *a, **k: None)
    reqs = zipf_trace(6, e.cfg.vocab_size, max_prompt=16, max_new=10,
                      seed=1)
    assert _tokens(e.run(reqs, prefill_chunk=4)) == \
        _tokens(e.run(reqs, prefill_chunk=0))


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 32]),
       layout=st.sampled_from(["contiguous", "paged"]),
       trace_seed=st.integers(min_value=0, max_value=30))
def test_chunked_equivalence_sweep(chunk, layout, trace_seed):
    """Hypothesis sweep: any chunk size x layout x mixed-length trace is
    token-identical to the blocking full-prompt prefill."""
    e = engine_for(layout, page_size=16 if layout == "paged" else 0)
    reqs = zipf_trace(6, e.cfg.vocab_size, max_prompt=16, max_new=12,
                      seed=trace_seed)
    assert _tokens(e.run(reqs, prefill_chunk=chunk)) == \
        _tokens(e.run(reqs, prefill_chunk=0))


def test_chunked_preemption_resume_equivalent():
    """Page-scarce chunked serving preempts mid-decode and re-ingests
    prompt+generated through the chunk pipeline — the resumed stream must
    match an uninterrupted blocking run exactly."""
    roomy = engine_for()
    scarce = engine_for("paged", page_size=8, num_pages=13)  # 96 KV tokens
    reqs = zipf_trace(12, roomy.cfg.vocab_size, max_prompt=24, max_new=32,
                      seed=3)
    ref = roomy.run(reqs, prefill_chunk=0)
    got = scarce.run(reqs, prefill_chunk=8)
    assert got.preemptions > 0
    assert _tokens(got) == _tokens(ref)
    again = scarce.run(reqs, prefill_chunk=8)
    assert again.preemptions == got.preemptions
    assert _tokens(again) == _tokens(got)


# ---------------------------------------------------------------------------
# Overlap: prompt ingestion no longer stalls decode


def test_scheduler_decodes_while_prompt_mid_prefill():
    """Regression for the admission stall: with a chunked manager, a
    decode tick runs in the same step that ingests a queued prompt's
    chunk — in-flight requests keep streaming."""
    e = engine_for()
    sched = Scheduler(e.make_pool(), e.prefill_fn, e.decode_fn,
                      sampler=e.sampler, chunk_step_fn=e.chunk_fn,
                      prefill_chunk=8)
    rng = np.random.RandomState(0)
    short = Request(rid=0, prompt=rng.randint(1, 100, 4).astype(np.int32),
                    max_new_tokens=32)
    long = Request(rid=1, prompt=rng.randint(1, 100, 48).astype(np.int32),
                   max_new_tokens=4)
    assert sched.try_admit(_Entry(short))
    sched.step()                      # short's one chunk lands -> active
    assert 0 in [a.st.rid for a in sched.active.values()]
    free_before = sched.free_tokens
    assert sched.try_admit(_Entry(long))
    # the queued 48-token backlog is charged against the load signal
    # beyond the slot reservation itself
    assert sched.free_tokens < free_before - len(long.prompt)
    n0 = len(next(iter(sched.active.values())).st.tokens)
    sched.step()
    assert sched.prefill_backlog      # long is mid-prefill (48 > 8) ...
    n1 = len(next(iter(sched.active.values())).st.tokens)
    assert n1 == n0 + 1               # ... and short still decoded a token
    while sched.has_work:
        sched.admit_from_queue()
        sched.step()
    stats = sched.stats()
    assert stats.overlap_steps >= 1
    assert [r.rid for r in stats.results] == [0, 1]


def test_router_overlaps_prefill_with_fleet_decode_and_lowers_ttft():
    """Acceptance: on the long-prompt trace the chunked fleet overlaps
    ingestion with decode (overlap ticks observed) and its mean TTFT step
    proxy is strictly lower than the blocking lockstep loop's — with
    token-identical output."""
    e = engine_for()
    router = ReplicaRouter([e] * 3, policy="least_loaded",
                           log=lambda *a, **k: None)
    reqs = longprompt_trace(9, e.cfg.vocab_size, max_prompt=MAX_LEN,
                            max_new=8, seed=0)
    blocking = router.run(reqs, policy="continuous", prefill_chunk=0)
    chunked = router.run(reqs, policy="continuous", prefill_chunk=8)
    assert _tokens(chunked) == _tokens(blocking)
    assert chunked.overlap_steps > 0
    assert blocking.overlap_steps == 0
    assert chunked.mean_ttft_steps < blocking.mean_ttft_steps
    # deterministic: a replay reproduces the proxy exactly
    again = router.run(reqs, policy="continuous", prefill_chunk=8)
    assert again.mean_ttft_steps == chunked.mean_ttft_steps


def test_single_replica_router_chunked_token_identical_to_engine():
    """N=1 routing stays a no-op under chunked prefill."""
    e = engine_for()
    router = ReplicaRouter([e], policy="least_loaded",
                           log=lambda *a, **k: None)
    reqs = zipf_trace(8, e.cfg.vocab_size, max_prompt=24, max_new=12,
                      seed=7)
    a = router.run(reqs, prefill_chunk=8)
    ref = e.run(reqs, prefill_chunk=8)
    assert _tokens(a) == _tokens(ref)
    assert a.replica_stats[0].decode_steps == ref.decode_steps
    assert a.replica_stats[0].prefill_chunks == ref.prefill_chunks


# ---------------------------------------------------------------------------
# Observability / plumbing


def test_stats_expose_chunk_pipeline_counters():
    e = engine_for()
    reqs = zipf_trace(8, e.cfg.vocab_size, max_prompt=24, max_new=8, seed=5)
    chunked = e.run(reqs, prefill_chunk=8)
    assert chunked.prefill_chunks > len(reqs)     # multi-chunk prompts
    assert chunked.prefill_tokens == sum(len(r.prompt) for r in reqs)
    # compile-cache proxy: (chunk bucket, kv bound) pairs, both pow2 —
    # bounded by log2(chunk) x log2(max_len)
    assert 1 <= chunked.prefill_compiles <= 16
    assert chunked.prefill_queue_peak >= 1
    assert chunked.mean_ttft_steps > 0
    blocking = e.run(reqs, prefill_chunk=0)
    assert blocking.overlap_steps == 0
    assert blocking.prefill_chunks == len(reqs)   # one whole-prompt chunk
    assert _tokens(blocking) == _tokens(chunked)


def test_bucket_len_is_next_power_of_two():
    assert [bucket_len(n) for n in (1, 2, 3, 5, 8, 9, 16)] == \
        [1, 2, 4, 8, 8, 16, 16]


def test_paged_reserve_prefix_and_exhaustion():
    from repro.configs import smoke_config
    from repro.models.transformer import model_for
    from repro.serving import PagedKVCachePool
    pool = PagedKVCachePool(model_for(smoke_config("deepseek-7b"),
                                      remat="none"),
                            num_slots=2, max_len=32, page_size=8,
                            num_pages=4)             # 3 usable pages
    s0 = pool.alloc()
    pool.reserve_prefix(s0, 17)                      # 3 pages
    assert pool.free_pages == 0
    s1 = pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.reserve_prefix(s1, 8)
    pool.free(s0)
    pool.reserve_prefix(s1, 8)                       # now it fits
    with pytest.raises(ValueError, match="max_len"):
        pool.reserve_prefix(s1, 33)


def test_longprompt_trace_deterministic_and_long():
    a = longprompt_trace(16, 1000, max_prompt=128, max_new=8, seed=4)
    b = longprompt_trace(16, 1000, max_prompt=128, max_new=8, seed=4)
    assert [r.prompt.tolist() for r in a] == [r.prompt.tolist() for r in b]
    lens = [len(r.prompt) for r in a]
    assert all(length <= 128 for length in lens)
    # prefill-stall regime: most prompts sit at the top bucket
    assert sum(length == 128 for length in lens) >= len(lens) // 2
    assert np.mean(lens) >= 64


def test_tuner_picks_chunk_size_and_quotes_ttft():
    from repro.configs.base import ShapeConfig, get_config
    from repro.core.plan import DeploymentPlan
    from repro.core.target import get_target
    from repro.core.tuning import tune

    cfg = get_config(ARCH)
    plan = tune(cfg, ShapeConfig("d", 128, 8, "decode"),
                get_target("local:cpu"))
    chunk = plan.serve_prefill_chunk
    assert chunk >= 8 and (chunk & (chunk - 1)) == 0    # pow2, bucketed
    assert chunk <= 128
    assert plan.napkin["serve_prefill_chunk"] == chunk
    assert "ttft_estimate" in plan.napkin
    again = DeploymentPlan.from_json(plan.to_json())
    assert again.serve_prefill_chunk == chunk
