"""End-to-end behaviour tests for the paper's system (EASEY on TPU):
the three RUN commands the execution layer supports, driven exactly the
way the middleware invokes them."""

import pytest

from repro.launch.run import run_command


class _Job:
    def __init__(self):
        self.lines = []

    def log(self, msg):
        self.lines.append(msg)


def test_run_train_command():
    job = _Job()
    out = run_command("train --steps 3 --seq-len 32 --global-batch 2 "
                      "--arch stablelm-1.6b-smoke", job=job)
    assert out["steps"] == 3
    assert any("loss" in ln for ln in job.lines)


def test_run_serve_command():
    job = _Job()
    out = run_command("serve --arch stablelm-1.6b-smoke --batch 2 "
                      "--prefill 16 --decode 4", job=job)
    assert out["decode_tokens"] == 4
    assert out["decode_tok_per_s"] > 0


def test_run_lulesh_paper_command():
    """The exact command shape from the paper's Listing 1.5."""
    job = _Job()
    out = run_command("ch-run -b ./data:/data lulesh.dash -- "
                      "/built/lulesh.dash -i 3 -s 8", job=job)
    assert out["iters"] == 3 and out["grid"] == 8
    assert out["fom"] > 0


def test_unknown_command_rejected():
    with pytest.raises(ValueError, match="unknown EASEY command"):
        run_command("frobnicate --now")
