"""Draft-then-verify speculative decoding: the keystone bit-identity
property (speculative streams == sequential streams, over draft length x
KV layout x trace x sampling style x preemption pressure), burst page
charging (accepted bursts spend only genuinely free pages; overflow
verify writes land in junk page 0, never a refcounted shared page), the
n-gram drafter, the tuner's spec_k pick, and the top_k/top_p request
validation that rides this PR."""

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.configs import smoke_config
from repro.core.tuning import SPEC_MAX_K, SPEC_MIN_REPETITIVENESS, spec_k_for
from repro.models.params import init_params
from repro.models.transformer import model_for
from repro.serving import (K_CAP, NGramDrafter, PagedKVCachePool,
                           ReplicaRouter, Request, ServeEngine,
                           effective_top_k, repetitive_trace,
                           trace_repetitiveness, uniform_trace, zipf_trace)
from repro.training.steps import build_verify_step_slots_paged

ARCH = "deepseek-7b-smoke"
SPEC_ARCH = "picolm-4-smoke"
SLOTS, MAX_LEN = 4, 64

_ENGINES: dict = {}
_BASELINES: dict = {}


def engine_for(arch=ARCH, layout="contiguous", page_size=0, num_pages=0,
               slots=SLOTS, max_len=MAX_LEN):
    """Engines are expensive (jit); share them across tests by config."""
    key = (arch, layout, page_size, num_pages, slots, max_len)
    if key not in _ENGINES:
        _ENGINES[key] = ServeEngine(
            arch=arch, target="local:cpu", num_slots=slots, max_len=max_len,
            seed=0, kv_layout=layout, page_size=page_size,
            num_pages=num_pages, log=lambda *a, **k: None)
    return _ENGINES[key]


def _tokens(stats):
    return [r.tokens for r in sorted(stats.results, key=lambda r: r.rid)]


def _trace(kind, engine, sampled, n=8, max_new=12):
    kw = dict(seed=3, max_new=max_new)
    if sampled:
        kw.update(temperature=0.8, top_k=8, top_p=0.9)
    vocab = engine.cfg.vocab_size
    if kind == "zipf":
        return zipf_trace(n, vocab, max_prompt=24, **kw)
    return repetitive_trace(n, vocab, prompt_len=8, **kw)


# ---------------------------------------------------------------------------
# keystone: speculative streams are bit-identical to sequential decode


@settings(max_examples=8, deadline=None)
@given(k=st.sampled_from([1, 2, 4]),
       layout=st.sampled_from(["contiguous", "paged"]),
       kind=st.sampled_from(["zipf", "repetitive"]),
       sampled=st.booleans(),
       tight=st.booleans())
def test_spec_streams_bit_identical(k, layout, kind, sampled, tight):
    """spec_k in {1,2,4} x layout x trace x greedy/sampled x page
    pressure: every combination must reproduce the spec-off streams
    exactly.  `tight` shrinks the paged page pool so preemption and
    re-prefill resume interleave with verify bursts."""
    if tight and layout != "paged":
        layout = "paged"           # page pressure only exists with pages
    if tight:
        engine = engine_for(layout="paged", page_size=8, num_pages=12)
    else:
        engine = engine_for(layout=layout)
    reqs = _trace(kind, engine, sampled)
    base_key = (id(engine), kind, sampled)
    if base_key not in _BASELINES:
        _BASELINES[base_key] = _tokens(engine.run(reqs, spec_k=0))
    spec = engine.run(reqs, spec_k=k)
    assert _tokens(spec) == _BASELINES[base_key]
    assert spec.spec_verify_steps > 0
    assert spec.spec_drafted_tokens == spec.spec_verify_steps * k
    assert 0 <= spec.spec_accepted_tokens <= spec.spec_drafted_tokens


def test_spec_accepts_bursts_on_repetitive_smallvocab():
    """On the 4-token-vocab probe arch the greedy continuation is n-gram
    predictable: the drafter must clear >1 accepted-tokens/verify-step
    and finish in strictly fewer scheduler ticks, with identical output."""
    engine = engine_for(arch=SPEC_ARCH, layout="paged")
    reqs = repetitive_trace(8, engine.cfg.vocab_size, max_new=32, seed=0)
    base = engine.run(reqs, spec_k=0)
    spec = engine.run(reqs, spec_k=4)
    assert _tokens(spec) == _tokens(base)
    assert spec.accepted_per_verify > 1.0
    assert spec.decode_steps < base.decode_steps


def test_spec_identical_under_preemption_pressure():
    """A page pool too small for the working set: preemptions and
    re-prefill resumes must interleave with verify bursts without
    perturbing the streams."""
    engine = engine_for(arch=SPEC_ARCH, layout="paged", page_size=8,
                        num_pages=10, max_len=64)
    reqs = repetitive_trace(8, engine.cfg.vocab_size, max_new=24, seed=1)
    base = engine.run(reqs, spec_k=0)
    spec = engine.run(reqs, spec_k=4)
    assert spec.preemptions > 0          # the pressure actually happened
    assert _tokens(spec) == _tokens(base)


def test_spec_through_router_fleet():
    """An N=2 fleet with spec on is token-identical to the spec-off
    fleet, and RouterStats aggregates the replica counters."""
    e_on = ServeEngine(arch=SPEC_ARCH, target="local:cpu", num_slots=2,
                       max_len=MAX_LEN, seed=0, kv_layout="paged",
                       spec_k=4, log=lambda *a, **k: None)
    reqs = repetitive_trace(6, e_on.cfg.vocab_size, max_new=16, seed=2)
    r_on = ReplicaRouter([e_on] * 2, log=lambda *a, **k: None).run(reqs)
    e_off = engine_for(arch=SPEC_ARCH, layout="paged", slots=2)
    r_off = ReplicaRouter([e_off] * 2, log=lambda *a, **k: None).run(reqs)
    assert _tokens(r_on) == _tokens(r_off)
    assert r_on.spec_verify_steps == \
        sum(s.spec_verify_steps for s in r_on.replica_stats) > 0
    assert r_on.accepted_per_verify > 1.0
    assert r_off.spec_verify_steps == 0


# ---------------------------------------------------------------------------
# burst page charging: junk page 0, never a refcounted page


def _model():
    return model_for(smoke_config("deepseek-7b"), remat="none")


def _prefill_cache(model, params, n):
    toks = jnp.ones((1, n), jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks}, None)
    return cache


def test_grow_for_burst_spends_only_free_pages():
    model = _model()
    params = init_params(model.param_table(), jax.random.PRNGKey(0))
    pool = PagedKVCachePool(model, num_slots=2, max_len=32, page_size=8,
                            num_pages=6)              # pages 1..5 usable
    s0 = pool.alloc()
    pool.insert(s0, _prefill_cache(model, params, 8))  # page-exact: 1 page
    assert pool.free_pages == 4
    # a 5-token burst wants 2 pages; both are free -> fully backed
    assert pool.grow_for_burst(s0, 5) == 5
    assert pool._pages_held[s0] == 2 and pool.free_pages == 3
    # a second ask is already covered by the held pages (idempotent)
    assert pool.grow_for_burst(s0, 5) == 5
    assert pool.free_pages == 3
    # mid-page: the burst straddles into one fresh page
    pool.lengths[s0] = 15
    assert pool.grow_for_burst(s0, 5) == 5
    assert pool._pages_held[s0] == 3 and pool.free_pages == 2
    # near max_len the backing is clamped to the slot's headroom
    pool.lengths[s0] = 30
    assert pool.grow_for_burst(s0, 10) == 2
    assert pool._pages_held[s0] == 4 and pool.free_pages == 1


def test_grow_for_burst_never_reclaims_cached_pages():
    """An empty free list with reclaimable prefix-cache pages: the decode
    path's _grow would reclaim them, but a burst is a bonus, not a
    reservation — grow_for_burst must leave the cache intact."""
    model = _model()
    params = init_params(model.param_table(), jax.random.PRNGKey(0))
    pool = PagedKVCachePool(model, num_slots=2, max_len=32, page_size=8,
                            num_pages=3)              # pages 1, 2 usable
    s0 = pool.alloc()
    pool.insert(s0, _prefill_cache(model, params, 8))  # page 1
    s1 = pool.alloc()
    pool.insert(s1, _prefill_cache(model, params, 8))  # page 2
    page0 = int(pool.page_table[s0, 0])
    pool.pin_page(page0)         # a prefix cache takes its reference
    pool.free(s0)                # ... and becomes the page's sole owner
    assert pool.free_pages == 0 and pool.reclaimable_pages == 1
    assert pool.grow_for_burst(s1, 4) == 0    # nothing genuinely free
    assert pool.page_refs[page0] == 1 and pool.page_cached[page0]
    assert pool.reclaimable_pages == 1        # cache untouched


def test_verify_overflow_writes_divert_to_junk_page():
    """A slot at exact page capacity with nothing free: the verify step's
    burst positions have no backing page, so their KV writes must land in
    reserved junk page 0 — and a refcounted page SHARED with another
    request must come through bit-identical."""
    model = _model()
    params = init_params(model.param_table(), jax.random.PRNGKey(0))
    pool = PagedKVCachePool(model, num_slots=2, max_len=32, page_size=8,
                            num_pages=2)              # page 1 only
    s0 = pool.alloc()
    pool.insert(s0, _prefill_cache(model, params, 8))  # fills page 1 exactly
    shared = int(pool.page_table[s0, 0])
    s1 = pool.alloc()
    pool.adopt_run(s1, [shared])                       # refcounted sharer
    pool.set_length(s1, 8)
    pool.sync_index()
    assert pool.page_refs[shared] == 2
    assert pool.grow_for_burst(s0, 4) == 0             # nothing to back
    before_shared = np.asarray(pool.cache["k"][:, shared])
    before_junk = np.asarray(pool.cache["k"][:, 0])
    verify = build_verify_step_slots_paged(model)
    logits, new_cache = verify(
        params, pool.cache, jnp.ones((2, 4), jnp.int32),
        jnp.ones((2,), jnp.int32), jnp.asarray(pool.page_table))
    pool.adopt(new_cache)
    assert logits.shape[:2] == (2, 4)
    after_shared = np.asarray(pool.cache["k"][:, shared])
    after_junk = np.asarray(pool.cache["k"][:, 0])
    # positions 8..11 have page-table entry 0 -> every write diverted
    assert np.array_equal(before_shared, after_shared)
    assert not np.array_equal(before_junk, after_junk)
    # index stays host-authoritative: the verify step must not advance it
    assert list(np.asarray(pool.cache["index"])) == [8, 8]


# ---------------------------------------------------------------------------
# drafter


def test_ngram_drafter_locks_onto_cycles():
    d = NGramDrafter()
    # period-3 cycle: the longest-suffix rule continues it exactly
    assert d.draft([1, 2, 3, 1, 2, 3, 1, 2], 4) == [3, 1, 2, 3]
    # no recurring suffix: fall back to repeating the last token
    assert d.draft([5, 6, 7], 2) == [7, 7]
    assert d.draft([], 3) == [0, 0, 0]
    # proposals extend the working history (a continuation, not k
    # independent guesses): the drafted cycle keeps rolling
    assert d.draft([4, 9, 4, 9], 5) == [4, 9, 4, 9, 4]
    with pytest.raises(ValueError):
        NGramDrafter(max_n=0)


def test_trace_repetitiveness_separates_regimes():
    rep = repetitive_trace(16, 4, seed=0)
    rand = uniform_trace(16, 256, seed=0)
    r_hi, r_lo = trace_repetitiveness(rep), trace_repetitiveness(rand)
    assert r_hi > SPEC_MIN_REPETITIVENESS
    assert r_lo < SPEC_MIN_REPETITIVENESS
    assert r_hi > r_lo


# ---------------------------------------------------------------------------
# tuner pick


def test_spec_k_for_thresholds():
    assert spec_k_for(0.0) == 0
    assert spec_k_for(SPEC_MIN_REPETITIVENESS - 0.01) == 0
    k_mid, k_hi = spec_k_for(0.5), spec_k_for(0.95)
    assert 1 <= k_mid <= k_hi <= SPEC_MAX_K
    assert spec_k_for(1.0) == SPEC_MAX_K      # clamped, saturating


def test_tuner_wires_repetitiveness_into_plan():
    from repro.core.appspec import AppSpec
    from repro.core.build import BuildService
    from repro.core.target import get_target
    plans = {}
    for rep in (0.0, 0.9):
        app = AppSpec(arch=ARCH, shape="decode_32k",
                      shape_overrides={"seq_len": MAX_LEN, "global_batch": 4,
                                       "serve_repetitiveness": rep},
                      run="serve --engine continuous")
        plans[rep] = BuildService().build(app, get_target("local:cpu"),
                                          lower=False).plan
    assert plans[0.0].serve_spec_k == 0
    assert plans[0.9].serve_spec_k == spec_k_for(0.9) > 0
    assert "serve_spec" in plans[0.9].napkin
    # spec_k=None defers the ENGINE to the plan's pick
    eng = ServeEngine(arch=SPEC_ARCH, target="local:cpu", num_slots=2,
                      max_len=MAX_LEN, seed=0, kv_layout="paged",
                      spec_k=None, repetitiveness=0.9,
                      log=lambda *a, **k: None)
    assert eng.spec_k == spec_k_for(0.9)


# ---------------------------------------------------------------------------
# top_k validation + effective-k surfacing (satellite regression)


def test_top_k_above_cap_rejected_at_submission():
    engine = engine_for()
    bad = [Request(rid=0, prompt=np.ones(4, np.int32),
                   max_new_tokens=4, temperature=0.8, top_k=K_CAP + 1)]
    with pytest.raises(ValueError, match="top_k"):
        engine.run(bad)
    router = ReplicaRouter([engine], log=lambda *a, **k: None)
    with pytest.raises(ValueError, match="top_k"):
        router.run(bad)


def test_effective_top_k_surfaced_in_stats():
    # ask for K_CAP on a 4-token vocab: valid, but the sampler can only
    # ever keep 4 — ServeStats must surface the k actually applied
    assert effective_top_k(K_CAP, 4) == 4
    assert effective_top_k(8, 256) == 8
    assert effective_top_k(0, 256) == 0
    engine = engine_for(arch=SPEC_ARCH, layout="paged")
    reqs = repetitive_trace(2, engine.cfg.vocab_size, max_new=4, seed=0,
                            temperature=0.7, top_k=K_CAP)
    stats = engine.run(reqs)
    assert stats.effective_top_k == {0: 4, 1: 4}
    greedy = engine.run(repetitive_trace(2, engine.cfg.vocab_size,
                                         max_new=4, seed=0))
    assert greedy.effective_top_k == {}      # top_k off -> nothing to report


# ---------------------------------------------------------------------------
# top_p (nucleus) sampling


def test_top_p_validation():
    engine = engine_for()
    for bad_p in (0.0, -0.5, 1.5):
        bad = [Request(rid=0, prompt=np.ones(4, np.int32), max_new_tokens=4,
                       temperature=0.8, top_p=bad_p)]
        with pytest.raises(ValueError, match="top_p"):
            engine.run(bad)
        with pytest.raises(ValueError, match="top_p"):
            ReplicaRouter([engine], log=lambda *a, **k: None).run(bad)


def test_top_p_one_is_bitwise_passthrough():
    engine = engine_for()
    base = engine.run(zipf_trace(6, engine.cfg.vocab_size, max_prompt=16,
                                 max_new=8, seed=5, temperature=0.9,
                                 top_k=8))
    explicit = engine.run(zipf_trace(6, engine.cfg.vocab_size, max_prompt=16,
                                     max_new=8, seed=5, temperature=0.9,
                                     top_k=8, top_p=1.0))
    assert _tokens(base) == _tokens(explicit)


def test_top_p_filters_and_stays_deterministic_across_layouts():
    kw = dict(max_prompt=16, max_new=10, seed=7, temperature=1.5, top_p=0.5)
    e_cont = engine_for(layout="contiguous")
    e_paged = engine_for(layout="paged")
    nucleus = engine_for().run(zipf_trace(8, e_cont.cfg.vocab_size, **kw))
    # the filter really bites: some stream must differ from top_p=1.0
    full = e_cont.run(zipf_trace(8, e_cont.cfg.vocab_size,
                                 **{**kw, "top_p": 1.0}))
    assert _tokens(nucleus) != _tokens(full)
    # same draw on a repeat run and across KV layouts
    again = e_cont.run(zipf_trace(8, e_cont.cfg.vocab_size, **kw))
    paged = e_paged.run(zipf_trace(8, e_paged.cfg.vocab_size, **kw))
    assert _tokens(nucleus) == _tokens(again) == _tokens(paged)
