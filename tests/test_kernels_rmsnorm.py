"""Fused RMSNorm kernel sweep vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import rmsnorm

CASES = [
    ((4, 128), 128, jnp.float32),
    ((2, 64, 256), 64, jnp.float32),
    ((8, 1024), 256, jnp.bfloat16),
    ((3, 5, 384), 7, jnp.bfloat16),     # odd rows force block shrink
    ((1, 512), 1024, jnp.float32),      # block > rows
]


@pytest.mark.parametrize("shape,block,dtype", CASES)
def test_rmsnorm_matches_ref(shape, block, dtype, rng):
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, shape, jnp.float32).astype(dtype)
    w = jax.random.normal(k2, (shape[-1],), jnp.float32)
    got = rmsnorm(x, w, block_rows=block)
    want = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_matches_model_layer(rng):
    from repro.models.layers import rmsnorm as layer_rmsnorm
    x = jax.random.normal(rng, (4, 32, 128), jnp.bfloat16)
    w = jnp.ones((128,), jnp.float32)
    got = rmsnorm(x, w)
    want = layer_rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2,
                               atol=2e-2)
