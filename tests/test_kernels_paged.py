"""Fused paged-attention decode kernel: equivalence sweep vs the gather
oracle and the contiguous slot-decode path, plus junk-page masking.

The kernel's contract (kernels/paged_attention.py) is *token identity*
with the gather-then-attend path, so the sweep crosses page size x
pages-per-slot x GQA ratio x per-slot lengths — including freed slots
whose page-table rows point at the reserved junk page 0 — and checks
three-way agreement: paged-Pallas == gather oracle == contiguous
slot-decode attention over the same KV.
"""

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.kernels import ref  # noqa: E402
from repro.kernels.ops import paged_attention  # noqa: E402
from repro.models.layers import dot_attention  # noqa: E402


def make_case(seed, lens, page_size, max_pages, K, G, dh, dtype,
              poison=0.0):
    """A random page pool + *shuffled* page tables holding `lens` tokens
    per slot (0 = freed slot: zeroed page-table row).  `poison` fills the
    reserved junk page 0 so any read through it is loud."""
    slots = len(lens)
    held = [min(-(-L // page_size), max_pages) if L else 0 for L in lens]
    num_pages = sum(held) + 1
    # non-sequential page ids exercise the indirection, not just offsets
    order = np.random.default_rng(seed).permutation(
        np.arange(1, num_pages, dtype=np.int32))
    table = np.zeros((slots, max_pages), np.int32)
    i = 0
    for s_, h in enumerate(held):
        table[s_, :h] = order[i:i + h]
        i += h
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (slots, K * G, dh), jnp.float32).astype(dtype)
    kp = jax.random.normal(
        kk, (num_pages, page_size, K, dh), jnp.float32).astype(dtype)
    vp = jax.random.normal(
        kv, (num_pages, page_size, K, dh), jnp.float32).astype(dtype)
    if poison:
        kp = kp.at[0].set(poison)
        vp = vp.at[0].set(poison)
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lens, jnp.int32)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


CASES = [
    # (page_size, max_pages, K, G, dh, lens, dtype)
    (8, 4, 2, 2, 32, [32, 17, 8, 1], jnp.float32),
    (4, 4, 1, 4, 32, [16, 3, 0, 9], jnp.float32),        # MQA + freed slot
    (16, 2, 4, 1, 16, [32, 31, 30, 5], jnp.bfloat16),    # MHA, bf16 pool
    (8, 8, 2, 4, 64, [64, 1, 40, 0, 23], jnp.bfloat16),
]


@pytest.mark.parametrize("psize,mp,K,G,dh,lens,dtype", CASES)
def test_paged_kernel_three_way_equivalence(psize, mp, K, G, dh, lens,
                                            dtype):
    q, kp, vp, table, kv_len = make_case(7, lens, psize, mp, K, G, dh,
                                         dtype, poison=1e4)
    out = np.asarray(paged_attention(q, kp, vp, table, kv_len), np.float32)
    # gather oracle: masks junk pages, zeroes fully-masked rows — every
    # row comparable, freed slots included
    want = np.asarray(
        ref.paged_attention_ref(q, kp, vp, table, kv_len), np.float32)
    np.testing.assert_allclose(out, want, rtol=_tol(dtype), atol=_tol(dtype))
    # contiguous slot decode: the same KV laid out (slots, t, K, dh),
    # attended with per-row lengths — live slots only (a fully-masked
    # contiguous row softmaxes to uniform, by design its output is
    # discarded upstream)
    t = mp * psize
    kc = jnp.take(kp, table, axis=0).reshape(len(lens), t, K, dh)
    vc = jnp.take(vp, table, axis=0).reshape(len(lens), t, K, dh)
    cont = dot_attention(q[:, None], kc, vc, causal=True,
                         q_offset=kv_len - 1, kv_len=kv_len)[:, 0]
    live = np.asarray(kv_len) > 0
    np.testing.assert_allclose(out[live],
                               np.asarray(cont, np.float32)[live],
                               rtol=_tol(dtype), atol=_tol(dtype))


@settings(max_examples=8, deadline=None)
@given(psize=st.sampled_from([4, 8]),
       mp=st.sampled_from([2, 3, 4]),
       K=st.sampled_from([1, 2]),
       G=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 10_000),
       lens=st.lists(st.integers(0, 32), min_size=2, max_size=5))
def test_paged_kernel_hypothesis_sweep(psize, mp, K, G, seed, lens):
    lens = [min(L, psize * mp) for L in lens]
    if not any(lens):
        lens[0] = 1
    q, kp, vp, table, kv_len = make_case(seed, lens, psize, mp, K, G, 16,
                                         jnp.float32, poison=1e4)
    out = np.asarray(paged_attention(q, kp, vp, table, kv_len), np.float32)
    want = np.asarray(
        ref.paged_attention_ref(q, kp, vp, table, kv_len), np.float32)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_freed_slots_exact_zero_under_poisoned_junk():
    """A freed/preempted slot (zeroed page-table row, stale nonzero
    kv_len — exactly what the decode step's `safe_pages` produces for
    inactive rows) must output exactly 0: the junk page is skipped
    in-kernel, never averaged in."""
    lens = [24, 13, 7]
    q, kp, vp, table, kv_len = make_case(3, lens, 8, 4, 2, 2, 32,
                                         jnp.float32, poison=1e6)
    table = table.at[1].set(0)          # freed mid-flight; kv_len stays 13
    out = np.asarray(paged_attention(q, kp, vp, table, kv_len))
    assert np.all(out[1] == 0.0), "freed slot read the junk page"
    # the other slots are untouched by the free
    want = np.asarray(
        ref.paged_attention_ref(q, kp, vp, table, kv_len))
    np.testing.assert_allclose(out[0], want[0], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(out[2], want[2], rtol=2e-5, atol=2e-5)


def test_junk_page_contents_never_leak_into_live_slots():
    """Live-slot outputs are bitwise independent of what rots in the
    reserved junk page (freed slots' dead decode writes land there)."""
    lens = [17, 9, 32]
    clean = make_case(11, lens, 8, 4, 2, 2, 32, jnp.float32, poison=0.0)
    dirty = make_case(11, lens, 8, 4, 2, 2, 32, jnp.float32, poison=1e6)
    out_clean = np.asarray(paged_attention(*clean[:3], clean[3], clean[4]))
    out_dirty = np.asarray(paged_attention(*dirty[:3], dirty[3], dirty[4]))
    np.testing.assert_array_equal(out_clean, out_dirty)


def test_paged_kernel_rejects_bad_gqa():
    q = jnp.zeros((2, 3, 16))            # H=3 not divisible by K=2
    kp = jnp.zeros((4, 8, 2, 16))
    with pytest.raises(AssertionError):
        paged_attention(q, kp, kp, jnp.zeros((2, 2), jnp.int32),
                        jnp.zeros((2,), jnp.int32))
