"""Vstep-clocked telemetry: the MetricsRegistry schema both
``to_metrics()`` views are built on (keys can't drift from the
``router.py`` docstring table), Tracer span/ring semantics, the
bit-identity guarantee (tracing-on streams == tracing-off), Chrome-trace
export validity + byte determinism, AutoscaleEvent log replay, the
BENCH_serving.json structural validator, and the ``--trace-out`` /
``--metrics-out`` / ``--prom-out`` launcher flags."""

import json
import re
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).parent.parent / "benchmarks"))

import validate_bench  # noqa: E402
from repro.serving import (EVENT_KINDS, PHASES, ROUTER_SCHEMA, SERVE_SCHEMA,
                           AutoscaleEvent, AutoscalePolicy, MetricSpec,
                           MetricsRegistry, NGramDrafter, ReplicaRouter,
                           ServeEngine, Tracer, chrome_trace,
                           poisson_arrivals, prometheus_text,
                           replay_peak_replicas, sharedprefix_trace,
                           write_chrome_trace, zipf_trace)
from repro.serving import router as router_mod

ARCH = "picolm-4-smoke"

_ENGINES: dict = {}


def engine_for(layout="paged", page_size=8, num_pages=5, slots=3,
               max_len=64, spec_k=0):
    """Engines are expensive (jit); share them across tests by config."""
    key = (layout, page_size, num_pages, slots, max_len, spec_k)
    if key not in _ENGINES:
        _ENGINES[key] = ServeEngine(
            arch=ARCH, target="local:cpu", num_slots=slots, max_len=max_len,
            seed=0, kv_layout=layout, page_size=page_size,
            num_pages=num_pages, spec_k=spec_k, log=lambda *a, **k: None)
    return _ENGINES[key]


def _tokens(stats):
    return {r.rid: r.tokens for r in stats.results}


# ---------------------------------------------------------------------------
# MetricsRegistry: schema enforcement, instruments, Prometheus rendering


def test_registry_rejects_undeclared_and_incomplete():
    reg = MetricsRegistry((MetricSpec("a_total", "counter", "a"),
                           MetricSpec("b_now", "gauge", "b")))
    reg.set("a_total", 3)
    with pytest.raises(KeyError):
        reg.set("not_declared", 1)
    with pytest.raises(ValueError, match="b_now"):
        reg.snapshot()                      # declared b_now never set
    reg.set("b_now", 0.5)
    assert reg.snapshot() == {"a_total": 3, "b_now": 0.5}
    with pytest.raises(ValueError):
        reg.declare(MetricSpec("a_total", "counter", "dup"))
    with pytest.raises(ValueError):
        reg.declare(MetricSpec("bad key!", "gauge", ""))


def test_registry_template_keys_expand_per_replica():
    reg = MetricsRegistry(ROUTER_SCHEMA)
    for i in (0, 1, 7):
        reg.set(f"replica{i}_generated_tokens", i)
    assert reg.spec_for("replica7_occupancy").kind == "gauge"
    with pytest.raises(KeyError):
        reg.spec_for("replicaX_generated_tokens")
    snap = reg.snapshot(require_complete=False)
    assert snap["replica7_generated_tokens"] == 7


def test_registry_kind_discipline():
    reg = MetricsRegistry()
    reg.declare(MetricSpec("hits_total", "counter", ""))
    reg.declare(MetricSpec("lat_steps", "histogram", ""), buckets=(1, 4))
    reg.inc("hits_total")
    reg.inc("hits_total", 2)
    with pytest.raises(ValueError):
        reg.observe("hits_total", 1)
    with pytest.raises(ValueError):
        reg.set("lat_steps", 1)
    for v in (1, 2, 3, 99):
        reg.observe("lat_steps", v)
    snap = reg.snapshot()
    assert snap["hits_total"] == 3
    assert snap["lat_steps_count"] == 4
    assert snap["lat_steps_sum"] == 105.0
    assert snap["lat_steps_le_1"] == 1      # per-bucket (non-cumulative)
    assert snap["lat_steps_le_4"] == 2


def test_prometheus_text_format():
    schema = (MetricSpec("x_total", "counter", "things done"),
              MetricSpec("y_now", "gauge", ""))
    text = prometheus_text({"x_total": 4, "y_now": float("nan"),
                            "z_free": 1.5}, schema)
    lines = text.splitlines()
    assert "# HELP x_total things done" in lines
    assert "# TYPE x_total counter" in lines
    assert "x_total 4" in lines
    assert "y_now NaN" in lines             # valid Prometheus literal
    assert "# TYPE z_free gauge" in lines   # undeclared key -> bare gauge
    assert text.endswith("\n")


# ---------------------------------------------------------------------------
# Satellite: both to_metrics() views are registry views over the schema


def test_serve_stats_to_metrics_matches_schema():
    eng = engine_for()
    reqs = zipf_trace(6, eng.cfg.vocab_size, max_prompt=16, max_new=6,
                      seed=0)
    m = eng.run(reqs, policy="continuous").to_metrics()
    assert list(m) == [s.key for s in SERVE_SCHEMA]
    assert m["serve_requests_completed"] == 6
    assert all(v is not None for k, v in m.items()
               if not isinstance(v, float) or v == v)


def test_router_stats_to_metrics_matches_schema():
    eng = engine_for()
    reqs = zipf_trace(6, eng.cfg.vocab_size, max_prompt=16, max_new=6,
                      seed=0)
    router = ReplicaRouter([eng, eng], log=lambda *a, **k: None)
    m = router.run(reqs, policy="continuous").to_metrics()
    exact = [s.key for s in ROUTER_SCHEMA if "{i}" not in s.key]
    assert [k for k in m if not k.startswith("replica")] == exact
    reg = MetricsRegistry(ROUTER_SCHEMA)
    for i in range(2):
        for t in (s.key for s in ROUTER_SCHEMA if "{i}" in s.key):
            assert t.format(i=i) in m
    for key in m:                           # every key resolves in-schema
        reg.spec_for(key)


def _docstring_table_rows():
    """Parse the reST metric table out of router.py's module docstring."""
    doc = router_mod.__doc__
    rows = []
    in_table = seen_header = False
    for line in doc.splitlines():
        if re.fullmatch(r"=+(\s+=+)+", line.strip()):
            if in_table and seen_header:
                in_table = False            # closing rule
            elif in_table:
                seen_header = True          # rule under the header row
            else:
                in_table, seen_header = True, False
            continue
        if in_table and seen_header and line.strip():
            key, kind = line.split()[:2]
            rows.append((key, kind))
    return rows


def test_router_docstring_table_matches_schema():
    """The docstring's key table IS the export: same keys, same kinds,
    nothing missing, nothing extra (satellite: docs can't drift)."""
    rows = _docstring_table_rows()
    assert rows, "metric table not found in router.py docstring"
    assert len(rows) == len(ROUTER_SCHEMA)  # no duplicate rows either
    assert dict(rows) == {s.key: s.kind for s in ROUTER_SCHEMA}


# ---------------------------------------------------------------------------
# Tracer span/ring semantics


def test_tracer_span_matching_and_close():
    tr = Tracer()
    tr.begin("queued", 1, 0, replica=0)
    assert tr.end("queued", 1, 3, pending_tokens=8)
    (s,) = tr.spans_of("queued")
    assert (s.v_start, s.v_end, s.steps) == (0, 3, 3)
    assert s.attrs["pending_tokens"] == 8
    assert not tr.end("decode", 1, 4)       # never opened: counted, no crash
    assert tr.unmatched_ends == 1
    tr.begin("resume", 2, 5)
    assert tr.end_any(("resume", "queued"), 2, 7)
    tr.begin("decode", 3, 8)
    assert tr.close(10) == 1                # flushes the open decode span
    assert tr.spans_of("decode")[0].v_end == 10
    assert tr._open == {}


def test_tracer_rebegin_closes_old_and_ring_bounds():
    tr = Tracer(ring_capacity=4)
    tr.begin("decode", 9, 0)
    tr.begin("decode", 9, 5)                # re-begin same (rid, phase)
    first, second = tr.spans_of("decode")
    assert first.v_end == 5 and second.v_start == 5
    for v in range(10):
        tr.instant("preempt", v, replica=0, rid=v)
    assert tr.total_events == 10
    assert len(tr.events) == 4
    assert tr.dropped_events == 6
    assert [e.vstep for e in tr.events_of("preempt")] == [6, 7, 8, 9]
    with pytest.raises(ValueError):
        Tracer(ring_capacity=0)


def test_tracer_metrics_registry_view():
    tr = Tracer()
    tr.span("prefill_chunk", 1, 0, 1)
    tr.span("decode", 1, 1, 9)
    tr.instant("reroute", 3, replica=1, rid=1)
    snap = tr.metrics().snapshot(require_complete=False)
    assert snap["trace_spans_total"] == 2
    assert snap["trace_events_total"] == 1
    assert snap["trace_prefill_chunk_spans"] == 1
    assert snap["trace_span_vsteps_count"] == 2
    assert snap["trace_span_vsteps_sum"] == 9.0


# ---------------------------------------------------------------------------
# Keystone: tracing is observationally free, traces are byte-reproducible


def _full_fleet_run(tracer=None):
    """An openloop_poisson_autoscale-style drain that exercises every
    lifecycle phase: paged spec engines under page pressure (preempt +
    resume), chunked prefill, shared-prefix cache (cache_attach +
    reclaim), Poisson arrivals, SLO admission, autoscaling."""
    eng = engine_for(spec_k=2)
    reqs = sharedprefix_trace(10, eng.cfg.vocab_size, n_heads=2, head_len=8,
                              max_suffix=10, max_new=10, seed=3)
    reqs = poisson_arrivals(reqs, mean_gap=2.0, seed=7)
    router = ReplicaRouter([eng, eng, eng], log=lambda *a, **k: None)
    stats = router.run(reqs, policy="continuous", prefill_chunk=8,
                       prefix_cache=True, slo_ttft_steps=30,
                       slo_e2e_steps=200, admission="reject",
                       autoscale=AutoscalePolicy(min_replicas=1,
                                                 max_replicas=3),
                       tracer=tracer)
    return stats


def test_tracing_on_streams_bit_identical_to_off():
    baseline = _full_fleet_run(tracer=None)
    tr = Tracer()
    traced = _full_fleet_run(tracer=tr)
    assert _tokens(traced) == _tokens(baseline)
    assert traced.total_vsteps == baseline.total_vsteps
    wall = ("router_wall_s", "router_tokens_per_s")   # ADVISORY only
    strip = lambda m: {k: v for k, v in m.items() if k not in wall}
    assert strip(traced.to_metrics()) == strip(baseline.to_metrics())
    assert tr.spans                          # and it actually traced


def test_full_run_covers_every_phase_and_scales():
    tr = Tracer()
    stats = _full_fleet_run(tracer=tr)
    assert {s.phase for s in tr.spans} == set(PHASES)
    kinds = {e.kind for e in tr.events}
    assert "autoscale_grow" in kinds
    assert "preempt" in kinds
    assert kinds <= set(EVENT_KINDS)
    assert tr._open == {}                    # everything closed at drain end
    # spans carry the structured attributes the timeline reader needs
    chunk = tr.spans_of("prefill_chunk")[0]
    assert {"index", "tokens", "offset"} <= chunk.attrs.keys()
    verify = tr.spans_of("spec_verify")[0]
    assert {"k", "emitted", "accepted"} <= verify.attrs.keys()
    assert stats.peak_replicas >= 2


def test_chrome_trace_valid_and_byte_identical(tmp_path):
    tr1, tr2 = Tracer(), Tracer()
    _full_fleet_run(tracer=tr1)
    _full_fleet_run(tracer=tr2)
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    trace = write_chrome_trace(tr1, p1)
    write_chrome_trace(tr2, p2)
    assert p1.read_bytes() == p2.read_bytes()   # byte-identical runs
    data = json.loads(p1.read_text())           # valid JSON
    assert data == trace
    evs = data["traceEvents"]
    by_ph = {}
    for ev in evs:
        by_ph.setdefault(ev["ph"], []).append(ev)
    # >= 1 complete-event span per lifecycle phase
    names = {ev["name"] for ev in by_ph["X"]}
    assert set(PHASES) <= names
    # autoscale instants present, phase "i" with scope
    inst = [ev for ev in by_ph["i"] if ev["name"].startswith("autoscale_")]
    assert inst and all(ev["s"] == "p" for ev in inst)
    # metadata: one process per replica, slot threads + queue lane
    procs = [ev for ev in by_ph["M"] if ev["name"] == "process_name"]
    threads = [ev for ev in by_ph["M"] if ev["name"] == "thread_name"]
    assert {p["pid"] for p in procs} == {0, 1, 2}
    assert {t["args"]["name"] for t in threads} >= {"queue", "slot 0"}
    # vstep clock only: integer timestamps, no wall-clock anywhere
    assert all(isinstance(ev["ts"], int) for ev in evs if "ts" in ev)
    ts = [ev["ts"] for ev in by_ph["X"]]
    assert ts == sorted(ts)                     # monotone for Perfetto


# ---------------------------------------------------------------------------
# Satellite: the AutoscaleEvent log is deterministic and replayable


def test_autoscale_event_log_deterministic_and_replays_peak():
    s1 = _full_fleet_run()
    s2 = _full_fleet_run()
    assert s1.autoscale_events == s2.autoscale_events
    assert s1.autoscale_events, "autoscaler never acted — config regressed"
    assert replay_peak_replicas(s1.autoscale_events, 1) == s1.peak_replicas


def test_replay_peak_replicas_state_machine():
    import dataclasses
    ev = lambda action, r, serving: AutoscaleEvent(
        vstep=0, action=action, replica=r, serving=serving,
        per_replica_cap=4)
    log = [ev("grow", 1, 2), ev("grow", 2, 3), ev("drain", 2, 2),
           ev("stop", 2, 2), ev("drain", 1, 1)]
    assert replay_peak_replicas(log, 1) == 3
    assert replay_peak_replicas([], 2) == 2
    with pytest.raises(ValueError):          # serving count inconsistent
        replay_peak_replicas([ev("grow", 1, 9)], 1)
    bogus = dataclasses.replace(ev("grow", 1, 2), action="explode")
    with pytest.raises(ValueError):
        replay_peak_replicas([bogus], 1)


# ---------------------------------------------------------------------------
# Satellite: BENCH_serving.json structural validator


def _valid_bench():
    path = Path(__file__).parent.parent / "BENCH_serving.json"
    return validate_bench.parse_strict(path.read_text())


def test_validator_accepts_checked_in_bench():
    assert validate_bench.check(_valid_bench()) == []


def test_validator_flags_structural_drift():
    data = _valid_bench()
    data["cells"]["mystery_cell"] = {"tokens_per_s": 1.0}
    data["cells"]["paged_continuous"].pop("decode_steps")
    data["cells"]["paged_spec_on"]["surprise"] = 1
    data["cells"]["contiguous_static"]["tokens_per_step"] = "fast"
    del data["trace_seed"]
    problems = "\n".join(validate_bench.check(data))
    assert "mystery_cell" in problems
    assert "decode_steps" in problems
    assert "surprise" in problems
    assert "'fast'" in problems
    assert "trace_seed" in problems


def test_validator_rejects_nan_literals():
    with pytest.raises(ValueError, match="NaN"):
        validate_bench.parse_strict('{"cells": {"x": {"y": NaN}}}')
    assert validate_bench.parse_strict('{"y": null}') == {"y": None}


# ---------------------------------------------------------------------------
# Drafter instrumentation counters


def test_ngram_drafter_counts_hits_and_fallbacks():
    d = NGramDrafter(max_n=2)
    ctx = [1, 2, 3, 1, 2]
    d.draft(ctx, 3)                          # suffix (1,2) seen -> 3, ...
    assert d.calls == 1
    assert d.drafted_tokens == 3
    assert d.ngram_hits + d.fallbacks == 3
    assert d.ngram_hits >= 1
    d2 = NGramDrafter(max_n=3)
    d2.draft([7], 2)      # first token has no earlier suffix: fallback;
    assert d2.fallbacks == 1                 # then (7,7) -> period-1 hit
    assert d2.ngram_hits == 1


# ---------------------------------------------------------------------------
# Satellite: launcher flags write metrics / prometheus / trace files


def _launch(tmp_path, tag, **kw):
    from repro.launch.serve import serve_main
    paths = {k: tmp_path / f"{tag}_{k}.out" for k in
             ("trace_out", "metrics_out", "prom_out")}
    out = serve_main(arch=ARCH, batch=2, prefill_len=8, decode_tokens=4,
                     requests=3, max_len=32, seed=0,
                     log=lambda *a, **k: None,
                     **{k: str(p) for k, p in paths.items()}, **kw)
    return out, paths


@pytest.mark.parametrize("replicas,prefix", [(1, "serve_"), (2, "router_")])
def test_serve_main_telemetry_outputs(tmp_path, replicas, prefix):
    from repro.serving.telemetry import json_sanitize
    out, paths = _launch(tmp_path, f"x{replicas}", replicas=replicas)
    # metrics file: strict JSON (no NaN literals), matches the run's view
    metrics = validate_bench.parse_strict(paths["metrics_out"].read_text())
    assert any(k.startswith(prefix) for k in metrics)
    assert metrics[f"{prefix}requests_completed"] == 3
    assert metrics == json_sanitize(out["metrics"])
    prom = paths["prom_out"].read_text()
    assert f"# TYPE {prefix}requests_completed counter" in prom
    trace = json.loads(paths["trace_out"].read_text())
    assert {ev["name"] for ev in trace["traceEvents"]
            if ev["ph"] == "X"} >= {"queued", "decode"}
