"""easeylint: per-rule fixtures, suppression, CLI schema, repo-clean.

Every rule gets a violating snippet and a passing twin — the twin is as
important as the violation: a rule that fires on the repo idiom would
train everyone to sprinkle pragmas.  The repo-clean test then pins the
real invariant: ``src/`` + ``benchmarks/`` lint with zero errors under
the bundled allowlist.
"""

import json
import sys
from pathlib import Path

import pytest

from repro.analysis.lint import (LintConfig, default_config, lint_paths,
                                 lint_source)
from repro.analysis.lint import toml_lite
from repro.analysis.lint.__main__ import JSON_VERSION, main as lint_main

REPO = Path(__file__).parent.parent
sys.path.insert(0, str(REPO / "benchmarks"))

import validate_bench  # noqa: E402


def _errors(text, rel, cfg=None, rules=None):
    return [f for f in lint_source(text, rel, cfg, rules)
            if f.severity == "error"]


def _rules_fired(text, rel, cfg=None, rules=None):
    return {f.rule for f in _errors(text, rel, cfg, rules)}


# ---------------------------------------------------------------------------
# rule: wall-clock

def test_wall_clock_fires_on_call_and_reference():
    bad = (
        "import time\n"
        "def f():\n"
        "    return time.time()\n"
        "def g(clock=time.perf_counter):\n"
        "    return clock()\n"
    )
    errs = _errors(bad, "src/repro/x.py", rules=["wall-clock"])
    assert len(errs) == 2
    assert {e.line for e in errs} == {3, 4}


def test_wall_clock_catches_bare_import_and_datetime():
    bad = (
        "from time import perf_counter as pc\n"
        "import datetime\n"
        "def f():\n"
        "    return pc(), datetime.datetime.now()\n"
    )
    assert len(_errors(bad, "src/repro/x.py", rules=["wall-clock"])) == 2


def test_wall_clock_passing_twin_injected_clock():
    good = (
        "def f(clock):\n"
        "    return clock()\n"
        "def g(now=None):\n"
        "    return 0.0 if now is None else now\n"
    )
    assert _errors(good, "src/repro/x.py", rules=["wall-clock"]) == []


def test_wall_clock_exempts_timed_helper():
    good = (
        "import time\n"
        "def _timed(fn):\n"
        "    t0 = time.perf_counter()\n"
        "    out = fn()\n"
        "    return out, time.perf_counter() - t0\n"
    )
    assert _errors(good, "src/repro/x.py", rules=["wall-clock"]) == []


def test_wall_clock_pragma_same_line_and_line_above():
    good = (
        "import time\n"
        "a = time.time()  # easeylint: allow[wall-clock] — advisory\n"
        "# easeylint: allow[wall-clock]\n"
        "b = time.time()\n"
    )
    assert _errors(good, "src/repro/x.py", rules=["wall-clock"]) == []


def test_allowlist_suppresses_by_path_and_requires_reason():
    cfg = LintConfig.from_text(
        '[[allow]]\nrule = "wall-clock"\npath = "src/repro/adv/"\n'
        'reason = "wall-clock FOM file"\n')
    bad = "import time\nt = time.time()\n"
    assert _errors(bad, "src/repro/adv/b.py", cfg, ["wall-clock"]) == []
    assert len(_errors(bad, "src/repro/core/b.py", cfg,
                       ["wall-clock"])) == 1
    with pytest.raises(ValueError, match="reason"):
        LintConfig.from_text(
            '[[allow]]\nrule = "wall-clock"\npath = "x.py"\n')


# ---------------------------------------------------------------------------
# rule: telemetry-guard

def test_telemetry_guard_fires_unguarded():
    bad = (
        "def step(self):\n"
        "    self.tracer.begin('decode', 0)\n"
    )
    errs = _errors(bad, "src/repro/serving/x.py",
                   rules=["telemetry-guard"])
    assert len(errs) == 1 and errs[0].line == 2


def test_telemetry_guard_passing_idioms():
    good = (
        "def step(self, tracer):\n"
        "    if self.tracer is not None:\n"
        "        self.tracer.begin('a', 0)\n"
        "    if tracer is None:\n"
        "        return\n"
        "    tracer.emit('b')\n"
        "    ok = tracer is not None and tracer.emit('c')\n"
        "def other(sink):\n"
        "    assert sink is not None\n"
        "    sink.emit('d')\n"
    )
    assert _errors(good, "src/repro/serving/x.py",
                   rules=["telemetry-guard"]) == []


def test_telemetry_guard_nested_def_does_not_inherit():
    bad = (
        "def outer(tracer):\n"
        "    if tracer is not None:\n"
        "        def cb():\n"
        "            tracer.begin('x', 0)\n"  # closure may outlive guard
        "        return cb\n"
    )
    assert len(_errors(bad, "src/repro/serving/x.py",
                       rules=["telemetry-guard"])) == 1


# ---------------------------------------------------------------------------
# rule: keyed-rng

def test_keyed_rng_scoped_to_serving():
    bad = "import jax\nk = jax.random.PRNGKey(0)\n"
    assert _rules_fired(bad, "src/repro/serving/x.py",
                        rules=["keyed-rng"]) == {"keyed-rng"}
    assert _errors(bad, "src/repro/training/x.py",
                   rules=["keyed-rng"]) == []


def test_keyed_rng_fires_on_unfolded_and_reused_keys():
    bad = (
        "import jax\n"
        "def sample(base, logits):\n"
        "    k = jax.random.PRNGKey(7)\n"
        "    a = jax.random.categorical(k, logits)\n"       # base key draw
        "    b = jax.random.uniform(base)\n"
        "    c = jax.random.uniform(base)\n"                # reuse of param
        "    return a, b, c\n"
    )
    errs = _errors(bad, "src/repro/serving/x.py", rules=["keyed-rng"])
    msgs = "\n".join(e.message for e in errs)
    assert "literal PRNGKey(7)" in msgs
    assert "base key `k`" in msgs
    assert "reused" in msgs


def test_keyed_rng_passing_fold_in_chain():
    good = (
        "import jax\n"
        "def sample(base, rid, step, logits):\n"
        "    k = jax.random.fold_in(jax.random.fold_in(base, rid), step)\n"
        "    return jax.random.categorical(k, logits)\n"
    )
    assert _errors(good, "src/repro/serving/x.py",
                   rules=["keyed-rng"]) == []


# ---------------------------------------------------------------------------
# rule: jit-purity

def test_jit_purity_fires_on_captured_mutation_and_tracer():
    bad = (
        "import jax\n"
        "log = []\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    log.append(x)\n"
        "    return x * 2\n"
    )
    errs = _errors(bad, "src/repro/training/x.py", rules=["jit-purity"])
    assert len(errs) == 1 and "log.append" in errs[0].message
    bad2 = (
        "import jax\n"
        "def step(x, tracer):\n"
        "    tracer.emit('inside-trace')\n"
        "    return x\n"
        "out = jax.jit(step)\n"
    )
    assert _rules_fired(bad2, "src/repro/training/x.py",
                        rules=["jit-purity"]) == {"jit-purity"}


def test_jit_purity_transitive_and_pallas_refs_ok():
    # helper called from the scanned fn is traced transitively...
    bad = (
        "import jax.lax as lax\n"
        "seen = set()\n"
        "def helper(c):\n"
        "    seen.add(c)\n"
        "    return c\n"
        "def body(c, x):\n"
        "    return helper(c), x\n"
        "out = lax.scan(body, 0, None)\n"
    )
    errs = _errors(bad, "src/repro/training/x.py", rules=["jit-purity"])
    assert len(errs) == 1 and "seen.add" in errs[0].message
    # ...while a pallas kernel writing its own o_ref parameter is pure
    good = (
        "from jax.experimental import pallas as pl\n"
        "def kernel(x_ref, o_ref):\n"
        "    acc = x_ref[...] * 2\n"
        "    o_ref[...] = acc\n"
        "def call(x):\n"
        "    return pl.pallas_call(kernel, out_shape=None)(x)\n"
    )
    assert _errors(good, "src/repro/kernels/x.py",
                   rules=["jit-purity"]) == []


# ---------------------------------------------------------------------------
# rule: refcount-pairing

def test_refcount_fires_on_leaked_acquisition():
    bad = (
        "def admit(pool, slot, pages):\n"
        "    pool.attach(slot, pages)\n"
        "    return True\n"
    )
    errs = _errors(bad, "src/repro/serving/x.py",
                   rules=["refcount-pairing"])
    assert len(errs) == 1 and "attach" in errs[0].message


def test_refcount_passing_release_escape_and_raise():
    good = (
        "def paired(pool, slot, pages):\n"
        "    pool.attach(slot, pages)\n"
        "    pool.free(slot)\n"
        "def handoff(pool, slot, pages):\n"
        "    pool.adopt_run(slot, pages)\n"
        "    return slot\n"                       # ownership moves out
        "def stored(self, pool, slot, pages):\n"
        "    pool.reserve_prefix(slot, pages)\n"
        "    self.slots[slot] = pages\n"          # escape via store
        "def failing(pool, slot, pages):\n"
        "    pool.attach(slot, pages)\n"
        "    raise RuntimeError('evicted')\n"     # exception path exempt
    )
    assert _errors(good, "src/repro/serving/x.py",
                   rules=["refcount-pairing"]) == []


def test_refcount_branch_must_release_on_both_paths():
    bad = (
        "def admit(pool, slot, pages, ok):\n"
        "    pool.attach(slot, pages)\n"
        "    if ok:\n"
        "        pool.free(slot)\n"
        "    return ok\n"                         # leak on the else path
    )
    assert len(_errors(bad, "src/repro/serving/x.py",
                       rules=["refcount-pairing"])) == 1


# ---------------------------------------------------------------------------
# rule: vmem-budget

_VMEM_CFG = LintConfig(vmem_bounds={"d": 256})


def _kernel_src(bx, by):
    return (
        "from jax.experimental import pallas as pl\n"
        "def kern(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...]\n"
        "def run(x):\n"
        f"    return pl.pallas_call(kern, grid=(1,),\n"
        f"        in_specs=[pl.BlockSpec(({bx}, {by}), lambda i: (i, 0))],\n"
        f"        out_specs=pl.BlockSpec(({bx}, {by}), lambda i: (i, 0)),\n"
        "        out_shape=None)(x)\n"
    )


def test_vmem_estimate_info_within_budget():
    out = lint_source(_kernel_src(128, "d"), "src/repro/kernels/x.py",
                      _VMEM_CFG, ["vmem-budget"])
    infos = [f for f in out if f.severity == "info"]
    assert len(infos) == 1 and "estimated VMEM" in infos[0].message
    assert [f for f in out if f.severity == "error"] == []


def test_vmem_inflated_blockspec_fails():
    # 8192*8192 f32 = 256 MiB per block, x2 specs x2 double-buffering
    errs = _errors(_kernel_src(8192, 8192), "src/repro/kernels/x.py",
                   _VMEM_CFG, ["vmem-budget"])
    assert len(errs) == 1 and "exceeds" in errs[0].message


def test_vmem_dynamic_dim_is_an_error():
    errs = _errors(_kernel_src("n_runtime", 128), "src/repro/kernels/x.py",
                   _VMEM_CFG, ["vmem-budget"])
    assert errs and "dynamic block dimension" in errs[0].message


def test_vmem_scratch_and_bounds_resolution():
    src = (
        "from jax.experimental import pallas as pl\n"
        "from jax.experimental.pallas import tpu as pltpu\n"
        "import jax.numpy as jnp\n"
        "def kern(x_ref, o_ref, acc):\n"
        "    o_ref[...] = x_ref[...]\n"
        "def run(x, block_q: int = 64):\n"
        "    bq = min(block_q, 1 << 30)\n"
        "    return pl.pallas_call(kern, grid=(1,),\n"
        "        in_specs=[pl.BlockSpec((bq, d), lambda i: (i, 0))],\n"
        "        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),\n"
        "        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],\n"
        "        out_shape=None)(x)\n"
    )
    out = lint_source(src, "src/repro/kernels/x.py", _VMEM_CFG,
                      ["vmem-budget"])
    info = [f for f in out if f.severity == "info"][0]
    # blocks: 2 specs * 64*256*4 = 128 KiB (x2 buffering = 256), scratch 64
    assert "2x128 KiB blocks + 64 KiB scratch" in info.message
    assert [f for f in out if f.severity == "error"] == []


def test_vmem_reports_estimates_for_repo_kernels():
    cfg = default_config()
    want = {
        "src/repro/kernels/flash_attention.py": "flash_attention_pallas",
        "src/repro/kernels/paged_attention.py": "paged_attention_pallas",
        "src/repro/kernels/rmsnorm.py": "rmsnorm_pallas",
        "src/repro/kernels/sedov_stencil.py": "sedov_step_pallas",
    }
    for rel, fn_name in want.items():
        out = lint_source((REPO / rel).read_text(), rel, cfg,
                          ["vmem-budget"])
        infos = [f for f in out if f.severity == "info"]
        assert any(f"`{fn_name}`" in f.message for f in infos), rel


def test_vmem_budget_fraction_matches_tuning():
    from repro.analysis.lint.rules import vmem_budget
    from repro.core import tuning
    assert vmem_budget.VMEM_BUDGET_FRACTION == tuning.VMEM_BUDGET_FRACTION


# ---------------------------------------------------------------------------
# whole-repo invariants

def test_repo_lints_clean():
    findings, nfiles = lint_paths([REPO / "src", REPO / "benchmarks"])
    errors = [f for f in findings if f.severity == "error"]
    assert errors == [], "\n".join(f.render() for f in errors)
    assert nfiles > 50


def test_seeded_violation_fails_repo_lint():
    """An unguarded tracer call added to scheduler.py must fail CI."""
    rel = "src/repro/serving/scheduler.py"
    text = (REPO / rel).read_text()
    assert _errors(text, rel, default_config()) == []
    seeded = text + (
        "\n\ndef _drift(self):\n"
        "    self.tracer.begin('unguarded', 0)\n"
    )
    errs = _errors(seeded, rel, default_config())
    assert any(e.rule == "telemetry-guard" for e in errs)


# ---------------------------------------------------------------------------
# CLI / JSON schema

def test_cli_json_schema_stable(tmp_path, capsys):
    (tmp_path / "bad.py").write_text("import time\nt = time.time()\n")
    rc = lint_main([str(tmp_path / "bad.py"), "--format", "json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert set(out) == {"version", "files", "rules", "errors", "infos",
                        "findings"}
    assert out["version"] == JSON_VERSION
    assert out["files"] == 1 and out["errors"] == 1
    assert set(out["findings"][0]) == {"rule", "path", "line", "col",
                                       "severity", "message", "hint"}


def test_cli_exit_codes(tmp_path, capsys):
    (tmp_path / "ok.py").write_text("x = 1\n")
    assert lint_main([str(tmp_path / "ok.py")]) == 0
    assert lint_main([str(tmp_path / "missing_dir")]) == 2
    capsys.readouterr()


def test_cli_unknown_rule_rejected(tmp_path):
    (tmp_path / "ok.py").write_text("x = 1\n")
    with pytest.raises(ValueError, match="unknown rule"):
        lint_main([str(tmp_path / "ok.py"), "--rules", "nope"])


def test_syntax_error_is_a_finding():
    errs = _errors("def f(:\n", "src/repro/x.py")
    assert len(errs) == 1 and errs[0].rule == "parse"


# ---------------------------------------------------------------------------
# toml_lite

def test_toml_lite_subset():
    data = toml_lite.loads(
        '# comment\n'
        '[[allow]]\n'
        'rule = "wall-clock"  # trailing\n'
        'path = "a # not-a-comment.py"\n'
        'reason = "because"\n'
        '[vmem]\n'
        'target = "lrz:tpu-v5e-pod"\n'
        '[vmem.bounds]\n'
        'd = 8192\n'
        'frac = 0.5\n'
        'flag = true\n')
    assert data["allow"] == [{"rule": "wall-clock",
                              "path": "a # not-a-comment.py",
                              "reason": "because"}]
    assert data["vmem"]["target"] == "lrz:tpu-v5e-pod"
    assert data["vmem"]["bounds"] == {"d": 8192, "frac": 0.5,
                                      "flag": True}


def test_toml_lite_rejects_junk_with_line_numbers():
    with pytest.raises(toml_lite.TomlLiteError, match="line 2"):
        toml_lite.loads('[ok]\nwhat even is this\n')
    with pytest.raises(toml_lite.TomlLiteError, match="line 1"):
        toml_lite.loads('k = [1, 2]\n')


# ---------------------------------------------------------------------------
# validate_bench: wall_* keys are rejected in gated positions

def test_validate_bench_rejects_wall_keys():
    data = validate_bench.parse_strict(
        (REPO / "BENCH_serving.json").read_text())
    assert validate_bench.check(data) == []
    data["cells"]["paged_static"]["wall_latency_s"] = 1.23
    problems = "\n".join(validate_bench.check(data))
    assert "wall_latency_s" in problems and "gated position" in problems
