"""EASEY core: Appfile, JobSpec (paper §3), batch synthesis (Alg. 1),
package integrity, middleware staging, job state machine, tuner."""

import json
import tarfile

import pytest

from repro.core.appspec import AppSpec, parse_appfile
from repro.core.batch import make_batch, pbs_batch, slurm_batch
from repro.core.jobs import Job, JobState, LocalScheduler
from repro.core.jobspec import lulesh_example, parse_jobspec
from repro.core.target import get_target
from repro.core.tuning import tune
from repro.configs import SHAPES, get_config


# ---------------------------------------------------------------- Appfile

APPFILE = """\
FROM arch:deepseek-7b
SHAPE train_4k
###include_local_kernels###
###include_local_collectives###
RUN train --steps 50
"""


def test_appfile_roundtrip():
    spec = parse_appfile(APPFILE)
    assert spec.arch == "deepseek-7b"
    assert spec.shape == "train_4k"
    assert spec.run == "train --steps 50"
    spec2 = parse_appfile(spec.to_appfile())
    assert spec2.arch == spec.arch and spec2.shape == spec.shape


def test_appfile_rejects_unknown_directive():
    with pytest.raises(ValueError, match="unknown directive"):
        parse_appfile("FROM arch:deepseek-7b\nSHAPE train_4k\n###bogus###\n")


def test_appfile_accepts_paper_mpi_hook():
    spec = parse_appfile(
        "FROM arch:deepseek-7b\nSHAPE train_4k\n###includelocalmpi###\n")
    assert "###includelocalmpi###" in spec.directives


def test_appspec_hash_stable():
    a = AppSpec("deepseek-7b", "train_4k")
    b = AppSpec("deepseek-7b", "train_4k")
    assert a.content_hash() == b.content_hash()
    c = AppSpec("deepseek-7b", "decode_32k")
    assert a.content_hash() != c.content_hash()


# ---------------------------------------------------------------- JobSpec

def test_lulesh_listing_1_5_parses():
    spec = parse_jobspec(lulesh_example())
    assert spec.name == "lulesh_dash"
    assert spec.deployment.nodes == 46
    assert spec.deployment.tasks_per_node == 48
    assert spec.deployment.clocktime == "06:00:00"
    assert spec.executions[0].kind == "mpi"
    assert spec.executions[0].mpi_tasks == 2197  # 13^3 cores, paper Table 1
    assert "lulesh.dash -i 1000 -s 13" in spec.executions[0].command


def test_jobspec_id_hash_on_submission():
    spec = parse_jobspec({"job": {"name": "j"}})
    assert spec.job_id == ""
    jid = spec.ensure_id()
    assert len(jid) == 12 and spec.ensure_id() == jid


def test_gridftp_planned_next_release():
    with pytest.raises(NotImplementedError, match="next release"):
        parse_jobspec({"job": {"name": "x"}, "data": {"input": [
            {"source": "gsiftp://x/y", "protocol": "gridftp"}]}})


# ------------------------------------------------------------- batch files

def test_slurm_batch_golden():
    spec = parse_jobspec(lulesh_example())
    text = slurm_batch(spec, workdir="/scratch/j1")
    assert "#SBATCH --job-name=lulesh_dash" in text
    assert "#SBATCH --nodes=46" in text
    assert "#SBATCH --ntasks-per-node=48" in text
    assert "#SBATCH --time=06:00:00" in text
    assert "#SBATCH --mail-user=hoeb@mnm-team.org" in text
    assert "srun --ntasks=2197" in text
    assert "cd /scratch/j1" in text


def test_pbs_batch_golden():
    spec = parse_jobspec(lulesh_example())
    text = pbs_batch(spec)
    assert "#PBS -N lulesh_dash" in text
    assert "#PBS -l nodes=46:ppn=48" in text
    assert "mpirun -np 2197" in text


def test_unsupported_scheduler_matches_paper():
    spec = parse_jobspec({"job": {"name": "x"}})
    with pytest.raises(ValueError, match="not supported so far"):
        make_batch(spec, "lsf")


# ------------------------------------------------------------ job machine

def test_job_state_transitions():
    j = Job("id", "n")
    j.transition(JobState.RUNNING)
    j.transition(JobState.FAILED)
    j.transition(JobState.PENDING)  # requeue allowed
    with pytest.raises(ValueError):
        Job("id2", "n").transition(JobState.FINISHED)


def test_scheduler_runs_and_requeues():
    sched = LocalScheduler()
    calls = []

    def fn(job):
        calls.append(1)
        if len(calls) == 1:
            raise RuntimeError("boom")
        return 42

    jid = sched.submit(fn, "flaky")
    assert sched.status(jid) is JobState.FAILED
    assert "boom" in sched.logs(jid)[1]
    sched.requeue(jid)
    assert sched.status(jid) is JobState.FINISHED
    assert sched.result(jid) == 42
    assert sched.jobs[jid].restarts == 1


# ------------------------------------------------------------------ tuner

def test_tuner_nemotron_needs_8bit_moments():
    plan = tune(get_config("nemotron-4-340b"), SHAPES["train_4k"],
                get_target("lrz:tpu-v5e-pod"))
    assert plan.optimizer == "adamw8bit"
    assert plan.microbatches >= 8


def test_tuner_small_model_keeps_fp32():
    plan = tune(get_config("stablelm-1.6b"), SHAPES["train_4k"],
                get_target("lrz:tpu-v5e-pod"))
    assert plan.optimizer == "adamw"


def test_tuner_decode_no_remat():
    plan = tune(get_config("deepseek-7b"), SHAPES["decode_32k"],
                get_target("lrz:tpu-v5e-pod"))
    assert plan.remat_policy == "none"
    assert plan.microbatches == 1


def test_plan_json_roundtrip():
    from repro.core.plan import DeploymentPlan
    plan = tune(get_config("dbrx-132b"), SHAPES["train_4k"],
                get_target("lrz:tpu-v5e-2pod"))
    plan2 = DeploymentPlan.from_json(plan.to_json())
    assert plan2.mesh_shape == (2, 16, 16)
    assert plan2.optimizer == plan.optimizer
    assert "EASEY tuning report" in plan2.report()


# --------------------------------------------------- tuner: replica split

def _serve_plan(replicas: int):
    from repro.configs.base import ShapeConfig
    return tune(get_config("deepseek-7b"),
                ShapeConfig("d", 32768, 4096, "decode",
                            serve_replicas=replicas),
                get_target("lrz:tpu-v5e-pod"))


def test_tuner_splits_serve_budget_per_replica():
    """Per-replica slot/page counts shrink as the fleet grows: N replicas
    share one HBM budget, so each gets ~1/N of the KV pool."""
    plans = {n: _serve_plan(n) for n in (1, 2, 4)}
    assert plans[1].serve_replicas == 1 and plans[4].serve_replicas == 4
    assert plans[1].serve_slots > plans[2].serve_slots > plans[4].serve_slots
    assert plans[1].serve_num_pages > plans[2].serve_num_pages \
        > plans[4].serve_num_pages
    # ~proportional: a 4-way split leaves each replica about a quarter
    assert plans[4].serve_slots <= plans[1].serve_slots // 4 + 1
    assert plans[4].serve_num_pages <= plans[1].serve_num_pages // 4 + 1


def test_tuner_fleet_capacity_within_a_page_per_replica():
    """Splitting the budget loses at most rounding: the fleet's aggregate
    paged capacity stays within one page per replica (plus each replica's
    own reserved junk page) of the unsplit pool."""
    single = _serve_plan(1)
    for n in (2, 4, 8):
        plan = _serve_plan(n)
        fleet = plan.napkin["serve_fleet_tokens"]
        per_replica = (plan.serve_num_pages - 1) * plan.serve_page_size
        assert fleet == n * per_replica
        lost = single.napkin["serve_fleet_tokens"] - fleet
        assert 0 <= lost <= 2 * n * plan.serve_page_size


def test_tuner_replica_split_napkin_renders_and_roundtrips():
    from repro.core.plan import DeploymentPlan
    plan = _serve_plan(3)
    for key in ("serve_fleet_capacity", "serve_fleet_tokens", "serve_pool",
                "serve_pool_paged"):
        assert key in plan.napkin, key
    assert "per replica" in plan.napkin["serve_pool"]
    report = plan.report()
    assert "serve replicas  : 3" in report
    assert "serve_fleet_capacity" in report
    again = DeploymentPlan.from_json(plan.to_json())
    assert again.serve_replicas == 3
    assert again.serve_num_pages == plan.serve_num_pages
    # replicas=1 keeps the original single-engine phrasing
    assert "per replica" not in _serve_plan(1).napkin["serve_pool"]
