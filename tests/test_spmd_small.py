"""SPMD integration on 8 forced host devices (subprocess — the main test
process must keep seeing 1 device).  Covers: sharded train step execution,
elastic re-mesh, and a miniature dry-run with collectives in the HLO."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.appspec import AppSpec
from repro.core.build import BuildService
from repro.core.target import get_target
from repro.data.pipeline import DataPipeline
from repro.models.params import init_params, partition_specs
from repro.models.transformer import model_for
from repro.optim import make_optimizer
from repro.training.steps import init_train_state

out = {}

# --- 1. sharded training on a 2x4 mesh, real execution ---
app = AppSpec(arch="deepseek-7b-smoke", shape="train_4k",
              shape_overrides={"seq_len": 32, "global_batch": 4})
tgt = get_target("local:cpu-mesh8")
res = BuildService().build(app, tgt, lower=False)
model = model_for(app.model_config, remat=res.plan.remat_policy)
opt = make_optimizer(res.plan.optimizer)
params = init_params(model.param_table(), jax.random.PRNGKey(0))
state = init_train_state(model, opt, params, res.plan)
state = jax.device_put(state, res.in_shardings[0])
pipe = DataPipeline(model, app.shape_config, mesh=res.mesh)
step = jax.jit(res.step_fn, in_shardings=res.in_shardings,
               out_shardings=res.out_shardings, donate_argnums=(0,))
losses = []
for i in range(3):
    state, metrics = step(state, pipe.batch_at(i))
    losses.append(float(metrics["loss"]))
out["spmd_losses"] = losses
out["spmd_finite"] = all(np.isfinite(l) for l in losses)

# --- 2. HLO contains collectives ---
lowered = step.lower(state, pipe.batch_at(3))
txt = lowered.compile().as_text()
out["has_collectives"] = any(op in txt for op in
                             ("all-reduce", "reduce-scatter", "all-gather"))

# --- 3. elastic: restore the state onto a degraded 1x4 mesh and step ---
from repro.launch.mesh import _mesh
from repro.runtime.elastic import reshard_state
from repro.training.steps import train_state_table
host_state = jax.tree.map(lambda x: np.asarray(x), state)
small_mesh = _mesh((1, 4), ("data", "model"))
table = train_state_table(model, opt, res.plan)
restate = reshard_state(host_state, table, small_mesh)
from repro.training.steps import build_train_step
step2 = jax.jit(build_train_step(model, opt, res.plan, small_mesh))
pipe2 = DataPipeline(model, app.shape_config, mesh=small_mesh)
restate, m2 = step2(restate, pipe2.batch_at(3))
out["elastic_loss_finite"] = bool(np.isfinite(float(m2["loss"])))

print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_spmd_8dev_subprocess():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert out["spmd_finite"]
    assert out["has_collectives"]
    assert out["elastic_loss_finite"]
