"""Optimizer + training-step properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.plan import DeploymentPlan
from repro.optim import AdamW, AdamW8bit
from repro.optim.schedule import warmup_cosine


def _quadratic_problem(n=8, seed=0):
    rng = np.random.RandomState(seed)
    target = jnp.asarray(rng.randn(n), jnp.float32)
    params = {"w": jnp.zeros((n,), jnp.float32)}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss_fn, target


def test_adamw_converges_quadratic():
    params, loss_fn, target = _quadratic_problem()
    opt = AdamW(weight_decay=0.0)
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        params, state, _ = opt.update(g, state, params, lr=0.05)
    assert float(loss_fn(params)) < 1e-2


def test_adamw8bit_tracks_fp32():
    params, loss_fn, _ = _quadratic_problem(16)
    p32, p8 = params, jax.tree.map(jnp.copy, params)
    o32, o8 = AdamW(weight_decay=0.0), AdamW8bit(weight_decay=0.0)
    s32, s8 = o32.init(p32), o8.init(p8)
    for _ in range(100):
        g32 = jax.grad(loss_fn)(p32)
        g8 = jax.grad(loss_fn)(p8)
        p32, s32, _ = o32.update(g32, s32, p32, lr=0.05)
        p8, s8, _ = o8.update(g8, s8, p8, lr=0.05)
    l32, l8 = float(loss_fn(p32)), float(loss_fn(p8))
    assert l8 < 0.3, l8  # quantized moments still converge


def test_state_table_matches_init_structure():
    from repro.configs import smoke_config
    from repro.models.params import init_params, shape_structs
    from repro.models.transformer import model_for
    model = model_for(smoke_config("deepseek-7b"))
    params = init_params(model.param_table(), jax.random.PRNGKey(0))
    for opt in (AdamW(), AdamW8bit()):
        table = opt.state_table(model.param_table())
        declared = shape_structs(table)
        actual = opt.init(params)
        td = jax.tree.structure(declared)
        ta = jax.tree.structure(actual)
        assert td == ta, (opt.name, td, ta)
        for d, a in zip(jax.tree.leaves(declared), jax.tree.leaves(actual)):
            assert d.shape == a.shape and d.dtype == a.dtype


@settings(max_examples=10, deadline=None)
@given(micro=st.sampled_from([1, 2, 4]), seed=st.integers(0, 1000))
def test_grad_accumulation_equivalence(micro, seed):
    """Microbatched gradients == full-batch gradients (same update)."""
    from repro.configs import smoke_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import DataPipeline
    from repro.models.params import init_params
    from repro.models.transformer import model_for
    from repro.training.steps import build_train_step, init_train_state

    cfg = smoke_config("stablelm-1.6b")
    model = model_for(cfg, remat="none")
    params = init_params(model.param_table(), jax.random.PRNGKey(seed))
    opt = AdamW(weight_decay=0.0)
    shape = ShapeConfig("t", 16, 4, "train")
    batch = DataPipeline(model, shape, seed=seed).batch_at(0)

    outs = []
    for m in (1, micro):
        plan = DeploymentPlan(arch="x", shape="t", target="cpu",
                              mesh_shape=(1,), mesh_axes=("data",),
                              microbatches=m)
        state = init_train_state(model, opt, params, plan)
        step = build_train_step(model, opt, plan)
        new_state, metrics = step(state, batch)
        outs.append(new_state["params"])
    a = jax.tree.leaves(outs[0])
    b = jax.tree.leaves(outs[1])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_ef_int8_error_feedback_reduces_bias():
    from repro.training.steps import _ef_int8
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(256) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g)
    total_q = jnp.zeros_like(g)
    for i in range(64):
        q, err = _ef_int8(g, err)
        total_q = total_q + q
    # error feedback: accumulated quantized sum converges to the true sum
    rel = float(jnp.linalg.norm(total_q - 64 * g) / jnp.linalg.norm(64 * g))
    assert rel < 0.05, rel


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(jnp.asarray(0), peak_lr=1e-3, warmup_steps=10,
                              total_steps=100))
    lr10 = float(warmup_cosine(jnp.asarray(10), peak_lr=1e-3, warmup_steps=10,
                               total_steps=100))
    lr100 = float(warmup_cosine(jnp.asarray(100), peak_lr=1e-3,
                                warmup_steps=10, total_steps=100))
    assert lr0 == 0.0 and abs(lr10 - 1e-3) < 1e-9 and lr100 < 2e-4
