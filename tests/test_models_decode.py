"""Prefill + decode must reproduce the full-forward logits.

This is the strongest correctness property of the serving path: for every
family, running prefill on s tokens then decoding token s+1 must give the
same logits as a full forward over s+1 tokens at position s+1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticSource
from repro.models.params import init_params
from repro.models.transformer import model_for

ARCHS = ["deepseek-7b", "mistral-large-123b", "granite-moe-3b-a800m",
         "dbrx-132b", "xlstm-1.3b", "zamba2-7b", "whisper-tiny",
         "llava-next-34b", "stablelm-1.6b", "nemotron-4-340b"]


def _full_logits(model, params, batch, upto):
    cfg = model.cfg
    b = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        x = None
    # run model.loss's forward path manually: use prefill on the longer
    # prompt and take its last-logits as the reference
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_plus_decode_matches_longer_prefill(arch, rng):
    cfg = smoke_config(arch)
    model = model_for(cfg, remat="none")
    params = init_params(model.param_table(), rng)
    s = 32
    shape_long = ShapeConfig("p", s + 1, 2, "prefill")
    shape_short = ShapeConfig("p", s, 2, "prefill")
    src = SyntheticSource(cfg.vocab_size, 0)
    batch_long = {k: jnp.asarray(v) for k, v in
                  src.batch(model.batch_table(shape_long), 0).items()}

    def shorten(k, v):
        if k in ("tokens",):
            return v[:, :-1]
        if k == "frames":
            return v  # encoder length stays the same
        return v

    batch_short = {k: shorten(k, v) for k, v in batch_long.items()}

    logits_ref, _ = model.prefill(params, batch_long, None)
    logits_pre, cache = model.prefill(params, batch_short, None)

    # grow every attention kv cache by one slot for the decode step —
    # recursively, so the hybrid family's nested shared_kv cache is grown
    # too (an unpadded cache makes dynamic_update_slice clamp the write
    # onto the last prompt position, silently corrupting attention)
    def grow(c):
        out = {}
        for key, v in c.items():
            if isinstance(v, dict):
                out[key] = grow(v)
            elif key in ("k", "v") and hasattr(v, "ndim") and v.ndim >= 3:
                pad = [(0, 0)] * v.ndim
                pad[2 if v.ndim == 5 else 1] = (0, 1)
                out[key] = jnp.pad(v, pad)
            else:
                out[key] = v
        return out

    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid_mamba"):
        cache = grow(cache)
    next_tok = batch_long["tokens"][:, -1:]
    logits_dec, _ = model.decode_step(params, cache, next_tok, None)

    a = np.asarray(logits_ref[:, -1], np.float32)
    b = np.asarray(logits_dec[:, -1], np.float32)
    # bf16 models accumulate small divergence; demand tight agreement
    tol = 0.05 * (np.abs(a).max() + 1)
    assert np.abs(a - b).max() < tol, (arch, np.abs(a - b).max(), tol)
    # and the top-1 token must match for (almost) every row
    top_match = (a.argmax(-1) == b.argmax(-1)).mean()
    assert top_match >= 0.5, (arch, top_match)
