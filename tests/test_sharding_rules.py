"""Property tests for the sharding rules engine (the AutoTuner's
divisibility-fallback mechanism)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.rules import AxisRules, DEFAULT_RULES, logical_to_spec


def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    """A Mesh over numpy device placeholders — logical_to_spec only reads
    axis names/sizes, so real devices are unnecessary."""
    class _Dev:  # minimal stand-in
        def __init__(self, i):
            self.id = i
    devs = np.array([_Dev(i) for i in range(int(np.prod(shape)))]).reshape(shape)
    return Mesh(devs, axes)


MESH = _fake_mesh()
MESH3 = _fake_mesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard():
    spec = logical_to_spec(("embed", "mlp"), (2048, 5632), MESH, DEFAULT_RULES)
    assert spec == P("data", "model")


def test_indivisible_dim_falls_back_with_record():
    fb = []
    spec = logical_to_spec(("kv_heads", None), (8, 128), MESH, DEFAULT_RULES, fb)
    assert spec == P(None, None)       # 8 kv heads % 16 -> replicate
    assert any("kv_heads" in f for f in fb)


def test_axis_never_reused_within_spec():
    rules = AxisRules(rules={"a": ("model",), "b": ("model",)})
    spec = logical_to_spec(("a", "b"), (16, 16), MESH, rules)
    assert spec == P("model", None)    # second dim cannot reuse 'model'


def test_multi_axis_batch_on_multipod():
    spec = logical_to_spec(("act_batch", "act_seq"), (256, 4096), MESH3,
                           DEFAULT_RULES)
    assert spec == P(("pod", "data"), None)


def test_partial_multi_axis_when_batch_small():
    # batch=2 divides pod(2) but not pod*data(32): keep the pod factor only
    spec = logical_to_spec(("act_batch",), (2,), MESH3, DEFAULT_RULES)
    assert spec == P("pod")


@settings(max_examples=50, deadline=None)
@given(
    dim=st.integers(1, 4096),
    logical=st.sampled_from(["embed", "mlp", "heads", "vocab", "act_batch"]),
)
def test_spec_always_valid(dim, logical):
    """Whatever the dim, the produced spec's axis product divides it."""
    fb = []
    spec = logical_to_spec((logical,), (dim,), MESH3, DEFAULT_RULES, fb)
    entry = spec[0]
    if entry is None:
        return
    axes = entry if isinstance(entry, tuple) else (entry,)
    sizes = dict(zip(MESH3.axis_names, MESH3.devices.shape))
    prod = int(np.prod([sizes[a] for a in axes]))
    assert dim % prod == 0


@settings(max_examples=30, deadline=None)
@given(
    shape=st.lists(st.sampled_from([1, 2, 8, 16, 40, 96, 256, 4096]),
                   min_size=1, max_size=4),
)
def test_no_mesh_axis_used_twice(shape):
    logicals = ["act_batch", "heads", "mlp", "vocab"][:len(shape)]
    spec = logical_to_spec(tuple(logicals), tuple(shape), MESH3, DEFAULT_RULES)
    used = []
    for entry in spec:
        if entry is None:
            continue
        used += list(entry) if isinstance(entry, tuple) else [entry]
    assert len(used) == len(set(used)), spec
