"""Shared-prefix KV page cache: refcount lifecycle (a preempted sharer
must never free pages another request still references), LRU eviction
under page pressure (never while refcount > 1), suffix-only prefill
accounting, and the equivalence bar — cached and cold runs emit
bit-identical token streams across chunk sizes x shared-prefix depths x
preemption — plus the prefix-key unification and the serving-bench gate
fixes that ride along."""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "benchmarks"))
from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.serving import (KVCachePool, PagedKVCachePool, PrefixCache,
                           ReplicaRouter, ServeEngine, prefix_key,
                           prefix_replica, sharedprefix_trace)

ARCH = "deepseek-7b-smoke"

_ENGINES: dict = {}
_MODELS: dict = {}


def engine_for(page_size=16, num_pages=0, slots=6, max_len=64):
    """Engines are expensive (jit); share them across tests by config."""
    key = (page_size, num_pages, slots, max_len)
    if key not in _ENGINES:
        _ENGINES[key] = ServeEngine(
            arch=ARCH, num_slots=slots, max_len=max_len, seed=0,
            kv_layout="paged", page_size=page_size, num_pages=num_pages,
            log=lambda *a, **k: None)
    return _ENGINES[key]


def model():
    if "m" not in _MODELS:
        from repro.configs import smoke_config
        from repro.models.transformer import model_for
        _MODELS["m"] = model_for(smoke_config("deepseek-7b"), remat="none")
    return _MODELS["m"]


def _tokens(stats):
    return [r.tokens for r in sorted(stats.results, key=lambda r: r.rid)]


def _prompt(n, start=1):
    return np.arange(start, start + n, dtype=np.int32)


def _brute_reclaimable(cache):
    """O(cells) ground truth for the pool's O(1) cache-only counter."""
    refs = cache.pool.page_refs
    return sum(1 for c in cache._cells.values() if refs[c.page] == 1)


# ---------------------------------------------------------------------------
# Refcount lifecycle on the pool


def test_refcounted_insert_free_attach_reclaim():
    """The full life of a shared run: insert pins prompt-covered pages,
    a request freeing its slot only decrements, a hit re-attaches by
    pointer copy, and reclaim returns sole-cache pages to the free
    list."""
    pool = PagedKVCachePool(model(), num_slots=3, max_len=64,
                            page_size=8, num_pages=16)
    cache = PrefixCache(pool, max_pages=8)
    prompt = _prompt(20)                       # 3 pages, 2 fully covered
    s0 = pool.alloc()
    pool.reserve_prefix(s0, len(prompt))
    run = [int(pool.page_table[s0, i]) for i in range(2)]
    partial = int(pool.page_table[s0, 2])
    assert cache.insert(prompt, s0) == 2       # the partial page is mutable
    assert [int(pool.page_refs[p]) for p in run] == [2, 2]
    assert int(pool.page_refs[partial]) == 1

    free_before = pool.free_pages
    pool.free(s0)                              # request done
    assert pool.free_pages == free_before + 1  # only the partial page freed
    assert [int(pool.page_refs[p]) for p in run] == [1, 1]
    assert cache.reclaimable_pages == _brute_reclaimable(cache) == 2

    hit = cache.probe(prompt)
    assert hit.n_tokens == 16 and hit.pages == run and hit.pinned == 2
    s1 = pool.alloc()
    assert cache.attach(s1, prompt) == 16      # pointer copies, no KV writes
    pool.reserve_prefix(s1, len(prompt))
    assert [int(pool.page_table[s1, i]) for i in range(2)] == run
    assert [int(pool.page_refs[p]) for p in run] == [2, 2]
    assert cache.reclaimable_pages == _brute_reclaimable(cache) == 0

    pool.free(s1)                              # a sharer freeing never
    assert [int(pool.page_refs[p]) for p in run] == [1, 1]   # frees the run
    assert cache.reclaimable_pages == _brute_reclaimable(cache) == 2
    assert cache.reclaim(2) == 2
    assert cache.reclaimable_pages == _brute_reclaimable(cache) == 0
    assert [int(pool.page_refs[p]) for p in run] == [0, 0]
    assert cache.probe(prompt).n_tokens == 0
    assert cache.hits == 1 and cache.misses == 0 and cache.tokens_saved == 16


def test_preempted_sharer_leaves_other_requests_pages_alone():
    """Two sharers of one run: evicting (freeing) one must leave the
    run resident and readable for the other — the bug class refcounts
    exist to kill."""
    pool = PagedKVCachePool(model(), num_slots=3, max_len=64,
                            page_size=8, num_pages=16)
    cache = PrefixCache(pool)
    prompt_a = np.concatenate([_prompt(16), _prompt(5, start=100)])
    prompt_b = np.concatenate([_prompt(16), _prompt(7, start=200)])
    s0 = pool.alloc()
    pool.reserve_prefix(s0, len(prompt_a))
    cache.insert(prompt_a, s0)
    run = [int(pool.page_table[s0, i]) for i in range(2)]

    s1 = pool.alloc()
    cache.attach(s1, prompt_b)                 # shares both head pages
    pool.reserve_prefix(s1, len(prompt_b))
    assert [int(pool.page_refs[p]) for p in run] == [3, 3]

    pool.free(s1)                              # "preempted" sharer
    assert [int(pool.page_refs[p]) for p in run] == [2, 2]
    assert [int(pool.page_table[s0, i]) for i in range(2)] == run
    assert not pool._free_pages.is_free(run[0])
    assert not pool._free_pages.is_free(run[1])
    # reclaim refuses while the survivor still references the run
    assert cache.reclaim(2) == 0
    # and releasing an already-free page is caught, not silently negative
    pool.free(s0)
    cache.reclaim(2)
    assert [int(pool.page_refs[p]) for p in run] == [0, 0]
    with pytest.raises(ValueError, match="below zero"):
        pool.release_page(run[0])


def test_lru_eviction_order_and_shared_page_protection():
    """Reclaim takes the least-recently-used cells first, deepest page
    first within a chain, and never a cell whose page a live request
    still shares."""
    pool = PagedKVCachePool(model(), num_slots=4, max_len=64,
                            page_size=8, num_pages=20)
    cache = PrefixCache(pool)
    pa = np.concatenate([_prompt(16), _prompt(1, start=500)])
    pb = np.concatenate([_prompt(16, start=300), _prompt(1, start=600)])

    sa = pool.alloc()
    pool.reserve_prefix(sa, len(pa))
    cache.insert(pa, sa)
    run_a = [int(pool.page_table[sa, i]) for i in range(2)]
    pool.free(sa)
    sb = pool.alloc()
    pool.reserve_prefix(sb, len(pb))
    cache.insert(pb, sb)
    run_b = [int(pool.page_table[sb, i]) for i in range(2)]
    pool.free(sb)

    # touching A (an attach) makes B the LRU chain
    sc = pool.alloc()
    cache.attach(sc, pa)
    pool.reserve_prefix(sc, len(pa))
    pool.free(sc)
    assert cache.reclaim(2) == 2
    assert cache.probe(pb).n_tokens == 0       # B evicted ...
    assert cache.probe(pa).n_tokens == 16      # ... A survives

    # a live sharer pins A outright: nothing left to reclaim
    sd = pool.alloc()
    cache.attach(sd, pa)
    pool.reserve_prefix(sd, len(pa))
    assert cache.reclaimable_pages == 0
    assert cache.reclaim(4) == 0
    assert cache.probe(pa).pages == run_a
    assert run_a != run_b


def test_insert_respects_pin_budget():
    """max_pages caps cache-only pages: over-budget inserts evict LRU
    cells back under the tuner's pin quota."""
    pool = PagedKVCachePool(model(), num_slots=4, max_len=64,
                            page_size=8, num_pages=24)
    cache = PrefixCache(pool, max_pages=2)
    for i, start in enumerate((1, 300, 700)):
        p = np.concatenate([_prompt(16, start=start), _prompt(1, start=900)])
        s = pool.alloc()
        pool.reserve_prefix(s, len(p))
        cache.insert(p, s)
        pool.free(s)
    assert cache.reclaimable_pages <= 2
    assert cache.evictions >= 2


def test_pool_reclaims_cache_before_admission_fails():
    """Page pressure evicts cache cells before anything starves: a pool
    whose free list is exhausted but whose cache holds reclaimable pages
    still admits (only the cold suffix's pages are new)."""
    pool = PagedKVCachePool(model(), num_slots=2, max_len=32,
                            page_size=8, num_pages=4)   # 3 usable pages
    cache = PrefixCache(pool)
    pa = _prompt(17)                           # 3 pages, 2 cached
    s0 = pool.alloc()
    pool.reserve_prefix(s0, len(pa))
    cache.insert(pa, s0)
    pool.free(s0)
    assert pool.free_pages == 1                # 2 pinned by the cache
    assert pool.free_tokens == 3 * 8           # reclaimable counts as free
    pb = _prompt(17, start=400)                # no hit, needs all 3 pages
    assert pool.can_admit(len(pb), hit=cache.probe(pb))
    s1 = pool.alloc()
    pool.reserve_prefix(s1, len(pb))           # grows via LRU reclaim
    assert pool._pages_held[s1] == 3
    assert cache.probe(pa).n_tokens == 0       # cache gave way


def test_admission_reserves_only_cold_suffix():
    """With a full-run hit, can_admit asks for the suffix's pages alone —
    and does not double-count the hit's cache-only pages as spendable."""
    pool = PagedKVCachePool(model(), num_slots=2, max_len=32,
                            page_size=8, num_pages=4)   # 3 usable pages
    cache = PrefixCache(pool)
    pa = _prompt(17)
    s0 = pool.alloc()
    pool.reserve_prefix(s0, len(pa))
    cache.insert(pa, s0)
    pool.free(s0)                              # 1 free + 2 cache-pinned
    hit = cache.probe(pa)
    assert hit.pinned == 2
    # 3 pages total, 2 shared -> 1 cold page needed, 1 genuinely free
    assert pool.can_admit(len(pa), hit=hit)
    s1 = pool.alloc()
    assert cache.attach(s1, pa) == 16
    pool.reserve_prefix(s1, len(pa))
    assert pool.free_pages == 0
    # the same ask WITHOUT the hit would need 3 pages from 1 free + 0
    # reclaimable (the run is now shared, not reclaimable)
    assert not pool.can_admit(len(pa), hit=None)


def test_prefix_cache_requires_paged_layout():
    with pytest.raises(ValueError, match="paged"):
        PrefixCache(KVCachePool(model(), num_slots=2, max_len=32))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(arch=ARCH, num_slots=2, max_len=32,
                    kv_layout="contiguous", prefix_cache=True,
                    log=lambda *a, **k: None)


# ---------------------------------------------------------------------------
# Equivalence: cached == cold, bit-identical


def test_cached_matches_cold_and_saves_prefill():
    e = engine_for()
    reqs = sharedprefix_trace(12, e.cfg.vocab_size, seed=0)
    cold = e.run(reqs, prefix_cache=False)
    hot = e.run(reqs, prefix_cache=True)
    assert _tokens(hot) == _tokens(cold)
    assert hot.prefix_hits > 0
    assert hot.prefill_tokens_saved > 0
    assert hot.prefill_tokens + hot.prefill_tokens_saved == \
        cold.prefill_tokens
    assert hot.prefill_chunks < cold.prefill_chunks
    # deterministic: fresh pool + fresh cache per run replays exactly
    again = e.run(reqs, prefix_cache=True)
    assert _tokens(again) == _tokens(hot)
    assert again.prefix_hits == hot.prefix_hits
    assert again.prefill_tokens_saved == hot.prefill_tokens_saved


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([0, 4, 8, 16]),
       head_len=st.sampled_from([16, 32]),
       trace_seed=st.integers(min_value=0, max_value=25))
def test_cached_equivalence_sweep(chunk, head_len, trace_seed):
    """Hypothesis sweep: any chunk size (0 = blocking) x shared-prefix
    depth x trace is token-identical with the cache on or off."""
    e = engine_for()
    reqs = sharedprefix_trace(8, e.cfg.vocab_size, head_len=head_len,
                              seed=trace_seed)
    cold = e.run(reqs, prefill_chunk=chunk, prefix_cache=False)
    hot = e.run(reqs, prefill_chunk=chunk, prefix_cache=True)
    assert _tokens(hot) == _tokens(cold)


def test_preemption_with_sharing_matches_roomy_reference():
    """Page scarcity + sharing: preempting a sharer mid-flight must not
    corrupt the run other requests read — the resumed streams match a
    roomy cache-off reference bit for bit."""
    roomy = engine_for()
    scarce = engine_for(page_size=8, num_pages=13)     # 96 KV tokens
    reqs = sharedprefix_trace(10, roomy.cfg.vocab_size, head_len=16,
                              max_new=24, seed=3)
    ref = roomy.run(reqs, prefix_cache=False)
    got = scarce.run(reqs, prefill_chunk=8, prefix_cache=True)
    assert got.preemptions > 0
    assert _tokens(got) == _tokens(ref)
    again = scarce.run(reqs, prefill_chunk=8, prefix_cache=True)
    assert again.preemptions == got.preemptions
    assert _tokens(again) == _tokens(got)


def test_router_per_replica_caches_compose():
    """prefix_affinity colocates sharers, so per-replica caches hit
    without any cross-replica coordination — and the fleet's streams
    stay identical to the cache-off fleet."""
    e = engine_for()
    router = ReplicaRouter([e] * 3, policy="prefix_affinity",
                           log=lambda *a, **k: None)
    # more requests than the fleet holds at once: hits need a wave that
    # arrives after an earlier sharer's prefill completed (there is no
    # in-flight dedup — concurrent misses both pay, deterministically)
    reqs = sharedprefix_trace(30, e.cfg.vocab_size, seed=5)
    cold = router.run(reqs)
    hot = router.run(reqs, prefix_cache=True)
    assert _tokens(hot) == _tokens(cold)
    assert hot.prefill_tokens_saved > 0
    assert hot.prefill_tokens + hot.prefill_tokens_saved == \
        cold.prefill_tokens


def test_mixed_layout_fleet_applies_cache_to_paged_replicas_only():
    """A documented paged+contiguous fleet must run with the per-run
    prefix_cache override (paged replicas cache, contiguous ones do
    not) instead of crashing on the contiguous pool."""
    e_paged = engine_for()
    e_cont = ServeEngine(arch=ARCH, num_slots=2, max_len=64, seed=0,
                         kv_layout="contiguous", log=lambda *a, **k: None)
    router = ReplicaRouter([e_paged, e_cont], policy="prefix_affinity",
                           log=lambda *a, **k: None)
    reqs = sharedprefix_trace(8, e_paged.cfg.vocab_size, seed=7)
    cold = router.run(reqs)
    hot = router.run(reqs, prefix_cache=True)      # must not raise
    assert _tokens(hot) == _tokens(cold)
    # and Build-level mixing composes the same way
    mixed = ReplicaRouter.build(arch=ARCH, replicas=2,
                                kv_layout="paged,contiguous", num_slots=2,
                                max_len=64, prefix_cache=True,
                                log=lambda *a, **k: None)
    assert mixed.engines[0].prefix_cache
    assert not mixed.engines[1].prefix_cache


# ---------------------------------------------------------------------------
# Satellites: prefix-key unification, imbalance NaN, bench gates


def test_prefix_key_is_the_single_source():
    prompt = _prompt(20, start=5)
    assert prefix_key(prompt, 8) == \
        np.asarray(prompt, np.int32)[:8].tobytes()
    assert prefix_key(prompt) == np.asarray(prompt, np.int32).tobytes()
    # shorter-than-ask prompts key on what exists (numpy slice semantics)
    assert prefix_key(prompt[:3], 8) == \
        np.asarray(prompt[:3], np.int32).tobytes()
    # routing still consumes the same bytes deterministically
    assert prefix_replica(prompt, 3) == prefix_replica(prompt.copy(), 3)


def test_imbalance_nan_when_fleet_saw_no_traffic():
    from repro.serving import RouterStats
    from repro.serving.scheduler import ServeStats

    def zero():
        return ServeStats(results=[], wall_s=0.0, decode_steps=0,
                          generated_tokens=0, occupancy=0.0)
    rs = RouterStats(results=[], replica_stats=[zero(), zero()],
                     replica_of={}, wall_s=0.0)
    assert rs.imbalance != rs.imbalance        # NaN, not a fake 1.0
    busy = zero()
    busy.peak_resident_tokens = 8
    rs2 = RouterStats(results=[], replica_stats=[busy, zero()],
                      replica_of={}, wall_s=0.0)
    assert rs2.imbalance == 2.0
    # the benchmark emitter maps NaN to JSON null, not a bare NaN token
    from serving_throughput import _num
    assert _num(rs.imbalance) is None
    assert _num(1.23456) == 1.2346


def test_check_regression_guards_each_metric_independently():
    """A baseline cell predating tokens_per_step must still enforce the
    TTFT ceiling (the old `continue` skipped everything)."""
    from serving_throughput import _check_regression
    base = {"cells": {"c": {"tokens_per_s": 10.0, "mean_ttft_steps": 10.0}}}
    fresh = {"cells": {"c": {"tokens_per_s": 10.0, "tokens_per_step": 1.0,
                             "mean_ttft_steps": 20.0}}}
    with pytest.raises(SystemExit, match="TTFT"):
        _check_regression(base, fresh)
    ok = {"cells": {"c": {"tokens_per_s": 10.0, "tokens_per_step": 1.0,
                          "mean_ttft_steps": 10.0}}}
    _check_regression(base, ok)                # no tokens_per_step gate yet
    # and a dead prefix cache fails wherever the baseline had savings
    base2 = {"cells": {"c": {"tokens_per_s": 1.0,
                             "prefill_tokens_saved": 50}}}
    fresh2 = {"cells": {"c": {"tokens_per_s": 1.0,
                              "prefill_tokens_saved": 0}}}
    with pytest.raises(SystemExit, match="reuse went dead"):
        _check_regression(base2, fresh2)


def test_check_regression_fails_on_ungated_new_cells():
    from serving_throughput import _check_regression
    base = {"cells": {"a": {"tokens_per_s": 1.0}}}
    fresh = {"cells": {"a": {"tokens_per_s": 1.0},
                       "b": {"tokens_per_s": 1.0},
                       "c": {"tokens_per_s": 1.0}}}
    with pytest.raises(SystemExit, match="2 new cell.*refresh"):
        _check_regression(base, fresh)
    # removed cells still fail (coverage regression)
    with pytest.raises(SystemExit, match="missing"):
        _check_regression(fresh, base)


# ---------------------------------------------------------------------------
# Trace + tuner plumbing


def test_sharedprefix_trace_clusters_heads():
    a = sharedprefix_trace(16, 1000, seed=2)
    b = sharedprefix_trace(16, 1000, seed=2)
    assert [r.prompt.tolist() for r in a] == [r.prompt.tolist() for r in b]
    heads = [tuple(r.prompt[:32]) for r in a]
    assert len(set(heads)) <= 4
    # Zipf clustering: the most popular head dominates
    top = max(set(heads), key=heads.count)
    assert heads.count(top) >= len(a) // 2
    assert all(len(r.prompt) > 32 for r in a)  # >= 1 private suffix token


def test_tuner_carves_prefix_cache_budget():
    from repro.configs.base import ShapeConfig, get_config
    from repro.core.plan import DeploymentPlan
    from repro.core.target import get_target
    from repro.core.tuning import tune

    cfg = get_config(ARCH)
    plan = tune(cfg, ShapeConfig("d", 128, 8, "decode"),
                get_target("local:cpu"))
    assert 0 < plan.serve_prefix_cache_pages < plan.serve_num_pages
    assert "serve_prefix_cache" in plan.napkin
    again = DeploymentPlan.from_json(plan.to_json())
    assert again.serve_prefix_cache_pages == plan.serve_prefix_cache_pages
    assert "serve prefix" in plan.report()
