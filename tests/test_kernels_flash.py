"""Flash-attention kernel: shape/dtype sweep vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import flash_attention

CASES = [
    # (b, s, t, H, K, dh, bq, bk, causal, dtype)
    (1, 128, 128, 4, 4, 64, 64, 64, True, jnp.float32),
    (2, 256, 256, 4, 2, 64, 128, 64, True, jnp.float32),
    (1, 128, 128, 8, 1, 32, 64, 128, True, jnp.float32),   # MQA
    (2, 128, 128, 4, 4, 64, 128, 128, False, jnp.float32),
    (1, 256, 256, 6, 2, 64, 64, 64, True, jnp.bfloat16),
    (1, 64, 64, 2, 2, 128, 64, 64, True, jnp.bfloat16),
    (2, 512, 512, 2, 2, 64, 128, 128, True, jnp.float32),
]


@pytest.mark.parametrize("b,s,t,H,K,dh,bq,bk,causal,dtype", CASES)
def test_flash_matches_ref(b, s, t, H, K, dh, bq, bk, causal, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (b, s, H, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, t, K, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, t, K, dh), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=causal)
    a = np.asarray(out, np.float32)
    w = np.asarray(want, np.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(a, w, rtol=tol, atol=tol)


def test_flash_matches_model_attention_path(rng):
    """The kernel agrees with the model's chunked reference attention."""
    from repro.models.layers import dot_attention
    k1, k2, k3 = jax.random.split(rng, 3)
    b, s, H, K, dh = 2, 256, 4, 2, 64
    q = jax.random.normal(k1, (b, s, H, dh), jnp.float32)
    k = jax.random.normal(k2, (b, s, K, dh), jnp.float32)
    v = jax.random.normal(k3, (b, s, K, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = dot_attention(q, k, v, causal=True, q_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_rejects_bad_blocks(rng):
    q = jnp.zeros((1, 100, 2, 16))
    with pytest.raises(AssertionError):
        flash_attention(q, q[:, :, :1], q[:, :, :1], block_q=64, block_k=64)


def test_flash_short_kv_len_regression(rng):
    """Fully-masked key blocks must not poison the accumulator.

    With kv_len=32 and block_k=64, key block 1 is masked end-to-end; the
    old kernel computed p = exp(NEG_INF - NEG_INF) = 1 for every masked
    entry there, corrupting l/acc.  The fix zeroes p under the mask, so
    the padded cache attends exactly like a 32-long one."""
    from repro.models.layers import dot_attention
    k1, k2, k3 = jax.random.split(rng, 3)
    b, s, t, H, K, dh, kv_len = 1, 128, 128, 4, 2, 64, 32
    q = jax.random.normal(k1, (b, s, H, dh), jnp.float32)
    k = jax.random.normal(k2, (b, t, K, dh), jnp.float32)
    v = jax.random.normal(k3, (b, t, K, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          kv_len=kv_len)
    want = dot_attention(q, k, v, causal=True, kv_len=kv_len, q_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # garbage past kv_len must be invisible, not just down-weighted
    k_dirty = k.at[:, kv_len:].set(1e4)
    v_dirty = v.at[:, kv_len:].set(1e4)
    out_dirty = flash_attention(q, k_dirty, v_dirty, causal=True,
                                block_q=64, block_k=64, kv_len=kv_len)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_dirty))


def test_flash_kv_len_zero_yields_finite_zeros(rng):
    """A row with no valid key at all returns zeros (clamped denominator),
    never NaN/inf from the 0/0 the poisoning bug would produce."""
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (1, 64, 2, 32), jnp.float32)
    k = jax.random.normal(k2, (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(k3, (1, 64, 2, 32), jnp.float32)
    out = np.asarray(flash_attention(q, k, v, causal=False, block_q=64,
                                     block_k=64, kv_len=0))
    assert np.all(out == 0.0)
