"""Paged KV cache: page allocator lifecycle, fragmentation/exhaustion,
preemption-and-resume determinism, per-request sampling, and the keystone
equivalence — paged and contiguous layouts produce token-identical output
on the same mixed-length traces, while paged admits strictly more
concurrent requests under the same tuner HBM budget."""

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.configs import smoke_config
from repro.models.params import init_params
from repro.models.transformer import model_for
from repro.serving import (KVCachePool, PagedKVCachePool, PoolExhausted,
                           Request, ServeEngine, zipf_trace)

ARCH = "deepseek-7b-smoke"
SLOTS, MAX_LEN = 4, 64

_ENGINES: dict = {}


def engine_for(layout, page_size=0, num_pages=0, slots=SLOTS,
               max_len=MAX_LEN, target="local:cpu", kv_kernel="auto"):
    """Engines are expensive (jit); share them across tests by config."""
    key = (layout, page_size, num_pages, slots, max_len, target, kv_kernel)
    if key not in _ENGINES:
        _ENGINES[key] = ServeEngine(
            arch=ARCH, target=target, num_slots=slots, max_len=max_len,
            seed=0, kv_layout=layout, page_size=page_size,
            num_pages=num_pages, kv_kernel=kv_kernel,
            log=lambda *a, **k: None)
    return _ENGINES[key]


def _model():
    return model_for(smoke_config("deepseek-7b"), remat="none")


def _prefill_cache(model, params, n):
    toks = jnp.ones((1, n), jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks}, None)
    return cache


def _tokens(stats):
    return [r.tokens for r in sorted(stats.results, key=lambda r: r.rid)]


# ---------------------------------------------------------------------------
# PagedKVCachePool allocator


def test_paged_pool_page_accounting_and_lifo_reuse():
    pool = PagedKVCachePool(_model(), num_slots=3, max_len=32, page_size=8,
                            num_pages=9)          # 8 usable, page 0 junk
    assert pool.max_pages == 4 and pool.free_pages == 8
    model, params = _model(), None
    params = init_params(model.param_table(), jax.random.PRNGKey(0))
    s0 = pool.alloc()
    pool.insert(s0, _prefill_cache(model, params, 12))   # 2 pages
    assert pool.free_pages == 6
    first_pages = list(pool.page_table[s0, :2])
    assert 0 not in first_pages                   # junk page never issued
    s1 = pool.alloc()
    pool.insert(s1, _prefill_cache(model, params, 5))    # 1 page
    assert pool.free_pages == 5
    pool.free(s0)
    assert pool.free_pages == 7
    assert list(pool.page_table[s0]) == [0, 0, 0, 0]     # row zeroed
    # freed pages are the next reissued (deterministic LIFO)
    s2 = pool.alloc()
    pool.insert(s2, _prefill_cache(model, params, 16))   # 2 pages
    assert set(pool.page_table[s2, :2]) == set(first_pages)


def test_paged_pool_grows_on_demand_and_starves():
    pool = PagedKVCachePool(_model(), num_slots=2, max_len=32, page_size=8,
                            num_pages=4)          # 3 usable pages
    params = init_params(_model().param_table(), jax.random.PRNGKey(0))
    s0 = pool.alloc()
    pool.insert(s0, _prefill_cache(_model(), params, 8))  # fills page exactly
    assert pool.free_pages == 2
    # next token crosses a page boundary -> on-demand growth
    assert pool.prepare_decode([s0]) == []
    assert pool._pages_held[s0] == 2 and pool.free_pages == 1
    # mid-page: no growth
    pool.lengths[s0] = 9
    assert pool.prepare_decode([s0]) == []
    assert pool.free_pages == 1
    # drain the pool -> the next boundary crossing starves
    pool.lengths[s0] = 16
    assert pool.prepare_decode([s0]) == []
    pool.lengths[s0] = 24
    assert pool.prepare_decode([s0]) == [s0]


def test_paged_pool_exhaustion_and_errors():
    model = _model()
    params = init_params(model.param_table(), jax.random.PRNGKey(0))
    pool = PagedKVCachePool(model, num_slots=2, max_len=32, page_size=8,
                            num_pages=3)          # 2 usable pages
    s0, s1 = pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted, match="slots"):
        pool.alloc()
    pool.insert(s0, _prefill_cache(model, params, 16))   # takes both pages
    with pytest.raises(PoolExhausted, match="pages"):
        pool.insert(s1, _prefill_cache(model, params, 8))
    with pytest.raises(ValueError, match="max_len"):
        pool.insert(s1, _prefill_cache(model, params, 33))
    # free-mask error paths: same errors as the contiguous pool, O(1) now
    pool.free(s0)
    with pytest.raises(ValueError, match="already free"):
        pool.free(s0)
    with pytest.raises(ValueError, match="out of range"):
        pool.free(99)


def test_contiguous_pool_free_mask_same_errors():
    pool = KVCachePool(_model(), num_slots=2, max_len=8)
    s = pool.alloc()
    pool.free(s)
    with pytest.raises(ValueError, match="already free"):
        pool.free(s)
    with pytest.raises(ValueError, match="out of range"):
        pool.free(99)
    assert pool.alloc() == s            # LIFO reissue preserved


def test_paged_insert_scatters_through_page_table():
    model = _model()
    params = init_params(model.param_table(), jax.random.PRNGKey(0))
    pool = PagedKVCachePool(model, num_slots=2, max_len=32, page_size=8)
    s0 = pool.alloc()
    pool.insert(s0, _prefill_cache(model, params, 12))
    k = np.asarray(pool.cache["k"], np.float32)
    p0, p1 = pool.page_table[s0, 0], pool.page_table[s0, 1]
    assert np.abs(k[:, p0]).sum() > 0                  # page fully written
    assert np.abs(k[:, p1, :4]).sum() > 0              # second page half
    assert np.abs(k[:, p1, 4:]).sum() == 0
    assert np.abs(k[:, 0]).sum() == 0                  # junk page untouched
    unallocated = [p for p in range(pool.num_pages) if p not in (0, p0, p1)]
    assert np.abs(k[:, unallocated]).sum() == 0


# ---------------------------------------------------------------------------
# Engine equivalence: paged == contiguous, token-identical


def test_paged_matches_contiguous_on_mixed_length_trace():
    ec = engine_for("contiguous")
    ep = engine_for("paged", page_size=16)
    reqs = zipf_trace(12, ec.cfg.vocab_size, max_prompt=24, max_new=32,
                      seed=3)
    a = ec.run(reqs, policy="continuous")
    b = ep.run(reqs, policy="continuous")
    assert _tokens(a) == _tokens(b)
    assert a.generated_tokens == b.generated_tokens
    # and under gang scheduling too
    sa = ec.run(reqs, policy="static")
    sb = ep.run(reqs, policy="static")
    assert _tokens(sa) == _tokens(sb) == _tokens(a)


def test_paged_matches_contiguous_moe_family():
    """The page table rides the MoE backbone's scan (aux-loss carry) too."""
    ec = ServeEngine(arch="granite-moe-3b-a800m-smoke", num_slots=3,
                     max_len=48, seed=0, log=lambda *a, **k: None)
    ep = ServeEngine(arch="granite-moe-3b-a800m-smoke", num_slots=3,
                     max_len=48, seed=0, kv_layout="paged", page_size=8,
                     log=lambda *a, **k: None)
    reqs = zipf_trace(6, ec.cfg.vocab_size, max_prompt=16, max_new=10,
                      seed=1)
    assert _tokens(ec.run(reqs)) == _tokens(ep.run(reqs))


@settings(max_examples=5, deadline=None)
@given(page_size=st.sampled_from([8, 16, 32]),
       trace_seed=st.integers(min_value=0, max_value=30))
def test_paged_equivalence_sweep(page_size, trace_seed):
    """Hypothesis sweep: for any page size and mixed-length trace, the two
    memory layouts decode token-identical streams."""
    ec = engine_for("contiguous")
    ep = engine_for("paged", page_size=page_size)
    reqs = zipf_trace(6, ec.cfg.vocab_size, max_prompt=16, max_new=12,
                      seed=trace_seed)
    assert _tokens(ec.run(reqs)) == _tokens(ep.run(reqs))


# ---------------------------------------------------------------------------
# Preemption / starvation


def test_preemption_and_resume_deterministic_and_equivalent():
    """Scarce pages force mid-decode preemptions; resumed requests must
    re-generate exactly the stream an uninterrupted run produces."""
    ec = engine_for("contiguous")
    scarce = engine_for("paged", page_size=8, num_pages=13)  # 96 KV tokens
    reqs = zipf_trace(12, ec.cfg.vocab_size, max_prompt=24, max_new=32,
                      seed=3)
    ref = ec.run(reqs, policy="continuous")
    a = scarce.run(reqs, policy="continuous")
    assert a.preemptions > 0
    assert _tokens(a) == _tokens(ref)
    b = scarce.run(reqs, policy="continuous")
    assert b.preemptions == a.preemptions and b.decode_steps == a.decode_steps
    assert _tokens(b) == _tokens(a)
    assert [r.preemptions for r in a.results] == \
        [r.preemptions for r in b.results]


def test_pool_exhausted_on_page_starvation_mid_decode():
    """A page pool smaller than one request's full length cannot make
    progress: preempt-and-resume would livelock, so the scheduler raises.
    Without an eos the worst case is certain and rejected before any work
    (completed results are never thrown away); with an eos the request is
    admitted optimistically and starves mid-decode."""
    tiny = engine_for("paged", page_size=8, num_pages=3, slots=2)
    reqs = zipf_trace(2, tiny.cfg.vocab_size, max_prompt=24, max_new=40,
                      seed=7)
    with pytest.raises(PoolExhausted):
        tiny.run(reqs)

    hopeful = ServeEngine(arch=ARCH, num_slots=2, max_len=64, seed=0,
                          kv_layout="paged", page_size=8, num_pages=3,
                          eos_id=-1, log=lambda *a, **k: None)
    req = Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32),
                  max_new_tokens=40)
    with pytest.raises(PoolExhausted, match="mid-decode"):
        hopeful.run([req])


def test_oversized_request_rejected_before_any_work_is_discarded():
    """A trace mixing servable requests with one that can never fit must
    fail fast — not after the servable ones already ran."""
    tiny = engine_for("paged", page_size=8, num_pages=3, slots=2)
    good = zipf_trace(3, tiny.cfg.vocab_size, max_prompt=8, max_new=4,
                      seed=0)                     # <= 11 resident tokens
    bad = [Request(rid=9, prompt=np.ones((16,), np.int32),
                   max_new_tokens=40)]            # 55 resident > 16 capacity
    with pytest.raises(PoolExhausted, match="never"):
        tiny.run(good + bad)


def test_top_k_beyond_sampler_cap_rejected():
    from repro.serving.sampling import K_CAP
    ec = engine_for("contiguous")
    bad = zipf_trace(1, ec.cfg.vocab_size, max_prompt=8, max_new=4, seed=0,
                     temperature=1.0, top_k=K_CAP + 1)
    with pytest.raises(ValueError, match="top_k"):
        ec.run(bad)


# ---------------------------------------------------------------------------
# Per-request sampling


def test_sampling_deterministic_and_layout_agnostic():
    ec = engine_for("contiguous")
    ep = engine_for("paged", page_size=16)
    reqs = zipf_trace(6, ec.cfg.vocab_size, max_prompt=16, max_new=12,
                      seed=5, temperature=0.8, top_k=8)
    s1 = ep.run(reqs)
    s2 = ep.run(reqs)
    assert _tokens(s1) == _tokens(s2)          # deterministic replay
    sc = ec.run(reqs)
    assert _tokens(s1) == _tokens(sc)          # layout-independent draws
    for r in s1.results:
        assert all(0 <= t < ec.cfg.vocab_size for t in r.tokens)


def test_top_k_one_is_greedy_and_temperature_changes_tokens():
    ec = engine_for("contiguous")
    greedy = zipf_trace(6, ec.cfg.vocab_size, max_prompt=16, max_new=12,
                        seed=5)
    k1 = zipf_trace(6, ec.cfg.vocab_size, max_prompt=16, max_new=12,
                    seed=5, temperature=2.0, top_k=1)
    hot = zipf_trace(6, ec.cfg.vocab_size, max_prompt=16, max_new=12,
                     seed=5, temperature=1.5)
    g = ec.run(greedy)
    assert _tokens(ec.run(k1)) == _tokens(g)
    assert _tokens(ec.run(hot)) != _tokens(g)


# ---------------------------------------------------------------------------
# Fused Pallas paged-attention kernel (kv_kernel="pallas")


def test_kernel_on_token_identical_to_gather_engine_level():
    """Engine-level keystone for the fused kernel: the SAME trace decoded
    with kv_kernel="pallas" and kv_kernel="gather" yields bit-identical
    token streams under both schedulers (the kernel reproduces the gather
    path's bf16 rounding recipe, not just its math)."""
    ep = engine_for("paged", page_size=16)
    ek = engine_for("paged", page_size=16, kv_kernel="pallas")
    assert ep.kv_kernel == "gather"          # auto resolves via the plan
    assert ek.kv_kernel == "pallas"
    reqs = zipf_trace(6, ep.cfg.vocab_size, max_prompt=16, max_new=10,
                      seed=3)
    a = ep.run(reqs, policy="continuous")
    b = ek.run(reqs, policy="continuous")
    assert _tokens(a) == _tokens(b)
    assert a.decode_steps == b.decode_steps
    # gang scheduling exercises the all-slots-resident shape too
    assert _tokens(ep.run(reqs, policy="static")) == \
        _tokens(ek.run(reqs, policy="static"))


def test_kernel_survives_preemption_and_junk_rows():
    """Scarce pages force mid-decode preemptions: freed slots leave
    zeroed page-table rows (and junk-page writes) that the kernel must
    mask in-kernel.  Token streams still match the gather path exactly."""
    scarce = engine_for("paged", page_size=8, num_pages=13)
    scarce_k = engine_for("paged", page_size=8, num_pages=13,
                          kv_kernel="pallas")
    reqs = zipf_trace(8, scarce.cfg.vocab_size, max_prompt=16, max_new=16,
                      seed=3)
    a = scarce.run(reqs, policy="continuous")
    b = scarce_k.run(reqs, policy="continuous")
    assert _tokens(a) == _tokens(b)
    assert b.preemptions == a.preemptions


def test_contiguous_engine_rejects_pallas_kv_kernel():
    with pytest.raises(ValueError, match="kv_kernel"):
        ServeEngine(arch=ARCH, num_slots=2, max_len=32, seed=0,
                    kv_layout="contiguous", kv_kernel="pallas",
                    log=lambda *a, **k: None)


# ---------------------------------------------------------------------------
# Overwrite clamp: a full slot's extra write must land in junk page 0


def test_full_slot_extra_write_routes_to_junk_not_shared_page():
    """Regression for the decode write clamp: a slot already at its
    page-run capacity (idx // page_size == max_pages) used to WRAP its
    write into the slot's last page via jnp.clip — and under the
    shared-prefix cache that page may be refcounted by other live
    requests.  The ok-guard must divert the overflow to the reserved
    junk page 0, leaving every real page bitwise untouched."""
    model = _model()
    params = init_params(model.param_table(), jax.random.PRNGKey(0))
    pool = PagedKVCachePool(model, num_slots=2, max_len=32, page_size=8,
                            num_pages=9)
    s0 = pool.alloc()
    pool.insert(s0, _prefill_cache(model, params, 32))  # 4 pages: at cap
    assert pool._pages_held[s0] == pool.max_pages
    # simulate a prefix-cache share: slot 1's row references s0's last
    # page, refcounted — exactly the page the old clamp would overwrite
    s1 = pool.alloc()
    last = int(pool.page_table[s0, -1])
    pool.page_table[s1, 0] = last
    pool.page_refs[last] += 1

    from repro.training.steps import build_decode_step_slots_paged
    step = jax.jit(build_decode_step_slots_paged(model))  # non-donating
    cache = dict(pool.cache)
    before_k = np.asarray(cache["k"], np.float32).copy()
    before_v = np.asarray(cache["v"], np.float32).copy()
    tokens = jnp.ones((2, 1), jnp.int32)
    active = jnp.asarray([1, 0], jnp.int32)
    _, new_cache = step(params, cache, tokens, active,
                        jnp.asarray(pool.page_table))
    after_k = np.asarray(new_cache["k"], np.float32)
    after_v = np.asarray(new_cache["v"], np.float32)
    # every real page — the shared refcounted one included — is bitwise
    # unchanged; the overflow write landed in the junk page
    np.testing.assert_array_equal(after_k[:, 1:], before_k[:, 1:])
    np.testing.assert_array_equal(after_v[:, 1:], before_v[:, 1:])
    assert np.abs(after_k[:, 0]).sum() > 0    # the write went somewhere


# ---------------------------------------------------------------------------
# Budget: tuner sizing + admit-more acceptance


def _tight_target():
    """CPU target whose budget affords ~3 contiguous worst-case slots."""
    from repro.core.target import TARGETS, TargetSpec, register
    from repro.core.tuning import param_count_estimate

    name = "test:serve-tight"
    if name not in TARGETS:
        from repro.core.tuning import kv_bytes_per_token
        cfg = smoke_config("deepseek-7b")
        hbm = (2 * param_count_estimate(cfg) +
               3.5 * kv_bytes_per_token(cfg) * MAX_LEN) / 0.85
        register(TargetSpec(
            name=name, chip="cpu", mesh_shape=(1,), mesh_axes=("data",),
            peak_flops=5e10, hbm_bw=2e10, hbm_bytes=hbm, ici_bw=1e9,
            scheduler="local", kernels="reference"))
    return name


def test_paged_admits_more_concurrent_requests_same_budget():
    """Acceptance: same tuner HBM budget, same Zipf trace — the paged
    layout holds strictly more requests in flight than contiguous."""
    tgt = _tight_target()
    ec = engine_for("contiguous", slots=8, target=tgt)
    ep = engine_for("paged", slots=8, target=tgt)
    assert ec.num_slots < 8                      # tuner capped worst-case
    reqs = zipf_trace(16, ec.cfg.vocab_size, max_prompt=32, max_new=32,
                      seed=0)
    a = ec.run(reqs, policy="continuous")
    b = ep.run(reqs, policy="continuous")
    assert b.peak_active > a.peak_active
    assert _tokens(a) == _tokens(b)              # same tokens, more overlap
    # the paged pool spends (at most) the same order of HBM
    cont_bytes = ec.num_slots * ec.max_len
    paged_bytes = ep.num_pages * ep.page_size
    assert paged_bytes <= cont_bytes * 1.25


def test_tuner_sizes_paged_pool_and_reports_delta():
    from repro.configs.base import ShapeConfig, get_config
    from repro.core.plan import DeploymentPlan
    from repro.core.target import get_target
    from repro.core.tuning import tune

    cfg = get_config("deepseek-7b-smoke")
    plan = tune(cfg, ShapeConfig("d", 128, 8, "decode"),
                get_target("local:cpu"))
    assert plan.serve_page_size == 16
    assert plan.serve_num_pages > 1
    for key in ("kv_pages", "page_size", "serve_pool_paged",
                "serve_capacity_delta"):
        assert key in plan.napkin, key
    assert "serve kv pages" in plan.report()
    again = DeploymentPlan.from_json(plan.to_json())
    assert again.serve_page_size == plan.serve_page_size
    assert again.serve_num_pages == plan.serve_num_pages

    # a budget-bound target buys fewer pages than the worst case, and the
    # napkin quotes the paged capacity win over contiguous
    big = ShapeConfig("d", 32768, 4096, "decode")
    plan_big = tune(get_config("deepseek-7b"), big, get_target("local:cpu"))
    worst = 4096 * (32768 // 16) + 1
    assert plan_big.serve_num_pages < worst
    assert "serve_capacity_delta" in plan_big.napkin
