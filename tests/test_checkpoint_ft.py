"""Checkpointing, fault tolerance, elastic restart."""

import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime.fault_tolerance import (FailureInjector, HeartbeatMonitor,
                                           SimulatedFailure, StragglerMonitor,
                                           run_with_restarts)


def _state(seed=0):
    rng = np.random.RandomState(seed)
    return {"params": {"w": jnp.asarray(rng.randn(4, 8), jnp.bfloat16),
                       "b": jnp.asarray(rng.randn(8), jnp.float32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip_including_bf16(tmp_path):
    ck = Checkpointer(tmp_path)
    st = _state()
    ck.save(3, st)
    restored, step = ck.restore(st)
    assert step == 3
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_last_k(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s))
    steps = json.loads((tmp_path / "manifest.json").read_text())["steps"]
    assert steps == [3, 4]
    assert not (tmp_path / "step_1").exists()


def test_atomic_no_partial_visible(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _state())
    # a crashed write leaves only a .tmp dir; latest_step must ignore it
    (tmp_path / "step_9.tmp").mkdir()
    assert ck.latest_step() == 1


def test_async_writer(tmp_path):
    ck = Checkpointer(tmp_path, async_writes=True)
    for s in range(5):
        ck.save(s, _state(s))
    ck.wait()
    assert ck.latest_step() == 4


def test_recheckpoint_byte_identical(tmp_path):
    """Same state + step + injected clock => byte-identical files.

    np.savez would bake the wall clock into every zip entry's mtime;
    the deterministic writer plus the injectable ``now=`` make a
    re-checkpoint diffable: different bytes mean different state."""
    blobs = []
    for d in ("a", "b"):
        ck = Checkpointer(tmp_path / d, now=lambda: 1234.5)
        ck.save(3, _state())
        blobs.append(((tmp_path / d / "step_3" / "arrays.npz").read_bytes(),
                      (tmp_path / d / "step_3" / "meta.json").read_bytes()))
    assert blobs[0][0] == blobs[1][0]
    assert blobs[0][1] == blobs[1][1]
    # and the advisory default still stamps a real time
    meta = json.loads(blobs[0][1])
    assert meta["time"] == 1234.5


def test_restart_resumes_bitwise(tmp_path):
    """Train with an injected failure == train without, loss for loss."""
    from repro.launch.train import train_main
    ref = train_main(arch="stablelm-1.6b-smoke", steps=8, seq_len=32,
                     global_batch=2, ckpt_dir=str(tmp_path / "a"),
                     ckpt_every=2, async_ckpt=False, log=lambda *a: None)
    faulty = train_main(arch="stablelm-1.6b-smoke", steps=8, seq_len=32,
                        global_batch=2, ckpt_dir=str(tmp_path / "b"),
                        ckpt_every=2, async_ckpt=False, fail_at=(5,),
                        log=lambda *a: None)
    assert faulty["restarts"] == 1
    assert faulty["final_loss"] == pytest.approx(ref["final_loss"], abs=1e-6)


def test_run_with_restarts_gives_up():
    ck = None

    def loop(start):
        raise SimulatedFailure("always")

    class _FakeCk:
        def latest_step(self):
            return None

    with pytest.raises(RuntimeError, match="exceeded"):
        run_with_restarts(loop, checkpointer=_FakeCk(), max_restarts=2,
                          logger=lambda *_: None)


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(deadline_s=1.0)
    hb.beat("host0", now=100.0)
    hb.beat("host1", now=100.0)
    assert hb.sweep(now=100.5) == set()
    hb.beat("host0", now=101.0)
    assert hb.sweep(now=101.5) == {"host1"}
    assert hb.healthy == {"host0"}


def test_straggler_monitor_flags_outlier():
    sm = StragglerMonitor(window=8, factor=3.0, warmup=3)
    for step in range(6):
        assert not sm.observe(step, 0.1)
    assert sm.observe(6, 1.0)       # 10x median
    assert not sm.observe(7, 0.11)  # baseline not poisoned
    assert len(sm.flagged) == 1


def test_elastic_reshard_cpu():
    """Mesh-agnostic checkpoint restores onto a different (1-dev) mesh."""
    from repro.models.params import partition_specs
    from repro.runtime.elastic import rebalance_batch_size
    import pytest
    # non-dividing survivor count shrinks the global batch only on opt-in
    with pytest.raises(ValueError):
        rebalance_batch_size(256, 16, 15)
    assert rebalance_batch_size(256, 16, 15, allow_shrink=True) == (17, 255)
    assert rebalance_batch_size(256, 16, 8) == (32, 256)
