"""Property tests: chunkwise-parallel SSM forms == step-by-step recurrences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models.mamba import ssd_chunkwise, ssd_decode, ssd_recurrent_ref
from repro.models.ssm import (mlstm_chunkwise, mlstm_recurrent,
                              mlstm_zero_state)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2), h=st.integers(1, 3),
    nchunks=st.integers(1, 3), chunk=st.sampled_from([4, 8]),
    dk=st.sampled_from([4, 8]), dv=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlstm_chunkwise_matches_recurrent(b, h, nchunks, chunk, dk, dv, seed):
    s = nchunks * chunk
    rng = np.random.RandomState(seed % (2**31 - 1))
    q = jnp.asarray(rng.randn(b, h, s, dk), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, dk), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, dv), jnp.float32)
    li = jnp.asarray(rng.randn(b, h, s) * 2, jnp.float32)
    lf = jnp.asarray(-np.abs(rng.randn(b, h, s)), jnp.float32)

    state0 = mlstm_zero_state(b, h, dk, dv)
    y1, s1 = mlstm_chunkwise(q, k, v, li, lf, state0, chunk)
    state0 = mlstm_zero_state(b, h, dk, dv)
    y2, s2 = mlstm_recurrent(q, k, v, li, lf, state0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    for a, b_ in zip(s1, s2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2), h=st.integers(1, 3),
    nchunks=st.integers(1, 3), chunk=st.sampled_from([4, 8]),
    p=st.sampled_from([4, 8]), n=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ssd_chunkwise_matches_recurrent(b, h, nchunks, chunk, p, n, seed):
    s = nchunks * chunk
    rng = np.random.RandomState(seed % (2**31 - 1))
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(b, s, h)) * 0.5 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(h)) - 0.1, jnp.float32)
    B = jnp.asarray(rng.randn(b, s, n) * 0.5, jnp.float32)
    C = jnp.asarray(rng.randn(b, s, n) * 0.5, jnp.float32)
    D = jnp.asarray(rng.randn(h), jnp.float32)

    y1, s1 = ssd_chunkwise(x, dt, A, B, C, D, None, chunk)
    y2, s2 = ssd_recurrent_ref(x, dt, A, B, C, D, None)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_decode_continues_chunkwise():
    """Prefill with chunkwise then decode one step == full recurrence."""
    rng = np.random.RandomState(0)
    b, s, h, p, n = 1, 16, 2, 4, 4
    x = jnp.asarray(rng.randn(b, s + 1, h, p), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(b, s + 1, h)) * 0.3 + 0.01, jnp.float32)
    A = jnp.asarray(np.array([-0.5, -1.0]), jnp.float32)
    B = jnp.asarray(rng.randn(b, s + 1, n) * 0.5, jnp.float32)
    C = jnp.asarray(rng.randn(b, s + 1, n) * 0.5, jnp.float32)
    D = jnp.asarray(rng.randn(h), jnp.float32)

    _, state = ssd_chunkwise(x[:, :s], dt[:, :s], A, B[:, :s], C[:, :s], D,
                             None, 8)
    y_dec, _ = ssd_decode(x[:, s:], dt[:, s:], A, B[:, s:], C[:, s:], D, state)
    y_ref, _ = ssd_recurrent_ref(x, dt, A, B, C, D, None)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_ref[:, -1]), rtol=1e-4, atol=1e-4)
