"""Per-arch smoke: reduced config, one forward/train step on CPU,
output shapes + no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, smoke_config
from repro.configs.base import ShapeConfig
from repro.core.plan import DeploymentPlan
from repro.data.pipeline import DataPipeline
from repro.models.params import init_params, param_count
from repro.models.transformer import model_for
from repro.optim import AdamW
from repro.training.steps import build_train_step, init_train_state

SMALL = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")
LM_ARCHS = [a for a in list_archs() if a != "lulesh-dash"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_loss_finite(arch, rng):
    cfg = smoke_config(arch)
    model = model_for(cfg)
    params = init_params(model.param_table(), rng)
    batch = DataPipeline(model, SMALL).batch_at(0)
    loss, metrics = model.loss(params, batch, None)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, loss)
    assert param_count(model.param_table()) > 0


@pytest.mark.parametrize("arch", ["deepseek-7b", "granite-moe-3b-a800m",
                                  "xlstm-1.3b", "zamba2-7b", "whisper-tiny"])
def test_train_step_decreases_loss(arch, rng):
    cfg = smoke_config(arch)
    model = model_for(cfg)
    plan = DeploymentPlan(arch=arch, shape="smoke", target="local:cpu",
                          mesh_shape=(1,), mesh_axes=("data",),
                          microbatches=2)
    opt = AdamW(weight_decay=0.0)
    step = jax.jit(build_train_step(model, opt, plan, peak_lr=3e-3,
                                    warmup_steps=2))
    params = init_params(model.param_table(), rng)
    state = init_train_state(model, opt, params, plan)
    pipe = DataPipeline(model, SMALL)
    first = last = None
    for i in range(8):
        batch = pipe.batch_at(0)  # same batch -> loss must drop
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert jnp.isfinite(loss), (arch, i)
        first = loss if first is None else first
        last = loss
    assert last < first, (arch, first, last)


def test_lulesh_blast_wave_propagates():
    from repro.models import lulesh
    cfg = lulesh.LuleshConfig(grid=16)
    st = lulesh.init_state(cfg)
    e0_corner = float(st["e"][0, 0, 0])
    st = lulesh.run(st, cfg, 20)
    assert bool(jnp.isfinite(st["e"]).all())
    assert bool(jnp.isfinite(st["rho"]).all())
    # the blast wave propagates: zones away from the corner gain energy
    # and density is perturbed (the corner itself may transiently heat
    # under compression in this proxy scheme, so no monotonicity there)
    assert float(st["e"][1, 0, 0]) > 1e3     # wavefront reached neighbors
    assert float(jnp.abs(st["rho"] - 1.0).max()) > 1e-3
    assert float(st["t"]) > 0
