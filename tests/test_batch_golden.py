"""Golden-file tests for core/batch.py — the paper's Algorithm 1 text
generation ("create batch_file; for each deployment parse to SLURM or PBS
command") must not drift."""

import pytest

from repro.core.batch import make_batch, pbs_batch, slurm_batch
from repro.core.jobspec import (DataItem, Deployment, Execution, JobSpec)


def _rich_spec() -> JobSpec:
    """Representative spec: mail + ram + one mpi and one plain execution
    and input data (covers every conditional branch of the generators)."""
    return JobSpec(
        name="lulesh_dash",
        mail="hoeb@mnm-team.org",
        inputs=[DataItem(source="https://example.org/input.tar",
                         protocol="https")],
        deployment=Deployment(nodes=46, ram="90gb", cores_per_task=1,
                              tasks_per_node=48, clocktime="06:00:00"),
        executions=[
            Execution("serial", "echo preparing"),
            Execution("mpi", "ch-run -b ./data:/data lulesh.dash -- "
                             "/built/lulesh.dash -i 1000 -s 13", 2197),
        ])


GOLDEN_SLURM = """\
#!/bin/bash
#SBATCH --job-name=lulesh_dash
#SBATCH --nodes=46
#SBATCH --ntasks-per-node=48
#SBATCH --cpus-per-task=1
#SBATCH --time=06:00:00
#SBATCH --mem=90gb
#SBATCH --mail-user=hoeb@mnm-team.org
#SBATCH --mail-type=END,FAIL

cd $EASEY_WORKDIR
mkdir -p data
echo preparing
srun --ntasks=2197 ch-run -b ./data:/data lulesh.dash -- /built/lulesh.dash -i 1000 -s 13
"""

GOLDEN_PBS = """\
#!/bin/bash
#PBS -N lulesh_dash
#PBS -l nodes=46:ppn=48
#PBS -l walltime=06:00:00
#PBS -l mem=90gb
#PBS -M hoeb@mnm-team.org
#PBS -m ae

cd $EASEY_WORKDIR
mkdir -p data
echo preparing
mpirun -np 2197 ch-run -b ./data:/data lulesh.dash -- /built/lulesh.dash -i 1000 -s 13
"""

GOLDEN_SLURM_PLAIN = """\
#!/bin/bash
#SBATCH --job-name=tiny
#SBATCH --nodes=1
#SBATCH --ntasks-per-node=1
#SBATCH --cpus-per-task=1
#SBATCH --time=01:00:00

cd /scratch/tiny
./a.out
"""


def test_slurm_golden():
    assert slurm_batch(_rich_spec()) == GOLDEN_SLURM


def test_pbs_golden():
    assert pbs_batch(_rich_spec()) == GOLDEN_PBS


def test_slurm_plain_golden_custom_workdir():
    """No mail/ram/data/mpi -> every optional line is absent."""
    spec = JobSpec(name="tiny",
                   executions=[Execution("serial", "./a.out")])
    assert slurm_batch(spec, workdir="/scratch/tiny") == GOLDEN_SLURM_PLAIN


def test_make_batch_dispatch_and_local():
    spec = _rich_spec()
    assert make_batch(spec, "slurm") == GOLDEN_SLURM
    assert make_batch(spec, "pbs") == GOLDEN_PBS
    local = make_batch(spec, "local")
    assert local.startswith("#!/bin/bash\n")
    assert "srun" not in local and "echo preparing" in local


def test_unsupported_scheduler_matches_paper_wording():
    with pytest.raises(ValueError, match="not supported"):
        make_batch(_rich_spec(), "lsf")
