"""Continuous-batching serving engine: KV pool slot lifecycle, scheduler
determinism, and the static/continuous equivalence + efficiency contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.params import init_params
from repro.models.transformer import model_for
from repro.serving import (KVCachePool, PoolExhausted, Request, ServeEngine,
                           uniform_trace, zipf_trace)
from repro.training.steps import build_decode_step, build_prefill_step

ARCH = "deepseek-7b-smoke"


@pytest.fixture(scope="module")
def engine():
    return ServeEngine(arch=ARCH, num_slots=4, max_len=64, seed=0,
                       log=lambda *a, **k: None)


# ---------------------------------------------------------------------------
# KVCachePool


def _model():
    return model_for(smoke_config("deepseek-7b"), remat="none")


def test_pool_alloc_exhaustion_and_reuse():
    pool = KVCachePool(_model(), num_slots=3, max_len=16)
    s0, s1, s2 = pool.alloc(), pool.alloc(), pool.alloc()
    assert sorted((s0, s1, s2)) == [0, 1, 2]
    assert pool.num_free == 0
    with pytest.raises(PoolExhausted):
        pool.alloc()
    pool.free(s1)
    assert pool.num_free == 1
    assert pool.alloc() == s1          # freed slots are reissued
    with pytest.raises(PoolExhausted):
        pool.alloc()


def test_pool_double_free_and_range_rejected():
    pool = KVCachePool(_model(), num_slots=2, max_len=8)
    s = pool.alloc()
    pool.free(s)
    with pytest.raises(ValueError):
        pool.free(s)
    with pytest.raises(ValueError):
        pool.free(99)


def test_pool_rejects_unservable_family():
    with pytest.raises(NotImplementedError):
        KVCachePool(model_for(smoke_config("xlstm-1.3b")), 2, 8)


def test_pool_insert_sets_slot_length():
    model = _model()
    params = init_params(model.param_table(), jax.random.PRNGKey(0))
    pool = KVCachePool(model, num_slots=3, max_len=32)
    toks = jnp.ones((1, 5), jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks}, None)
    slot = pool.alloc()
    pool.insert(slot, cache)
    assert pool.lengths[slot] == 5
    assert int(np.asarray(pool.cache["index"])[slot]) == 5
    # inserted keys land at positions [0, 5) of that slot only
    k = np.asarray(pool.cache["k"], np.float32)
    assert np.abs(k[:, slot, :5]).sum() > 0
    assert np.abs(k[:, slot, 5:]).sum() == 0
    other = [i for i in range(3) if i != slot]
    assert np.abs(k[:, other]).sum() == 0
    with pytest.raises(ValueError, match="max_len"):
        big = {kk: jnp.zeros((model.cfg.num_layers, 1, 33,
                              model.cfg.num_kv_heads, model.cfg.head_dim))
               for kk in ("k", "v")}
        pool.insert(slot, big)


# ---------------------------------------------------------------------------
# Scheduler / engine behaviour


def test_scheduler_deterministic_under_seeded_trace(engine):
    reqs = zipf_trace(12, engine.cfg.vocab_size, max_prompt=24, max_new=16,
                      seed=3)
    a = engine.run(reqs, policy="continuous")
    b = engine.run(zipf_trace(12, engine.cfg.vocab_size, max_prompt=24,
                              max_new=16, seed=3), policy="continuous")
    assert a.decode_steps == b.decode_steps
    assert a.generated_tokens == b.generated_tokens
    for ra, rb in zip(a.results, b.results):
        assert ra.rid == rb.rid and ra.slot == rb.slot
        assert ra.tokens == rb.tokens


def test_results_cover_all_requests_and_respect_budget(engine):
    reqs = zipf_trace(10, engine.cfg.vocab_size, max_prompt=32, max_new=8,
                      seed=5)
    stats = engine.run(reqs, policy="continuous")
    assert [r.rid for r in stats.results] == list(range(10))
    for req, res in zip(reqs, stats.results):
        assert 1 <= len(res.tokens) <= req.max_new_tokens
        assert res.prompt_len + len(res.tokens) - 1 <= engine.max_len
        assert res.t_done >= res.t_first >= res.t_submit


def test_prompt_longer_than_pool_rejected(engine):
    bad = [Request(rid=0, prompt=np.ones((engine.max_len + 1,), np.int32),
                   max_new_tokens=4)]
    with pytest.raises(ValueError, match="does not fit"):
        engine.run(bad)


def test_continuous_matches_static_path_for_uniform_requests(engine):
    """The keystone equivalence: slot-wise continuous decode must produce
    token-identical output to the original scalar-index static path."""
    n, plen, nnew = 4, 16, 8
    reqs = uniform_trace(n, engine.cfg.vocab_size, prompt_len=plen,
                         max_new=nnew, seed=11)
    cont = engine.run(reqs, policy="continuous")

    # reference: batched prefill + scalar-index decode (launch/serve.py's
    # pre-engine behaviour), cache padded to the pool's max_len
    model, params = engine.model, engine.params
    prefill = jax.jit(build_prefill_step(model))
    decode = jax.jit(build_decode_step(model), donate_argnums=(1,))
    toks = jnp.asarray(np.stack([r.prompt for r in reqs]))
    logits, cache = prefill(params, {"tokens": toks})
    pad = engine.max_len - cache["k"].shape[2]
    for key in ("k", "v"):
        cache[key] = jnp.pad(cache[key],
                             [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
    t = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    ref = [np.asarray(t)]
    for _ in range(nnew - 1):
        logits, cache = decode(params, cache, t)
        t = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        ref.append(np.asarray(t))
    ref_tokens = np.concatenate(ref, axis=1)

    got = np.stack([r.tokens for r in cont.results])
    np.testing.assert_array_equal(got, ref_tokens)


def test_static_policy_same_tokens_fewer_shared_steps(engine):
    """Same trace, both policies: identical per-request tokens (slot content
    is row-independent), and continuous needs strictly fewer decode steps
    on a heavy-tailed trace (deterministic — no wall-clock flakiness)."""
    reqs = zipf_trace(24, engine.cfg.vocab_size, max_prompt=8, max_new=48,
                      seed=3)
    static = engine.run(reqs, policy="static")
    cont = engine.run(reqs, policy="continuous")
    for rs, rc in zip(static.results, cont.results):
        assert rs.tokens == rc.tokens
    assert cont.decode_steps < static.decode_steps
    assert static.decode_steps >= 1.5 * cont.decode_steps
    assert cont.occupancy > static.occupancy


def test_tuner_serve_branch_sizes_pool():
    from repro.configs.base import ShapeConfig, get_config
    from repro.core.target import get_target
    from repro.core.tuning import tune

    cfg = get_config("deepseek-7b-smoke")
    shape = ShapeConfig("d", 128, 8, "decode")
    plan = tune(cfg, shape, get_target("local:cpu"))
    assert plan.serve_slots == 8 and plan.serve_max_len == 128
    assert "serve_pool" in plan.napkin
    # plan roundtrips with the new fields
    from repro.core.plan import DeploymentPlan
    again = DeploymentPlan.from_json(plan.to_json())
    assert again.serve_slots == 8 and again.serve_max_len == 128

    # a giant request against the full model must be HBM-capped
    big = ShapeConfig("d", 32768, 4096, "decode")
    plan_big = tune(get_config("deepseek-7b"), big, get_target("local:cpu"))
    assert plan_big.serve_slots < 4096
    assert any("capped" in n for n in plan_big.notes)


def test_engine_rejects_cacheless_family():
    with pytest.raises(NotImplementedError):
        ServeEngine(arch="xlstm-1.3b-smoke", num_slots=2, max_len=16,
                    log=lambda *a, **k: None)
