"""Roundtrip tests for the two portable spec parsers:
appspec.parse_appfile (the Dockerfile analogue) and jobspec.parse_jobspec
(the paper's four-part JSON job configuration)."""

import json

import pytest

from repro.core.appspec import AppSpec, KNOWN_DIRECTIVES, parse_appfile
from repro.core.jobspec import lulesh_example, parse_jobspec


# ---------------------------------------------------------------------------
# Appfile


def test_appfile_minimal_roundtrip():
    text = "FROM arch:deepseek-7b\nSHAPE train_4k\nRUN train --steps 5\n"
    spec = parse_appfile(text)
    assert spec.arch == "deepseek-7b"
    assert spec.shape == "train_4k"
    assert spec.run == "train --steps 5"
    assert spec.directives == ()
    again = parse_appfile(spec.to_appfile())
    assert again == AppSpec(arch="deepseek-7b", shape="train_4k",
                            run="train --steps 5", directives=())


def test_appfile_fully_populated_roundtrip():
    spec = AppSpec(arch="mistral-large-123b", shape="decode_32k",
                   run="serve --decode 32",
                   directives=KNOWN_DIRECTIVES[:3],
                   overrides={"num_layers": 4, "notes": "smoke"})
    again = parse_appfile(spec.to_appfile())
    assert again.arch == spec.arch and again.shape == spec.shape
    assert again.run == spec.run
    assert again.directives == spec.directives
    assert again.overrides == {"num_layers": 4, "notes": "smoke"}
    # a stable spec hashes stably (the package manifest key)
    assert again.content_hash() == \
        parse_appfile(spec.to_appfile()).content_hash()


@pytest.mark.parametrize("text,match", [
    ("FROM arch:x\nSHAPE train_4k\n###inject_rootkit###\n", "unknown directive"),
    ("FROM image:x\nSHAPE train_4k\n", "FROM must reference"),
    ("FROM arch:x\nSHAPE no_such_shape\n", "unknown shape"),
    ("FROM arch:x\nSHAPE train_4k\nDANCE badly\n", "unparseable"),
    ("SHAPE train_4k\n", "must contain FROM"),
])
def test_appfile_invalid_inputs(text, match):
    with pytest.raises(ValueError, match=match):
        parse_appfile(text)


# ---------------------------------------------------------------------------
# Job JSON


def test_jobspec_minimal():
    spec = parse_jobspec({"job": {"name": "j1"}})
    assert spec.name == "j1"
    assert spec.deployment.nodes == 1
    assert spec.executions == [] and not spec.has_data
    assert spec.mount == "/data"


def test_jobspec_fully_populated_roundtrip():
    d = {
        "job": {"name": "full", "id": "abc123", "mail": "x@y.z"},
        "data": {
            "input": [{"source": "https://h/in.dat", "protocol": "https",
                       "user": "u", "auth": "password"}],
            "output": [{"destination": "scp://h/out", "protocol": "scp"}],
            "mount": {"container-path": "/mnt/io"},
        },
        "deployment": {"nodes": 4, "ram": "8gb", "cores-per-task": 2,
                       "tasks-per-node": 24, "clocktime": "00:30:00"},
        "execution": [{"serial": {"command": "echo hi"}},
                      {"mpi": {"command": "./solver", "mpi-tasks": 96}}],
        "easey": {"arch": "deepseek-7b", "shape": "train_4k"},
    }
    a = parse_jobspec(d)
    b = parse_jobspec(json.dumps(d))   # dict and JSON text parse identically
    assert a == b
    assert a.job_id == "abc123" and a.mail == "x@y.z"
    assert a.mount == "/mnt/io" and a.has_data
    assert a.inputs[0].protocol == "https" and a.inputs[0].auth == "password"
    assert a.outputs[0].destination == "scp://h/out"
    assert a.deployment.tasks_per_node == 24
    assert [e.kind for e in a.executions] == ["serial", "mpi"]
    assert a.executions[1].mpi_tasks == 96
    assert a.easey == {"arch": "deepseek-7b", "shape": "train_4k"}


def test_jobspec_paper_listing_parses():
    spec = parse_jobspec(lulesh_example())
    assert spec.deployment.nodes == 46
    assert spec.executions[0].mpi_tasks == 2197
    sid = spec.ensure_id()
    assert sid and spec.ensure_id() == sid     # id is sticky once assigned


def test_jobspec_invalid_fields():
    with pytest.raises(ValueError, match="missing required 'job'"):
        parse_jobspec({"deployment": {}})
    with pytest.raises(ValueError, match="unsupported protocol"):
        parse_jobspec({"job": {"name": "x"},
                       "data": {"input": [{"source": "s",
                                           "protocol": "carrier-pigeon"}]}})
    with pytest.raises(NotImplementedError, match="gridftp"):
        parse_jobspec({"job": {"name": "x"},
                       "data": {"input": [{"source": "s",
                                           "protocol": "gridftp"}]}})
    with pytest.raises(ValueError, match="serial|mpi"):
        parse_jobspec({"job": {"name": "x"},
                       "execution": [{"quantum": {"command": "q"}}]})
