"""HLO cost-model unit tests: trip counts, dot flops, collective wire bytes
(fixture-text based), plus an end-to-end check against cost_analysis on an
unscanned program where XLA's own numbers are trustworthy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import (analyze, parse_hlo, type_bytes,
                               xla_cost_analysis)


FIXTURE = """\
HloModule test

%add (a: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  ROOT %r = f32[] add(%a, %a)
}

%body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16] get-tuple-element(%arg), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (arg: (s32[], f32[8,16])) -> pred[] {
  %arg = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %p0)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_type_bytes():
    assert type_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert type_bytes("bf16[2,3]") == 12
    assert type_bytes("(f32[4], s32[2])") == 24
    assert type_bytes("pred[]") == 1


def test_fixture_trip_count_and_flops():
    cost = analyze(FIXTURE, total_devices=256)
    # one dot (2*8*16*16 flops) executed 24 times
    assert cost.flops == pytest.approx(2 * 8 * 16 * 16 * 24)
    assert 24 in cost.while_trips.values()


def test_fixture_collective_wire_bytes():
    cost = analyze(FIXTURE, total_devices=256)
    payload = 8 * 16 * 4
    g = 16  # replica_groups=[16,16] -> group size 16
    want = 2 * (g - 1) / g * payload * 24
    assert cost.wire_bytes == pytest.approx(want)
    assert cost.collective_breakdown["all-reduce"]["count"] == 24


def test_matches_cost_analysis_unscanned():
    """On a scan-free program our dot flops == XLA's cost_analysis."""
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w1 = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w2 = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    compiled = jax.jit(f).lower(x, w1, w2).compile()
    ours = analyze(compiled.as_text()).flops
    theirs = xla_cost_analysis(compiled)["flops"]
    analytic = 2 * 64 * 128 * 256 + 2 * 64 * 256 * 32
    assert ours == pytest.approx(analytic, rel=0.01)
    assert ours == pytest.approx(theirs, rel=0.1)


def test_scan_correction_vs_unrolled():
    """Scanned program: our model must match the UNROLLED count."""
    L, D = 6, 32

    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    def unrolled(x, ws):
        for i in range(L):
            x, _ = body(x, ws[i])
        return x.sum()

    x = jax.ShapeDtypeStruct((16, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    ours_scan = analyze(jax.jit(scanned).lower(x, ws).compile().as_text()).flops
    xla_unrolled = xla_cost_analysis(
        jax.jit(unrolled).lower(x, ws).compile())["flops"]
    assert ours_scan == pytest.approx(xla_unrolled, rel=0.05)


def test_roofline_terms():
    from repro.analysis.roofline import from_cost
    cost = analyze(FIXTURE, total_devices=256)
    r = from_cost(cost, arch="a", shape="s", mesh="single", chips=256,
                  model_flops=cost.flops * 256)
    assert r.bottleneck in ("compute", "memory", "collective")
    assert r.t_compute > 0 and r.t_memory > 0 and r.t_collective > 0
    assert 0 < r.roofline_fraction <= 1.0
    assert r.useful_ratio == pytest.approx(1.0)
