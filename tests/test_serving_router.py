"""Multi-replica serving router: policy determinism (round-robin order,
least-loaded free-token choice, prefix-affinity stability under replica
count), overflow re-routing on page starvation, the N=1 == bare-engine
equivalence, the preempt-tie-break-by-rid regression, the fleet
conservation property (every admitted request completes exactly once on
exactly one replica), and the >= 2.5x in-flight acceptance criterion."""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.configs import smoke_config
from repro.serving import (PoolExhausted, ReplicaRouter, Request, ServeEngine,
                           prefix_replica, uniform_trace, zipf_trace)

ARCH = "deepseek-7b-smoke"
SLOTS, MAX_LEN = 4, 64

_ENGINES: dict = {}


def engine_for(layout="contiguous", page_size=0, num_pages=0, slots=SLOTS,
               max_len=MAX_LEN, target="local:cpu", eos_id=None):
    """Engines are expensive (jit); share them across tests by config."""
    key = (layout, page_size, num_pages, slots, max_len, target, eos_id)
    if key not in _ENGINES:
        _ENGINES[key] = ServeEngine(
            arch=ARCH, target=target, num_slots=slots, max_len=max_len,
            seed=0, kv_layout=layout, page_size=page_size,
            num_pages=num_pages, eos_id=eos_id, log=lambda *a, **k: None)
    return _ENGINES[key]


def router_for(engines, policy):
    return ReplicaRouter(engines, policy=policy, log=lambda *a, **k: None)


def _tokens(stats):
    return [r.tokens for r in sorted(stats.results, key=lambda r: r.rid)]


def _tight_target():
    """CPU target whose budget affords ~3 contiguous worst-case slots."""
    from repro.core.target import TARGETS, TargetSpec, register
    from repro.core.tuning import kv_bytes_per_token, param_count_estimate

    name = "test:router-tight"
    if name not in TARGETS:
        cfg = smoke_config("deepseek-7b")
        hbm = (2 * param_count_estimate(cfg) +
               3.5 * kv_bytes_per_token(cfg) * MAX_LEN) / 0.85
        register(TargetSpec(
            name=name, chip="cpu", mesh_shape=(1,), mesh_axes=("data",),
            peak_flops=5e10, hbm_bw=2e10, hbm_bytes=hbm, ici_bw=1e9,
            scheduler="local", kernels="reference"))
    return name


# ---------------------------------------------------------------------------
# Construction / validation


def test_router_validates_fleet_and_policy():
    e = engine_for()
    with pytest.raises(ValueError, match="at least one"):
        ReplicaRouter([], log=lambda *a, **k: None)
    with pytest.raises(ValueError, match="policy"):
        ReplicaRouter([e], policy="fastest", log=lambda *a, **k: None)
    moe = ServeEngine(arch="granite-moe-3b-a800m-smoke", num_slots=2,
                      max_len=32, seed=0, log=lambda *a, **k: None)
    with pytest.raises(ValueError, match="one architecture"):
        ReplicaRouter([e, moe], log=lambda *a, **k: None)
    # mixed max_len / eos_id would make output depend on the policy's
    # pick (budget clamp and stop condition differ per replica) — rejected
    short = ServeEngine(arch=ARCH, num_slots=2, max_len=32, seed=0,
                        log=lambda *a, **k: None)
    with pytest.raises(ValueError, match="max_len"):
        ReplicaRouter([e, short], log=lambda *a, **k: None)
    with pytest.raises(ValueError, match="eos_id"):
        ReplicaRouter([e, engine_for(slots=2, eos_id=-1)],
                      log=lambda *a, **k: None)


def test_router_rejects_request_no_replica_can_ever_serve():
    e = engine_for()
    router = router_for([e, e], "round_robin")
    too_long = [Request(rid=0, prompt=np.ones((MAX_LEN + 1,), np.int32),
                        max_new_tokens=4)]
    with pytest.raises(ValueError, match="any replica"):
        router.run(too_long)
    scarce = engine_for("paged", page_size=8, num_pages=3, slots=2)
    tiny_fleet = router_for([scarce, scarce], "least_loaded")
    fat = [Request(rid=0, prompt=np.ones((16,), np.int32),
                   max_new_tokens=40)]         # 55 resident > 16 capacity
    with pytest.raises(PoolExhausted, match="no replica"):
        tiny_fleet.run(fat)


# ---------------------------------------------------------------------------
# Policies


def test_round_robin_deterministic_assignment():
    e = engine_for()
    router = router_for([e, e, e], "round_robin")
    reqs = uniform_trace(6, e.cfg.vocab_size, prompt_len=8, max_new=4,
                         seed=2)
    a = router.run(reqs)
    # ring order: rid i lands on replica i mod 3 (ample capacity, nothing
    # skipped), and a replay reproduces the same assignment and tokens
    assert a.replica_of == {i: i % 3 for i in range(6)}
    b = router.run(uniform_trace(6, e.cfg.vocab_size, prompt_len=8,
                                 max_new=4, seed=2))
    assert b.replica_of == a.replica_of
    assert _tokens(a) == _tokens(b)


def test_round_robin_skips_full_replicas():
    small = engine_for(slots=1)               # one slot per replica
    router = router_for([small, small], "round_robin")
    reqs = uniform_trace(4, small.cfg.vocab_size, prompt_len=8, max_new=12,
                         seed=2)
    a = router.run(reqs)
    # both replicas fill immediately; later rids wait for free slots but
    # every request still completes exactly once
    assert sorted(a.replica_of) == [0, 1, 2, 3]
    assert a.peak_in_flight == 2


def test_least_loaded_picks_replica_with_most_free_tokens():
    one = engine_for(slots=1)                  # 1 x 64 free tokens
    four = engine_for(slots=4)                 # 4 x 64 free tokens
    router = router_for([one, one, four], "least_loaded")
    reqs = uniform_trace(3, one.cfg.vocab_size, prompt_len=8, max_new=4,
                         seed=0)
    a = router.run(reqs)
    # first pick is the roomiest replica (2); once it holds one request
    # (3 x 64 free) it still beats the single-slot replicas (1 x 64), so
    # everything lands there while slots remain
    assert a.replica_of[0] == 2
    assert all(idx == 2 for idx in a.replica_of.values())


def test_least_loaded_balances_paged_fleet_by_free_pages():
    # two paged replicas with different page pools: the bigger pool wins
    big = engine_for("paged", page_size=8, num_pages=13)   # 96 KV tokens
    small = engine_for("paged", page_size=8, num_pages=5)  # 32 KV tokens
    router = router_for([small, big], "least_loaded")
    reqs = uniform_trace(1, big.cfg.vocab_size, prompt_len=8, max_new=4,
                         seed=1)
    a = router.run(reqs)
    assert a.replica_of[0] == 1


def test_prefix_affinity_stable_under_replica_count():
    rng = np.random.RandomState(7)
    prompts = [rng.randint(1, 1000, size=(rng.randint(1, 24),))
               for _ in range(40)]
    for p in prompts:
        # deterministic: a property of (prefix, fleet size) only
        assert prefix_replica(p, 3) == prefix_replica(p, 3)
        # rendezvous hashing: growing the fleet N -> N+1 only ever moves
        # a prefix to the NEW replica, never between the survivors
        r3, r4 = prefix_replica(p, 3), prefix_replica(p, 4)
        assert r4 == r3 or r4 == 3
    # prefix-keyed: a shared prefix maps together whatever the suffix is
    base = np.arange(1, 9, dtype=np.int32)
    a = np.concatenate([base, np.full((6,), 101, np.int32)])
    b = np.concatenate([base, np.full((12,), 907, np.int32)])
    assert prefix_replica(a, 5) == prefix_replica(b, 5)


def test_prefix_affinity_routes_shared_prefixes_together():
    e = engine_for()
    router = ReplicaRouter([e, e, e], policy="prefix_affinity",
                           log=lambda *a, **k: None)
    base = np.arange(1, 9, dtype=np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [base, np.full((i + 1,), 50 + i, np.int32)]),
                    max_new_tokens=4)
            for i in range(4)]
    a = router.run(reqs)
    want = prefix_replica(base, 3, prefix_len=router.prefix_len)
    assert all(idx == want for idx in a.replica_of.values())


# ---------------------------------------------------------------------------
# Overflow / re-route


def test_starved_request_reroutes_instead_of_rejecting():
    """A request that solo-starves a scarce paged replica is evicted and
    completes on a roomier replica — with exactly the token stream an
    uninterrupted run on the roomy replica produces."""
    scarce = engine_for("paged", page_size=8, num_pages=3, slots=2,
                        eos_id=-1)            # 16 KV tokens, optimistic
    roomy = engine_for(slots=2, eos_id=-1)    # fleet-uniform eos
    router = router_for([scarce, roomy], "round_robin")
    req = Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32),
                  max_new_tokens=40)
    a = router.run([req])
    assert a.reroutes >= 1
    assert a.replica_of == {0: 1}
    ref = roomy.run([Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32),
                             max_new_tokens=40)])
    assert _tokens(a) == _tokens(ref)
    # the single-engine path still hard-rejects the same request
    with pytest.raises(PoolExhausted, match="mid-decode"):
        scarce.run([Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32),
                            max_new_tokens=40)])


def test_starved_request_with_no_roomier_replica_fails_fast():
    """When every replica's pool is too small for the evicted request's
    remaining generation, the router raises like the bare engine would —
    it must not grind one token per re-prefill bounce on the replica that
    just proved it cannot finish the request (optimistic eos bound)."""
    scarce = engine_for("paged", page_size=8, num_pages=3, slots=2,
                        eos_id=-1)
    router = router_for([scarce], "least_loaded")
    req = Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32),
                  max_new_tokens=40)
    with pytest.raises(PoolExhausted, match="no longer fit"):
        router.run([req])


# ---------------------------------------------------------------------------
# Preempt tie-break regression (scheduler fix riding this PR)


def test_preempt_tie_break_by_rid_not_submission_order():
    """Two requests admitted in the same step that later both starve: the
    victim is the one with the higher request id, however the trace was
    ordered at submission (previously: whichever was inserted later)."""
    eng = engine_for("paged", page_size=8, num_pages=5, slots=3)
    mk = lambda rid: Request(rid=rid,
                             prompt=np.arange(1, 9, dtype=np.int32) + rid,
                             max_new_tokens=20)
    for order in ([0, 1], [1, 0]):
        stats = eng.run([mk(rid) for rid in order])
        by_rid = sorted(stats.results, key=lambda r: r.rid)
        assert stats.preemptions >= 1
        assert by_rid[1].preemptions >= 1     # rid 1 is always the victim
        assert by_rid[0].preemptions == 0     # rid 0 never preempted


# ---------------------------------------------------------------------------
# Equivalence + acceptance


def test_single_replica_router_token_identical_to_bare_engine():
    """N=1 routing must be a no-op: token-identical output, same decode
    step count, same per-request preemption history."""
    e = engine_for()
    router = router_for([e], "least_loaded")
    reqs = zipf_trace(12, e.cfg.vocab_size, max_prompt=24, max_new=16,
                      seed=3)
    a = router.run(reqs)
    ref = e.run(reqs)
    assert _tokens(a) == _tokens(ref)
    assert [r.rid for r in a.results] == [r.rid for r in ref.results]
    assert a.replica_stats[0].decode_steps == ref.decode_steps
    assert [r.preemptions for r in a.results] == \
        [r.preemptions for r in ref.results]
    assert a.replica_of == {r.rid: 0 for r in ref.results}


def test_mixed_layout_fleet_token_identical_to_single_engine():
    """Routing across a paged + contiguous mix never changes tokens."""
    ec = engine_for()
    ep = engine_for("paged", page_size=16)
    router = router_for([ep, ec], "least_loaded")
    reqs = zipf_trace(10, ec.cfg.vocab_size, max_prompt=16, max_new=12,
                      seed=5)
    a = router.run(reqs)
    assert _tokens(a) == _tokens(ec.run(reqs))
    assert len(a.replica_of) == 10


def test_least_loaded_3_replicas_sustains_2_5x_in_flight():
    """Acceptance: on the tight-budget Zipf trace, a least_loaded router
    over 3 replicas holds >= 2.5x the in-flight requests of one
    contiguous engine — with token-identical output."""
    tgt = _tight_target()
    single = engine_for(slots=8, target=tgt)
    assert single.num_slots == 3               # tuner capped worst-case
    router = router_for([single] * 3, "least_loaded")
    reqs = zipf_trace(18, single.cfg.vocab_size, max_prompt=32, max_new=24,
                      seed=0)
    ref = single.run(reqs)
    fleet = router.run(reqs)
    assert fleet.peak_in_flight >= 2.5 * ref.peak_active
    assert _tokens(fleet) == _tokens(ref)
    # fleet drains the trace in fewer lockstep rounds than one engine
    rounds = max(s.decode_steps for s in fleet.replica_stats)
    assert rounds < ref.decode_steps


# ---------------------------------------------------------------------------
# Conservation property: nothing dropped, nothing duplicated


@settings(max_examples=6, deadline=None)
@given(trace_seed=st.integers(min_value=0, max_value=40),
       n=st.integers(min_value=4, max_value=10),
       policy=st.sampled_from(["round_robin", "least_loaded",
                               "prefix_affinity"]))
def test_router_conserves_requests_across_preemptions(trace_seed, n, policy):
    """For random Zipf traces over a mixed fleet whose paged replica is
    scarce enough to preempt: every admitted request completes exactly
    once, on exactly one replica — no duplicated or dropped ids."""
    scarce = engine_for("paged", page_size=8, num_pages=13)  # 96 KV tokens
    roomy = engine_for()
    router = router_for([scarce, roomy], policy)
    reqs = zipf_trace(n, roomy.cfg.vocab_size, max_prompt=16, max_new=12,
                      seed=trace_seed)
    stats = router.run(reqs)
    assert [r.rid for r in stats.results] == list(range(n))
    assert sorted(stats.replica_of) == list(range(n))
    # each rid completed on exactly one replica: per-replica results are
    # disjoint and cover the trace
    per_replica = [[r.rid for r in s.results] for s in stats.replica_stats]
    flat = sorted(rid for rids in per_replica for rid in rids)
    assert flat == list(range(n))
    for rids in per_replica:
        assert len(set(rids)) == len(rids)
    for req, res in zip(reqs, stats.results):
        assert 1 <= len(res.tokens) <= req.max_new_tokens
