"""Sedov stencil kernel vs the lulesh oracle: grids/blocks sweep +
boundary exactness + multi-step stability."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.ops import sedov_step_kernel
from repro.kernels.sedov_stencil import cfl_dt
from repro.models import lulesh


def _developed_state(n, warm=3):
    cfg = lulesh.LuleshConfig(grid=n)
    st_ = lulesh.init_state(cfg)
    for _ in range(warm):
        st_ = lulesh.step(st_, cfg)
    return cfg, st_


@pytest.mark.parametrize("n,bx", [(8, 4), (16, 4), (16, 8), (16, 16),
                                  (24, 8), (32, 16)])
def test_kernel_matches_oracle(n, bx):
    cfg, st_ = _developed_state(n)
    got = sedov_step_kernel(st_, cfg, block_x=bx)
    want = lulesh.step(st_, cfg)
    for f in ("rho", "e", "v"):
        scale = float(jnp.abs(want[f]).max()) + 1e-12
        err = float(jnp.abs(got[f] - want[f]).max()) / scale
        assert err < 1e-6, (f, n, bx, err)


def test_boundary_rows_exact():
    """Edge-clamped boundary must match the oracle exactly — the blast
    starts in the corner, so boundary errors show up immediately."""
    cfg, st_ = _developed_state(16, warm=1)
    got = sedov_step_kernel(st_, cfg, block_x=4)
    want = lulesh.step(st_, cfg)
    for f in ("rho", "e"):
        g, w = np.asarray(got[f]), np.asarray(want[f])
        np.testing.assert_allclose(g[0], w[0], rtol=1e-6)
        np.testing.assert_allclose(g[-1], w[-1], rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([8, 16]), bx=st.sampled_from([4, 8]),
       warm=st.integers(0, 4))
def test_kernel_property_random_states(n, bx, warm):
    if bx > n:
        return
    cfg, st_ = _developed_state(n, warm=warm)
    got = sedov_step_kernel(st_, cfg, block_x=bx)
    want = lulesh.step(st_, cfg)
    for f in ("rho", "e", "v"):
        scale = float(jnp.abs(want[f]).max()) + 1e-12
        assert float(jnp.abs(got[f] - want[f]).max()) / scale < 1e-5


def test_multi_step_kernel_trajectory():
    """10 kernel steps stay glued to 10 oracle steps."""
    cfg, st_k = _developed_state(16, warm=0)
    st_o = {k: v for k, v in st_k.items()}
    for _ in range(10):
        st_k = sedov_step_kernel(st_k, cfg, block_x=8)
        st_o = lulesh.step(st_o, cfg)
    for f in ("rho", "e"):
        scale = float(jnp.abs(st_o[f]).max()) + 1e-12
        assert float(jnp.abs(st_k[f] - st_o[f]).max()) / scale < 1e-4


def test_cfl_dt_positive_and_shrinks_with_energy():
    cfg, st_ = _developed_state(8, warm=0)
    dt = float(cfl_dt(st_))
    assert dt > 0
    hot = dict(st_, e=st_["e"] * 100)
    assert float(cfl_dt(hot)) < dt
