"""Open-loop serving: arrival presets (Poisson/bursty virtual-step
stamps), open == closed stream identity, SLO-aware reject admission,
autoscale conservation under drain, the vstep-only regression gate, and
the trace-repetitiveness off-by-one regression (constant-run prompts
read as 0.0 repetitive, so the tuner never turned spec decoding on)."""

import dataclasses
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent.parent / "benchmarks"))

from repro.core.tuning import SPEC_MAX_K, spec_k_for, ttft_napkin_steps
from repro.serving import (AutoscalePolicy, ReplicaRouter, Request,
                           RequestResult, ServeEngine, bursty_arrivals,
                           percentile_steps, poisson_arrivals,
                           trace_repetitiveness, with_arrivals, zipf_trace)

ARCH = "deepseek-7b-smoke"
SLOTS, MAX_LEN = 3, 64

_ENGINES: dict = {}


def engine_for(slots=SLOTS):
    key = slots
    if key not in _ENGINES:
        _ENGINES[key] = ServeEngine(
            arch=ARCH, target="local:cpu", num_slots=slots, max_len=MAX_LEN,
            seed=0, kv_layout="contiguous", log=lambda *a, **k: None)
    return _ENGINES[key]


def router_for(engines, **kw):
    return ReplicaRouter(engines, log=lambda *a, **k: None, **kw)


def _trace(n, engine, seed=0):
    return zipf_trace(n, engine.cfg.vocab_size, max_prompt=24, max_new=6,
                      alpha=1.3, seed=seed)


def _tokens(stats):
    return {r.rid: r.tokens for r in stats.results}


# ---------------------------------------------------------------------------
# Satellite: trace_repetitiveness off-by-one regression


def _buggy_repetitiveness(requests, max_n=3):
    """The pre-fix scan: ``range(i - max_n)`` drops the window ending at
    i-1, the only earlier occurrence a period-1 (constant-run) cycle
    ever has."""
    hits = total = 0
    for req in requests:
        p = [int(t) for t in np.asarray(req.prompt)]
        for i in range(max_n, len(p)):
            gram = p[i - max_n + 1:i + 1]
            found = any(p[j:j + max_n] == gram for j in range(i - max_n))
            hits += bool(found)
            total += 1
    return hits / total if total else 0.0


def test_repetitiveness_constant_run_regression():
    # a constant prompt of length max_n+1 is the minimal repetitive
    # input: the gram ending at the last position recurs exactly once
    # earlier, in the window ending at i-1 (j = i - max_n) — the one
    # start index the buggy ``range(i - max_n)`` scan excluded.  The
    # buggy scan therefore saw NO repetition at all and the tuner left
    # speculative decoding off on a maximally predictable trace.
    req = Request(rid=0, prompt=np.full(4, 7, dtype=np.int32),
                  max_new_tokens=4)
    r_fixed = trace_repetitiveness([req])
    r_buggy = _buggy_repetitiveness([req])
    assert r_fixed == 1.0
    assert r_buggy == 0.0
    # ...and the off-by-one flips the tuner's decision end to end:
    # spec_k_for goes from "spec off" to the full draft length
    assert spec_k_for(r_buggy) == 0
    assert spec_k_for(r_fixed) == SPEC_MAX_K
    # longer constant runs: the buggy scan recovers later positions but
    # still undercounts vs the fixed scan (which saturates at 1.0)
    long = Request(rid=1, prompt=np.full(12, 7, dtype=np.int32),
                   max_new_tokens=4)
    assert trace_repetitiveness([long]) == 1.0
    assert _buggy_repetitiveness([long]) < 1.0


def test_repetitiveness_unrelated_prompt_unaffected():
    # a strictly increasing prompt has no repeated n-gram under either
    # scan — the fix must not inflate genuinely novel prompts
    req = Request(rid=0, prompt=np.arange(16, dtype=np.int32),
                  max_new_tokens=4)
    assert trace_repetitiveness([req]) == 0.0
    assert _buggy_repetitiveness([req]) == 0.0


# ---------------------------------------------------------------------------
# Arrival presets


def test_poisson_arrivals_deterministic_and_monotone():
    e = engine_for()
    a = poisson_arrivals(_trace(8, e), mean_gap=4.0, seed=5)
    b = poisson_arrivals(_trace(8, e), mean_gap=4.0, seed=5)
    stamps = [r.arrival_vstep for r in a]
    assert stamps == [r.arrival_vstep for r in b]     # seeded == replayable
    assert stamps == sorted(stamps)                   # cumulative gaps
    assert all(s >= 0 for s in stamps)
    assert stamps[-1] > 0                             # actually spread out
    c = poisson_arrivals(_trace(8, e), mean_gap=4.0, seed=6)
    assert [r.arrival_vstep for r in c] != stamps     # seed moves the draw


def test_bursty_arrivals_modulate_and_validate():
    e = engine_for()
    # a short period relative to the arrival density, so the schedule
    # traverses whole burst/trough cycles within the trace
    reqs = bursty_arrivals(_trace(24, e), mean_gap=6.0, burst=4.0,
                           period=6.0, seed=1)
    stamps = [r.arrival_vstep for r in reqs]
    assert stamps == sorted(stamps)
    gaps = np.diff(stamps)
    assert gaps.max() >= 4 * max(gaps.min(), 1)       # peaks AND troughs
    with pytest.raises(ValueError, match="burst"):
        bursty_arrivals(_trace(2, e), burst=0.5)


def test_with_arrivals_dispatch():
    e = engine_for()
    reqs = with_arrivals(_trace(4, e), "poisson", mean_gap=3.0, seed=2)
    assert any(r.arrival_vstep > 0 for r in reqs)
    reqs = with_arrivals(reqs, "closed")              # closed re-zeroes
    assert all(r.arrival_vstep == 0 for r in reqs)
    with pytest.raises(ValueError, match="arrival mode"):
        with_arrivals(reqs, "uniform")


def test_negative_arrival_rejected():
    e = engine_for()
    reqs = _trace(1, e)
    reqs[0].arrival_vstep = -3
    with pytest.raises(ValueError, match="arrival_vstep"):
        e.run(reqs)


# ---------------------------------------------------------------------------
# SLO bookkeeping primitives


def test_meets_slo_vstep_semantics():
    r = RequestResult(rid=0, prompt_len=4, max_new_tokens=4,
                      v_submit=10, v_first=14, v_done=20)
    assert r.ttft_steps == 4 and r.e2e_steps == 10
    assert r.meets_slo(0, 0)                  # 0 = deadline unset, passes
    assert r.meets_slo(4, 10)
    assert not r.meets_slo(3, 0)              # ttft deadline busted
    assert not r.meets_slo(0, 9)              # e2e deadline busted
    unfinished = RequestResult(rid=1, prompt_len=4, max_new_tokens=4)
    assert not unfinished.meets_slo(100, 100)  # never completed never counts


def test_percentile_steps_empty_is_nan():
    assert np.isnan(percentile_steps([], 99))
    assert percentile_steps([4.0], 99) == 4.0


def test_ttft_napkin_steps():
    # waited + backlog share + own prefill chunk-equivalents
    assert ttft_napkin_steps(64, 16) == 4
    assert ttft_napkin_steps(1, 16) == 1          # ceil, never 0
    assert ttft_napkin_steps(64, 16, backlog_chunks=3, waited_steps=2) == 9


# ---------------------------------------------------------------------------
# Open loop == closed loop (streams), determinism, SLO admission


def test_open_loop_streams_match_closed_replay():
    e = engine_for()
    open_reqs = with_arrivals(_trace(6, e), "poisson", mean_gap=5.0, seed=3)
    closed = [dataclasses.replace(r, arrival_vstep=0) for r in open_reqs]
    s_open = e.run(open_reqs)
    s_closed = e.run(closed)
    assert _tokens(s_open) == _tokens(s_closed)
    # arrivals only ever push latency up, never tokens around
    assert s_open.decode_steps >= s_closed.decode_steps or \
        s_open.generated_tokens == s_closed.generated_tokens


def test_router_open_loop_determinism_with_slo_and_autoscale():
    e = engine_for()
    policy = AutoscalePolicy(min_replicas=1, max_replicas=3)
    kw = dict(policy="continuous", slo_ttft_steps=20, slo_e2e_steps=120,
              admission="reject", autoscale=policy)
    runs = []
    for _ in range(2):
        reqs = with_arrivals(_trace(10, e), "poisson", mean_gap=4.0, seed=7)
        runs.append(router_for([e, e, e]).run(reqs, **kw))
    a, b = runs
    assert [r.arrival_vstep
            for r in with_arrivals(_trace(10, e), "poisson", mean_gap=4.0,
                                   seed=7)] == \
        [r.arrival_vstep
         for r in with_arrivals(_trace(10, e), "poisson", mean_gap=4.0,
                                seed=7)]
    assert a.replica_of == b.replica_of
    assert [(r.rid, r.reason) for r in a.rejected] == \
        [(r.rid, r.reason) for r in b.rejected]
    assert _tokens(a) == _tokens(b)
    for f in ("p50_ttft_steps", "p99_ttft_steps", "p50_e2e_steps",
              "p99_e2e_steps", "goodput_tokens", "total_vsteps"):
        av, bv = getattr(a, f), getattr(b, f)
        assert av == bv or (av != av and bv != bv)  # NaN-safe equality


def test_slo_reject_partitions_and_explains():
    e = engine_for()
    # a brutal 3-vstep TTFT deadline on bunched arrivals: the queue tail
    # provably cannot make it and must be shed with a reason, up front
    reqs = with_arrivals(_trace(9, e), "poisson", mean_gap=1.0, seed=2)
    stats = router_for([e, e]).run(reqs, slo_ttft_steps=3,
                                   admission="reject")
    done = {r.rid for r in stats.results}
    shed = {r.rid for r in stats.rejected}
    assert shed                                     # something was shed
    assert done | shed == set(range(9))             # every rid accounted
    assert not done & shed                          # exactly once each
    for rej in stats.rejected:
        assert "slo_ttft" in rej.reason and rej.predicted_ttft_steps > 3
    # queue admission on the same trace completes everything
    full = router_for([e, e]).run(
        with_arrivals(_trace(9, e), "poisson", mean_gap=1.0, seed=2),
        slo_ttft_steps=3, admission="queue")
    assert {r.rid for r in full.results} == set(range(9))
    assert not full.rejected


def test_reject_needs_slo():
    e = engine_for()
    with pytest.raises(ValueError, match="needs"):
        router_for([e, e]).run(_trace(2, e), admission="reject")


# ---------------------------------------------------------------------------
# Autoscaling


def test_autoscale_drain_conserves_requests():
    e = engine_for()
    # bursty load so the fleet grows at the peak, then drains in the
    # trough — and despite replicas entering/leaving the accepting set,
    # every request completes exactly once on exactly one replica
    reqs = with_arrivals(_trace(14, e), "bursty", mean_gap=3.0, seed=4)
    stats = router_for([e, e, e]).run(
        reqs, slo_ttft_steps=12,
        autoscale=AutoscalePolicy(min_replicas=1, max_replicas=3,
                                  drain_idle_rounds=4))
    assert sorted(r.rid for r in stats.results) == list(range(14))
    assert sorted(stats.replica_of) == list(range(14))
    assert len({r.rid for r in stats.results}) == 14
    assert stats.autoscale_grows > 0                # the peak forced growth
    assert stats.peak_replicas > 1
    events = [(ev.action, ev.replica) for ev in stats.autoscale_events]
    assert all(a in ("grow", "drain", "stop") for a, _ in events)


def test_autoscale_beats_fixed_on_ttft():
    e = engine_for()
    mk = lambda: with_arrivals(_trace(12, e), "poisson",  # noqa: E731
                               mean_gap=6.0, seed=3)
    fixed = router_for([e]).run(mk(), slo_ttft_steps=20, slo_e2e_steps=120)
    auto = router_for([e, e, e]).run(
        mk(), slo_ttft_steps=20, slo_e2e_steps=120,
        autoscale=AutoscalePolicy(min_replicas=1, max_replicas=3))
    assert _tokens(fixed) == _tokens(auto)          # scaling never resamples
    assert auto.p99_ttft_steps < fixed.p99_ttft_steps
    assert auto.goodput_tokens >= fixed.goodput_tokens
    m = auto.to_metrics()
    assert m["router_ttft_p99_steps"] == auto.p99_ttft_steps
    assert m["router_goodput_tokens"] == auto.goodput_tokens
    assert m["router_autoscale_grows"] == auto.autoscale_grows


# ---------------------------------------------------------------------------
# Satellite audit: the CI gate is vstep-only — wall-clock is advisory


def test_regression_gate_ignores_wall_metrics():
    import serving_throughput as bench

    def snap(**over):
        cell = {"tokens_per_s": 50.0, "p99_ttft_steps": 10.0,
                "goodput_tokens": 100, "tokens_per_step": 2.0}
        cell.update(over)
        return {"cells": {"cell": dict(cell)}}

    # a 10x wall-clock collapse alone must NOT trip the gate (advisory)
    bench._check_regression(snap(), snap(tokens_per_s=5.0))
    # ...but the vstep-derived SLO metrics are enforced
    with pytest.raises(SystemExit, match="p99 TTFT"):
        bench._check_regression(snap(), snap(p99_ttft_steps=20.0))
    with pytest.raises(SystemExit, match="goodput"):
        bench._check_regression(snap(), snap(goodput_tokens=10))
    with pytest.raises(SystemExit, match="tokens/step"):
        bench._check_regression(snap(), snap(tokens_per_step=1.0))
    # an idle fleet's NaN percentile serializes to null: skips the gate
    # in either position instead of tripping it
    bench._check_regression(snap(p99_ttft_steps=None),
                            snap(p99_ttft_steps=None))
    bench._check_regression(snap(p99_ttft_steps=None),
                            snap(p99_ttft_steps=3.0))
