"""Optional-hypothesis shim so tier-1 collects without the dependency.

The property tests were written against hypothesis, but the runtime
container does not ship it.  When hypothesis is importable we re-export
the real ``given``/``settings``/``strategies``.  When it is not, ``@given``
degrades to a *deterministic sweep*: each strategy draws ``max_examples``
examples from a per-test seeded PRNG (stable across processes — the seed
goes through SHA-512, not ``hash()``), so the same examples run every
time and failures are reproducible.  No shrinking, no database — just the
property exercised over a fixed spread of inputs.

Usage (the only pattern the tier-1 suite needs):

    from _hypothesis_compat import given, settings, strategies as st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        """A draw function over a ``random.Random``."""

        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    class _StrategiesModule:
        """The subset of ``hypothesis.strategies`` the suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(inner, min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 4

            def draw(rng):
                n = rng.randint(min_size, hi)
                return [inner.example_from(rng) for _ in range(n)]

            return _Strategy(draw)

    strategies = _StrategiesModule()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        """Records max_examples on the (given-wrapped) test function."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        """Deterministic sweep: run the test over `max_examples` draws."""

        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                rng = random.Random(
                    f"easey:{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = {k: s.example_from(rng)
                             for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest must not see the strategy-drawn parameters as
            # fixtures: expose a signature without them (real fixtures,
            # if any, stay visible) and drop the __wrapped__ breadcrumb
            # functools.wraps left, which inspect would follow otherwise.
            sig = inspect.signature(fn)
            runner.__signature__ = sig.replace(
                parameters=[p for name, p in sig.parameters.items()
                            if name not in strats])
            del runner.__wrapped__
            runner.is_hypothesis_fallback = True
            return runner

        return deco
