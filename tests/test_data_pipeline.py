"""Data pipeline: determinism (restart safety), LM labels, family coverage."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataPipeline, SyntheticSource
from repro.models.transformer import model_for

SHAPE = ShapeConfig("t", 32, 2, "train")


def test_deterministic_across_instances():
    m = model_for(smoke_config("deepseek-7b"))
    a = DataPipeline(m, SHAPE, seed=7).batch_at(13)
    b = DataPipeline(m, SHAPE, seed=7).batch_at(13)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_different_steps_differ():
    m = model_for(smoke_config("deepseek-7b"))
    p = DataPipeline(m, SHAPE, seed=7)
    assert not np.array_equal(np.asarray(p.batch_at(0)["tokens"]),
                              np.asarray(p.batch_at(1)["tokens"]))


def test_labels_are_next_token_shift():
    m = model_for(smoke_config("deepseek-7b"))
    b = DataPipeline(m, SHAPE, seed=0).batch_at(0)
    t, l = np.asarray(b["tokens"]), np.asarray(b["labels"])
    np.testing.assert_array_equal(l[:, :-1], t[:, 1:])


@pytest.mark.parametrize("arch,extra", [
    ("whisper-tiny", "frames"), ("llava-next-34b", "patch_embeds")])
def test_modality_stub_inputs_present(arch, extra):
    m = model_for(smoke_config(arch))
    b = DataPipeline(m, SHAPE, seed=0).batch_at(0)
    assert extra in b
    assert np.isfinite(np.asarray(b[extra], np.float32)).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**20), step=st.integers(0, 10_000))
def test_tokens_always_in_vocab(seed, step):
    m = model_for(smoke_config("stablelm-1.6b"))
    b = DataPipeline(m, SHAPE, seed=seed).batch_at(step)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < m.cfg.vocab_size
