"""Architecture registry: exact assigned configs, cell accounting."""

import pytest

from repro.configs import ARCHS, SHAPES, cells, get_config, list_archs
from repro.core.tuning import active_param_count, param_count_estimate

ASSIGNED = {
    "whisper-tiny": dict(num_layers=4, d_model=384, num_heads=6,
                         num_kv_heads=6, d_ff=1536, vocab_size=51865),
    "mistral-large-123b": dict(num_layers=88, d_model=12288, num_heads=96,
                               num_kv_heads=8, d_ff=28672, vocab_size=32768),
    "nemotron-4-340b": dict(num_layers=96, d_model=18432, num_heads=96,
                            num_kv_heads=8, d_ff=73728, vocab_size=256000),
    "stablelm-1.6b": dict(num_layers=24, d_model=2048, num_heads=32,
                          num_kv_heads=32, d_ff=5632, vocab_size=100352),
    "deepseek-7b": dict(num_layers=30, d_model=4096, num_heads=32,
                        num_kv_heads=32, d_ff=11008, vocab_size=102400),
    "xlstm-1.3b": dict(num_layers=48, d_model=2048, num_heads=4,
                       num_kv_heads=4, d_ff=0, vocab_size=50304),
    "llava-next-34b": dict(num_layers=60, d_model=7168, num_heads=56,
                           num_kv_heads=8, d_ff=20480, vocab_size=64000),
    "granite-moe-3b-a800m": dict(num_layers=32, d_model=1536, num_heads=24,
                                 num_kv_heads=8, d_ff=512, vocab_size=49155,
                                 num_experts=40, experts_per_token=8),
    "dbrx-132b": dict(num_layers=40, d_model=6144, num_heads=48,
                      num_kv_heads=8, d_ff=10752, vocab_size=100352,
                      num_experts=16, experts_per_token=4),
    "zamba2-7b": dict(num_layers=81, d_model=3584, num_heads=32,
                      num_kv_heads=32, d_ff=14336, vocab_size=32000,
                      ssm_state=64),
}


def test_all_assigned_archs_registered():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs
    assert "lulesh-dash" in archs  # the paper's own app


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_exact_assigned_numbers(arch):
    cfg = get_config(arch)
    for key, val in ASSIGNED[arch].items():
        assert getattr(cfg, key) == val, (arch, key, getattr(cfg, key), val)


def test_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_cell_accounting_40():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40  # 10 archs x 4 shapes
    skipped = [c for c in all_cells if c[2]]
    # 8 full-attention archs skip long_500k; ssm/hybrid run it
    assert len(skipped) == 8
    for arch, shape, _ in skipped:
        assert shape == "long_500k"
        assert not get_config(arch).sub_quadratic
    runnable = cells()
    assert len(runnable) == 32


@pytest.mark.parametrize("arch,target", [
    ("mistral-large-123b", 123e9), ("nemotron-4-340b", 340e9),
    ("dbrx-132b", 132e9), ("deepseek-7b", 7e9), ("stablelm-1.6b", 1.6e9),
    ("xlstm-1.3b", 1.3e9), ("zamba2-7b", 7e9), ("llava-next-34b", 34e9),
    ("granite-moe-3b-a800m", 3.4e9),
])
def test_param_counts_near_nameplate(arch, target):
    n = param_count_estimate(get_config(arch))
    assert 0.7 * target < n < 1.45 * target, (arch, n / 1e9)


def test_moe_active_params():
    g = get_config("granite-moe-3b-a800m")
    active = active_param_count(g)
    total = param_count_estimate(g)
    assert active < total
    # ~800M active per the model name (embeddings included here)
    assert 0.4e9 < active < 1.4e9, active / 1e9
